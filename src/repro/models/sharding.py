"""Logical sharding hints, decoupled from any concrete mesh.

Models annotate activations with LOGICAL axes ("batch", "seq", "model_d",
"heads", "vocab", "expert"); the launch layer maps logical axes onto mesh
axes ("pod", "data", "model") and activates the mapping via `use_rules`.
Outside a mesh context (CPU smoke tests) hints are identity functions, so
the same model code runs anywhere.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


# logical axis -> mesh axes mapping used by the production launchers
DEFAULT_RULES = {
    "batch": ("pod", "data"),     # DP: batch over pod x data
    "seq": None,                  # sequence kept local by default
    "seq_shard": ("data",),       # long-context: sequence over data
    "seq_mp": ("model",),         # SP fallback: sequence over model when the
                                  # head count doesn't divide the TP degree
    "heads": ("model",),          # TP: attention heads
    "model_d": ("model",),        # TP: hidden/ffn dim
    "vocab": ("model",),          # TP: embedding/vocab
    "expert": ("model",),         # EP: experts over model axis
    "layers": None,
}


def mapped_size(logical_ax) -> int:
    """Product of mesh-axis sizes a logical axis maps to (1 if inactive)."""
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return 1
    rules, axis_names, axis_sizes = ctx
    m = rules.get(logical_ax)
    if not m:
        return 1
    n = 1
    for a in m:
        if a in axis_names:
            n *= axis_sizes.get(a, 1)
    return n


@contextlib.contextmanager
def use_rules(rules, mesh):
    """Activate a logical->mesh mapping (launchers only)."""
    prev = getattr(_state, "ctx", None)
    axis_names = set(mesh.axis_names) if mesh is not None else set()
    axis_sizes = dict(mesh.shape) if mesh is not None else {}
    _state.ctx = (rules, axis_names, axis_sizes)
    try:
        yield
    finally:
        _state.ctx = prev


def spec(*logical_axes, shape=None) -> P:
    """Resolve logical axes to a PartitionSpec under the active rules.

    With `shape`, axes that do not evenly divide the corresponding dim are
    dropped (a 2-kv-head tensor is never forced onto a 16-way axis — that
    triggers involuntary full rematerialization in the SPMD partitioner)."""
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return P()
    rules, axis_names, axis_sizes = ctx
    out = []
    for i, ax in enumerate(logical_axes):
        m = rules.get(ax) if ax is not None else None
        if m is None:
            out.append(None)
            continue
        m = tuple(a for a in m if a in axis_names)
        import os
        if os.environ.get("REPRO_HINT_NO_DIVCHECK"):   # perf-ablation toggle
            shape = None
        if shape is not None and m:
            n = 1
            for a in m:
                n *= axis_sizes.get(a, 1)
            if n == 0 or shape[i] % n != 0:
                m = ()
        out.append(m if len(m) > 1 else (m[0] if m else None))
    return P(*out)


def hint(x, *logical_axes):
    """with_sharding_constraint if a mapping is active; identity otherwise."""
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(
            x, spec(*logical_axes, shape=x.shape))
    except (ValueError, RuntimeError):
        return x
