"""Shared transformer layers: RMSNorm, RoPE, GQA attention, gated MLP.

All functions are pure; parameters are plain dicts of arrays. Attention
covers every variant in the assigned pool through arguments:
  * GQA with arbitrary kv-head count (internlm2/qwen2/gemma2/...)
  * QKV bias (qwen2)
  * logit softcapping (gemma2)
  * sliding-window / local attention (gemma2 alternating layers)
  * partial rotary (stablelm)
  * incremental decode with a preallocated KV cache
Compute runs in cfg.compute_dtype (bf16) with f32 softmax, params in f32.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.base import ArchConfig, dense_init, split_keys
from repro.models.sharding import hint


def rms_norm(x, w, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def _rope_freqs(positions, dim: int, theta: float, dtype):
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv     # (..., dim/2)
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x, positions, theta: float, rotary_pct: float = 1.0):
    """x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    rot = int(hd * rotary_pct) // 2 * 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    cos, sin = _rope_freqs(positions, rot, theta, x.dtype)   # (B,S,rot/2)
    cos = cos[:, :, None, :] if cos.ndim == 3 else cos[None, :, None, :]
    sin = sin[:, :, None, :] if sin.ndim == 3 else sin[None, :, None, :]
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out, xp], axis=-1) if rot < hd else out


def _sdpa_flash(qg, k, v, cfg, scale, sliding_window, kv_len):
    """Pallas flash-attention path: fold (B,Kv,G) -> BH, broadcast k/v.

    qg: (B,Sq,Kv,G,hd); k,v: (B,Skv,Kv,hd). Interpret mode off-TPU."""
    import jax as _jax
    from repro.kernels.flash_attention import flash_attention
    B, Sq, Kv, G, hd = qg.shape
    Skv = k.shape[1]
    qf = qg.transpose(0, 2, 3, 1, 4).reshape(B * Kv * G, Sq, hd)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(
        B * Kv * G, Skv, hd)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(
        B * Kv * G, Skv, hd)
    out = flash_attention(
        qf, kf, vf, scale=scale, causal=True, window=sliding_window,
        softcap=cfg.attn_softcap, kv_len=kv_len,
        interpret=_jax.default_backend() != "tpu")
    return out.reshape(B, Kv, G, Sq, hd).transpose(0, 3, 1, 2, 4)


class KVCache(NamedTuple):
    k: jnp.ndarray        # (B, max_len, Kv, hd)
    v: jnp.ndarray
    length: jnp.ndarray   # () int32 — valid prefix length


def init_attn(key, cfg: ArchConfig, d_model=None):
    D = d_model or cfg.d_model
    H, Kv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    ks = split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, H * hd), cfg.pdtype),
        "wk": dense_init(ks[1], (D, Kv * hd), cfg.pdtype),
        "wv": dense_init(ks[2], (D, Kv * hd), cfg.pdtype),
        "wo": dense_init(ks[3], (H * hd, D), cfg.pdtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), cfg.pdtype)
        p["bk"] = jnp.zeros((Kv * hd,), cfg.pdtype)
        p["bv"] = jnp.zeros((Kv * hd,), cfg.pdtype)
    return p


def _sdpa(q, k, v, mask, softcap, scale):
    """q: (B,Sq,Kv,G,hd)  k,v: (B,Skv,Kv,hd)  mask: (B|1, Sq, Skv) bool."""
    logits = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    # keep P and the PV accumulation in f32 (same as the QK einsum and the
    # chunked/flash paths); only the stored output drops to the compute dtype
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v,
                     preferred_element_type=jnp.float32)
    return out.astype(v.dtype)


def _sdpa_chunked(q, k, v, mask, softcap, scale, chunk: int):
    """Flash-style online-softmax attention over KV chunks.

    Identical math to _sdpa but never materializes the (Sq, Skv) logits in
    HBM: a lax.scan walks KV in `chunk`-sized blocks carrying the running
    (max, denominator, weighted accumulator). Memory drops from O(Sq*Skv)
    to O(Sq*chunk) — the hillclimb lever for the memory-bound attention
    cells (EXPERIMENTS.md §Perf). Shapes as in _sdpa.
    """
    B, Sq, Kv, G, hd = q.shape
    Skv = k.shape[1]
    nc = -(-Skv // chunk)
    pad = nc * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, 0), (0, pad)))
    kc = k.reshape(B, nc, chunk, Kv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nc, chunk, Kv, hd).transpose(1, 0, 2, 3, 4)
    mc = mask.reshape(mask.shape[0], Sq, nc, chunk).transpose(2, 0, 1, 3)

    def body(carry, xs):
        m, l, acc = carry                     # (B,Kv,G,Sq), ..., (..., hd)
        kb, vb, mb = xs
        logits = jnp.einsum("bqkgh,bskh->bkgqs", q, kb,
                            preferred_element_type=jnp.float32) * scale
        if softcap:
            logits = jnp.tanh(logits / softcap) * softcap
        logits = jnp.where(mb[:, None, None, :, :], logits, -1e30)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] \
            + jnp.einsum("bkgqs,bskh->bkgqh", p, vb.astype(jnp.float32))
        return (m_new, l, acc), None

    init = (jnp.full((B, Kv, G, Sq), -jnp.inf, jnp.float32),
            jnp.zeros((B, Kv, G, Sq), jnp.float32),
            jnp.zeros((B, Kv, G, Sq, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init, (kc, vc, mc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).astype(v.dtype)   # (B,Sq,Kv,G,hd)


def attend(params, x, cfg: ArchConfig, *, positions, kv=None, kv_positions=None,
           causal=True, sliding_window=None, cache: Optional[KVCache] = None,
           update_cache: bool = False, pad=None):
    """Unified attention entry point.

    Self-attention: kv=None. Cross-attention: kv=(memory, memory_positions),
    causal=False. With `cache` and Sq==1 this is an incremental decode step;
    with `cache` and update_cache=True it is a prefill that fills the cache.
    Returns (out (B,Sq,D), new_cache).

    `pad` ((B,) int32 per-row LEFT-pad lengths) serves ragged batches out of
    one cache: the caller passes positions already shifted by -pad (so rope
    angles and causal order are per-row logical positions), and here the
    first pad[b] cache slots of row b are masked invalid and kv positions
    are shifted to match. Only meaningful on the cached self-attention path;
    pad=None leaves every graph exactly as before.
    """
    B, Sq, D = x.shape
    H, Kv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    G = H // Kv
    cd = cfg.cdtype

    q = (x @ params["wq"].astype(cd))
    src = x if kv is None else kv
    k = (src @ params["wk"].astype(cd))
    v = (src @ params["wv"].astype(cd))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(cd)
        k = k + params["bk"].astype(cd)
        v = v + params["bv"].astype(cd)
    q = q.reshape(B, Sq, H, hd)
    k = k.reshape(B, src.shape[1], Kv, hd)
    v = v.reshape(B, src.shape[1], Kv, hd)

    if kv is None:  # rope only for self-attention
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rotary_pct)
        k = apply_rope(k, kv_positions if kv_positions is not None else positions,
                       cfg.rope_theta, cfg.rotary_pct)
    # TP shards heads when they divide the model axis; otherwise fall back
    # to sequence-parallel attention (queries sharded over "model") instead
    # of silently replicating the O(S^2) work on every TP rank
    from repro.models.sharding import mapped_size
    tp = mapped_size("heads")
    if tp > 1 and H % tp != 0 and Sq > 1:
        q = hint(q, "batch", "seq_mp", None, None)
    else:
        q = hint(q, "batch", None, "heads", None)
        k = hint(k, "batch", None, "heads", None)

    if cache is not None and kv is None:
        # decode (Sq==1) appends at cache.length; prefill writes the prefix
        nk = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k.astype(cache.k.dtype), cache.length, axis=1)
        nv = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v.astype(cache.v.dtype), cache.length, axis=1)
        cache = KVCache(nk, nv, cache.length + Sq)
        k, v = cache.k, cache.v

    Skv = k.shape[1]
    q_pos = positions if positions.ndim == 2 else positions[None, :]
    if cache is not None and kv is None:
        idx = jnp.arange(Skv)[None, :]
        valid = idx < cache.length
        kv_pos = idx
        if pad is not None:
            # ragged wave: row b's cache holds pad[b] dead slots before its
            # real prompt; mask them out and shift kv to logical positions
            valid = valid & (idx >= pad[:, None])
            kv_pos = idx - pad[:, None]
    else:
        kv_pos = (kv_positions if kv_positions is not None
                  else jnp.arange(Skv))[None, :]
        valid = jnp.ones((1, Skv), bool)
    if causal:
        mask = (q_pos[:, :, None] >= kv_pos[:, None, :]) & valid[:, None, :]
    else:
        mask = jnp.broadcast_to(valid[:, None, :], (valid.shape[0], Sq, Skv))
    if sliding_window:
        mask = mask & (q_pos[:, :, None] - kv_pos[:, None, :] < sliding_window)

    scale = cfg.query_scale if cfg.query_scale else hd ** -0.5
    qg = q.reshape(B, Sq, Kv, G, hd)
    use_flash = (cfg.attn_impl == "pallas_flash" and Sq > 1 and kv is None
                 and causal and Sq % 128 == 0 and Skv % 128 == 0
                 and pad is None)   # flash path has no per-row pad mask
    if use_flash:
        out = _sdpa_flash(qg, k, v, cfg, scale, sliding_window,
                          cache.length if cache is not None else None)
    elif cfg.attn_impl in ("chunked", "pallas_flash") and Sq > 1 \
            and Skv > cfg.attn_chunk:
        out = _sdpa_chunked(qg, k, v, mask, cfg.attn_softcap, scale,
                            cfg.attn_chunk)
    else:
        out = _sdpa(qg, k, v, mask, cfg.attn_softcap, scale)
    out = out.reshape(B, Sq, H * hd) @ params["wo"].astype(cd)
    return hint(out, "batch", None, "model_d"), cache


def init_mlp(key, cfg: ArchConfig, d_ff=None, d_model=None):
    D = d_model or cfg.d_model
    F = d_ff or cfg.d_ff
    ks = split_keys(key, 3)
    return {
        "wi": dense_init(ks[0], (D, F), cfg.pdtype),
        "wg": dense_init(ks[1], (D, F), cfg.pdtype),
        "wo": dense_init(ks[2], (F, D), cfg.pdtype),
    }


def mlp(params, x, cfg: ArchConfig):
    cd = cfg.cdtype
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = act(x @ params["wg"].astype(cd)) * (x @ params["wi"].astype(cd))
    h = hint(h, "batch", None, "model_d")
    return h @ params["wo"].astype(cd)
