"""Architecture config schema + parameter init helpers (pure JAX, no flax).

One ArchConfig describes any member of the assigned architecture pool:
dense / MoE / SSM / hybrid / VLM / audio. Family-specific fields are ignored
by families that don't use them. Configs are frozen + hashable so they can be
jit static arguments.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int

    # attention details
    head_dim: Optional[int] = None          # default d_model // n_heads
    qkv_bias: bool = False                  # qwen2
    rope_theta: float = 10_000.0
    rotary_pct: float = 1.0                 # stablelm partial rotary
    attn_softcap: Optional[float] = None    # gemma2 50.0
    final_softcap: Optional[float] = None   # gemma2 30.0
    sliding_window: Optional[int] = None    # gemma2 local layers
    local_global_period: int = 0            # gemma2: 2 => alternate local/global
    query_scale: Optional[float] = None
    tie_embeddings: bool = False
    act: str = "silu"                       # silu | gelu

    # MoE
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    n_shared_experts: int = 0
    moe_capacity_factor: float = 1.25
    moe_period: int = 1                     # every k-th layer is MoE

    # SSM / hybrid
    ssm_kind: str = ""                      # mamba2 | xlstm
    ssm_state: int = 64
    ssm_heads: int = 0
    ssm_expand: int = 2
    slstm_period: int = 0                   # xlstm: every k-th block is sLSTM
    attn_period: int = 0                    # zamba2: shared attn every k ssm layers

    # VLM
    cross_attn_period: int = 0              # llama3.2-vision: every 5th layer
    n_patches: int = 1601                   # stub vision tokens
    vision_dim: int = 1280                  # stub patch embedding dim

    # audio (enc-dec)
    enc_dec: bool = False
    n_enc_layers: int = 0
    n_enc_frames: int = 1500                # stub conv-frontend output length

    # numerics
    rms_eps: float = 1e-6
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # substrate behaviour
    remat: bool = True                      # activation checkpoint per block
    scan_layers: bool = True
    attn_impl: str = "dense"                # dense | chunked (flash-style)
    attn_chunk: int = 1024                  # KV chunk for attn_impl=chunked
    seq_parallel_residual: bool = False     # shard residual stream seq over TP
    moe_shard_cap: bool = False             # shard MoE dispatch cap over DP

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % max(self.n_kv, 1) == 0, "GQA group mismatch"

    @property
    def cdtype(self):
        return jnp.bfloat16 if self.compute_dtype == "bfloat16" else jnp.float32

    @property
    def pdtype(self):
        return jnp.float32 if self.param_dtype == "float32" else jnp.bfloat16

    # ---- parameter counts (roofline MODEL_FLOPS = 6*N*D) -------------------
    def param_count(self) -> int:
        """EXACT parameter count from the abstract init tree (eval_shape):
        zero drift between the count and the implementation."""
        from repro.models.transformer import count_params  # lazy: no cycle
        return count_params(self)

    def _param_count_analytic(self) -> int:
        D, H, Kv, hd = self.d_model, self.n_heads, self.n_kv, self.head_dim
        attn = D * H * hd + 2 * D * Kv * hd + H * hd * D
        if self.family in ("ssm", "hybrid") and self.ssm_kind:
            inner = self.ssm_expand * D
            ssm = D * inner * 2 + inner * D + inner * (2 * self.ssm_state)
            mixer = ssm
        else:
            mixer = attn
        if self.n_experts:
            ff_moe = 3 * D * self.expert_d_ff * self.n_experts \
                + D * self.n_experts \
                + 3 * D * self.expert_d_ff * self.n_shared_experts
            dense_every = self.moe_period
            n_moe = self.n_layers // dense_every
            n_dense = self.n_layers - n_moe
            ff_total = n_moe * ff_moe + n_dense * 3 * D * self.d_ff
            ff = ff_total / max(self.n_layers, 1)
        else:
            ff = 3 * D * self.d_ff
        per_layer = mixer + ff + 2 * D
        n_dec = self.n_layers
        total = n_dec * per_layer + self.vocab * D * (1 if self.tie_embeddings else 2)
        if self.enc_dec:
            # encoder layers + decoder cross-attention
            total += self.n_enc_layers * (attn + 3 * D * self.d_ff + 2 * D)
            total += n_dec * (attn + D)
        if self.cross_attn_period:
            n_x = self.n_layers // self.cross_attn_period
            total += n_x * (attn + 3 * D * self.d_ff + 2 * D)
        return int(total)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only top_k + shared experts)."""
        if not self.n_experts:
            return self.param_count()
        D = self.d_model
        full = self.param_count()
        inactive = (self.n_experts - self.top_k) * 3 * D * self.expert_d_ff
        n_moe = self.n_layers // self.moe_period
        return int(full - n_moe * inactive)


# ------------------------------ init helpers --------------------------------

def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))
