"""LM substrate: composable model definitions for the assigned arch pool."""
from repro.models.base import ArchConfig
from repro.models.transformer import Model, build_stack_spec
from repro.models import layers, moe, ssm, sharding

__all__ = ["ArchConfig", "Model", "build_stack_spec", "layers", "moe", "ssm",
           "sharding"]
