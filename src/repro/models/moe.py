"""Mixture-of-Experts FFN with capacity-based sort dispatch (EP-shardable).

Dispatch is the same fixed-capacity rank-allocation idiom the BCPNN spike
queues use (sort by destination, rank within group, drop past capacity):
tokens are routed to expert buffers of shape (E, C, D), experts run as one
batched einsum (MXU-friendly), and results are combined with router weights.
Experts shard over the "expert" logical axis (-> mesh "model"); with 128
experts on a 16-way model axis each device owns 8 experts.

Router runs in f32. Returns (out, aux) where aux carries the switch-style
load-balance loss and the dropped-token fraction (observability mirrors the
BCPNN drop counters).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.base import ArchConfig, dense_init, split_keys
from repro.models.sharding import hint


def _rank_within_sorted_key(keys, order):
    sorted_keys = keys[order]
    idx = jnp.arange(keys.shape[0])
    is_first = jnp.concatenate([jnp.array([True]), sorted_keys[1:] != sorted_keys[:-1]])
    first_pos = jax.lax.associative_scan(jnp.maximum,
                                         jnp.where(is_first, idx, 0))
    rank_sorted = idx - first_pos
    return jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)


def init_moe(key, cfg: ArchConfig):
    E, D, F = cfg.n_experts, cfg.d_model, cfg.expert_d_ff
    ks = split_keys(key, 5)
    p = {
        "router": dense_init(ks[0], (D, E), jnp.float32, scale=0.02),
        "wi": dense_init(ks[1], (E, D, F), cfg.pdtype),
        "wg": dense_init(ks[2], (E, D, F), cfg.pdtype),
        "wo": dense_init(ks[3], (E, F, D), cfg.pdtype),
    }
    if cfg.n_shared_experts:
        Fs = cfg.expert_d_ff * cfg.n_shared_experts
        kss = split_keys(ks[4], 3)
        p["shared"] = {
            "wi": dense_init(kss[0], (D, Fs), cfg.pdtype),
            "wg": dense_init(kss[1], (D, Fs), cfg.pdtype),
            "wo": dense_init(kss[2], (Fs, D), cfg.pdtype),
        }
    return p


def moe_ffn(params, x, cfg: ArchConfig):
    """x: (B, S, D) -> (out (B,S,D), aux dict)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    cd = cfg.cdtype
    xt = x.reshape(T, D)

    # ---- routing (f32) -----------------------------------------------------
    logits = xt.astype(jnp.float32) @ params["router"]       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)                   # (T, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # ---- capacity dispatch (sort + rank-within-expert) ---------------------
    M = T * K
    flat_e = top_e.reshape(M)
    cap = int(max(8, round(T * K / E * cfg.moe_capacity_factor)))
    order = jnp.argsort(flat_e)
    rank = _rank_within_sorted_key(flat_e, order)
    ok = rank < cap
    slot = jnp.where(ok, flat_e * cap + rank, E * cap)       # OOB -> dropped
    tok = jnp.arange(M) // K

    buf = jnp.zeros((E * cap, D), cd).at[slot].set(
        xt.astype(cd)[tok], mode="drop")
    # cap over DP turns the token->expert reshard into an all-to-all-like
    # exchange instead of a data-axis all-reduce of replicated buffers
    cap_ax = "batch" if cfg.moe_shard_cap else None
    buf = hint(buf.reshape(E, cap, D), "expert", cap_ax, None)

    # ---- expert computation (batched over experts) -------------------------
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = act(jnp.einsum("ecd,edf->ecf", buf, params["wg"].astype(cd))) \
        * jnp.einsum("ecd,edf->ecf", buf, params["wi"].astype(cd))
    out_e = jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(cd))
    out_e = hint(out_e, "expert", cap_ax, None).reshape(E * cap, D)

    # ---- combine ------------------------------------------------------------
    gathered = out_e[jnp.minimum(slot, E * cap - 1)]          # (M, D)
    w = jnp.where(ok, top_w.reshape(M), 0.0).astype(cd)
    out = (gathered * w[:, None]).reshape(T, K, D).sum(axis=1)

    if cfg.n_shared_experts:
        sp = params["shared"]
        hs = act(xt.astype(cd) @ sp["wg"].astype(cd)) * (xt.astype(cd) @ sp["wi"].astype(cd))
        out = out + hs @ sp["wo"].astype(cd)

    # switch-style load-balance loss + drop observability
    me = jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), axis=0)
    ce = jnp.mean(probs, axis=0)
    aux = {
        "lb_loss": E * jnp.sum(me * ce),
        "drop_frac": 1.0 - jnp.mean(ok.astype(jnp.float32)),
    }
    return out.reshape(B, S, D), aux
