"""State-space and recurrent mixers: Mamba2 (SSD), mLSTM, sLSTM.

These are the sub-quadratic backbones for zamba2-7b (Mamba2 + shared attn)
and xlstm-125m (mLSTM/sLSTM). Each mixer exposes:
  init_*(key, cfg)            parameters
  *_seq(params, x, cfg)       full-sequence form (train / prefill)
  *_step(params, x_t, state)  single-token recurrent form (decode)
and the recurrent state doubles as the "KV cache" — O(1) in sequence length,
which is what makes the long_500k decode cell feasible for these archs.

Mamba2 uses the chunked SSD algorithm (quadratic only within Q=128 chunks,
linear across chunks) so train-time memory is O(S*Q) not O(S^2) and the
inter-chunk state hand-off is an associative scan.
There is an echo of the paper here: "decay + rank-1 spike injection" is
exactly the BCPNN trace update; the SSD state update h' = a*h + dt*B x^T is
the same algebraic shape (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.base import ArchConfig, dense_init, split_keys
from repro.models.sharding import hint

CHUNK = 128


# ================================ Mamba2 (SSD) ===============================

def init_mamba2(key, cfg: ArchConfig):
    D = cfg.d_model
    inner = cfg.ssm_expand * D
    N = cfg.ssm_state
    P = 64                                   # head dim
    H = inner // P
    ks = split_keys(key, 4)
    return {
        "in_proj": dense_init(ks[0], (D, 2 * inner + 2 * N + H), cfg.pdtype),
        "conv": dense_init(ks[1], (4, inner + 2 * N), cfg.pdtype, scale=0.3),
        "a_log": jnp.zeros((H,), jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_w": jnp.zeros((inner,), cfg.pdtype),
        "out_proj": dense_init(ks[2], (inner, D), cfg.pdtype),
    }


def _mamba_projections(params, x, cfg: ArchConfig):
    D = cfg.d_model
    inner = cfg.ssm_expand * D
    N = cfg.ssm_state
    P = 64
    H = inner // P
    cd = cfg.cdtype
    zxbcdt = x @ params["in_proj"].astype(cd)
    z, xc, Bm, Cm, dt = jnp.split(
        zxbcdt, [inner, 2 * inner, 2 * inner + N, 2 * inner + 2 * N], axis=-1)
    return z, xc, Bm, Cm, dt, (inner, N, P, H)


def _causal_conv(u, w):
    """Depthwise causal conv, window 4. u: (B,S,C), w: (4,C)."""
    pad = jnp.pad(u, ((0, 0), (3, 0), (0, 0)))
    return sum(pad[:, i:i + u.shape[1], :] * w[i][None, None, :]
               for i in range(4))


def mamba2_seq(params, x, cfg: ArchConfig, state=None, return_state=False):
    """Chunked SSD over the full sequence. x: (B,S,D)."""
    B, S, D = x.shape
    cd = cfg.cdtype
    z, xc, Bm, Cm, dt, (inner, N, P, H) = _mamba_projections(params, x, cfg)
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, params["conv"].astype(cd)))
    xc, Bm, Cm = jnp.split(conv_out, [inner, inner + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # (B,S,H)
    a = -jnp.exp(params["a_log"])                                      # (H,)
    dA_log = dt * a[None, None, :]                                     # (B,S,H) <= 0

    Q = min(CHUNK, S)
    assert S % Q == 0, f"seq {S} not divisible by chunk {Q}"
    nc = S // Q
    xh = xc.reshape(B, nc, Q, H, P).astype(jnp.float32)
    Bc = Bm.reshape(B, nc, Q, N).astype(jnp.float32)
    Cc = Cm.reshape(B, nc, Q, N).astype(jnp.float32)
    dtc = dt.reshape(B, nc, Q, H)
    dAc = dA_log.reshape(B, nc, Q, H)

    cum = jnp.cumsum(dAc, axis=2)                                      # (B,nc,Q,H)
    # intra-chunk (quadratic within Q): L[t,s] = exp(cum_t - cum_s) for s<=t
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]                # (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(rel), 0.0)
    G = jnp.einsum("bcqn,bcsn->bcqs", Cc, Bc)                          # (B,nc,Q,Q)
    W = G[..., None] * L                                               # (B,nc,Q,Q,H)
    xdt = xh * dtc[..., None]                                          # (B,nc,Q,H,P)
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", W, xdt)

    # chunk summaries: state contributed by each chunk at its end
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)                    # (B,nc,Q,H)
    S_c = jnp.einsum("bcqh,bcqhp,bcqn->bchpn", decay_to_end * dtc, xh, Bc)

    # inter-chunk scan: h_{c} = exp(sum dA_c) * h_{c-1} + S_c
    chunk_decay = jnp.exp(cum[:, :, -1, :])                            # (B,nc,H)
    if state is None:
        state = jnp.zeros((B, H, P, N), jnp.float32)

    def comb(c1, c2):
        a1, s1 = c1
        a2, s2 = c2
        return a1 * a2, s1 * a2[..., None, None] + s2

    a_scan, s_scan = jax.lax.associative_scan(
        comb, (chunk_decay.transpose(1, 0, 2),
               S_c.transpose(1, 0, 2, 3, 4)), axis=0)
    # prepend incoming state
    h_before = jnp.concatenate([
        jnp.broadcast_to(state[None], (1, B, H, P, N)),
        s_scan[:-1] + a_scan[:-1][..., None, None]
        * state[None]], axis=0)                                        # (nc,B,H,P,N)
    h_final = s_scan[-1] + a_scan[-1][..., None, None] * state

    # inter-chunk contribution: y_t += C_t . (decay_from_chunk_start_t * h_prev)
    decay_from_start = jnp.exp(cum)                                    # (B,nc,Q,H)
    y_inter = jnp.einsum("bcqn,cbhpn,bcqh->bcqhp",
                         Cc, h_before, decay_from_start)
    y = (y_intra + y_inter).reshape(B, S, H, P)
    y = y + params["d_skip"][None, None, :, None] * xh.reshape(B, S, H, P)
    y = y.reshape(B, S, inner).astype(cd)

    # gated RMSNorm + out projection
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)
         * (1.0 + params["norm_w"].astype(jnp.float32))).astype(cd)
    out = y @ params["out_proj"].astype(cd)
    out = hint(out, "batch", None, "model_d")
    if return_state:
        conv_tail = conv_in[:, -3:, :]          # decode conv window hand-off
        return out, (h_final, conv_tail)
    return out


def mamba2_step(params, x_t, state, cfg: ArchConfig, conv_buf=None):
    """Single decode step. x_t: (B,1,D); state: (B,H,P,N); conv_buf: (B,3,C)."""
    B = x_t.shape[0]
    cd = cfg.cdtype
    z, xc, Bm, Cm, dt, (inner, N, P, H) = _mamba_projections(params, x_t, cfg)
    u = jnp.concatenate([xc, Bm, Cm], axis=-1)                         # (B,1,C)
    if conv_buf is None:
        conv_buf = jnp.zeros((B, 3, u.shape[-1]), u.dtype)
    window = jnp.concatenate([conv_buf, u], axis=1)                    # (B,4,C)
    w = params["conv"].astype(cd)
    conv_out = jax.nn.silu(jnp.einsum("bwc,wc->bc", window, w))[:, None, :]
    new_buf = window[:, 1:, :]
    xc, Bm, Cm = jnp.split(conv_out, [inner, inner + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])[:, 0]  # (B,H)
    a = -jnp.exp(params["a_log"])
    dA = jnp.exp(dt * a[None, :])                                      # (B,H)
    xh = xc.reshape(B, H, P).astype(jnp.float32)
    Bv = Bm[:, 0, :].astype(jnp.float32)                               # (B,N)
    Cv = Cm[:, 0, :].astype(jnp.float32)
    state = state * dA[:, :, None, None] \
        + (dt[:, :, None] * xh)[..., None] * Bv[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", state, Cv) \
        + params["d_skip"][None, :, None] * xh
    y = y.reshape(B, 1, inner).astype(cd) * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)
         * (1.0 + params["norm_w"].astype(jnp.float32))).astype(cd)
    return y @ params["out_proj"].astype(cd), state, new_buf


# ================================== mLSTM ====================================

def init_mlstm(key, cfg: ArchConfig):
    D = cfg.d_model
    inner = cfg.ssm_expand * D
    assert inner % cfg.n_heads == 0
    hd = inner // cfg.n_heads
    ks = split_keys(key, 7)
    return {
        "up": dense_init(ks[0], (D, 2 * inner), cfg.pdtype),
        "wq": dense_init(ks[1], (inner, cfg.n_heads * hd), cfg.pdtype),
        "wk": dense_init(ks[2], (inner, cfg.n_heads * hd), cfg.pdtype),
        "wv": dense_init(ks[3], (inner, cfg.n_heads * hd), cfg.pdtype),
        "wif": dense_init(ks[4], (inner, 2 * cfg.n_heads), jnp.float32, scale=0.02),
        "if_bias": jnp.zeros((2 * cfg.n_heads,), jnp.float32),
        "norm_w": jnp.zeros((cfg.n_heads * hd,), cfg.pdtype),
        "down": dense_init(ks[5], (cfg.n_heads * hd, D), cfg.pdtype),
    }


def mlstm_seq(params, x, cfg: ArchConfig, return_state: bool = False):
    """Parallel (attention-like) stabilized mLSTM. x: (B,S,D)."""
    B, S, D = x.shape
    cd = cfg.cdtype
    inner = cfg.ssm_expand * D
    Hh = cfg.n_heads
    up = x @ params["up"].astype(cd)
    u, gate = jnp.split(up, 2, axis=-1)
    q = (u @ params["wq"].astype(cd)).reshape(B, S, Hh, -1)
    k = (u @ params["wk"].astype(cd)).reshape(B, S, Hh, -1)
    v = (u @ params["wv"].astype(cd)).reshape(B, S, Hh, -1)
    hd = q.shape[-1]
    gates = u.astype(jnp.float32) @ params["wif"] + params["if_bias"]
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)                        # (B,S,H)
    logf = jax.nn.log_sigmoid(f_pre)
    cumf = jnp.cumsum(logf, axis=1)                                    # (B,S,H)
    # a[t,s] = cumf_t - cumf_s + i_s   (s <= t)
    a = cumf[:, :, None, :] - cumf[:, None, :, :] + i_pre[:, None, :, :]
    mask = jnp.tril(jnp.ones((S, S), bool))
    a = jnp.where(mask[None, :, :, None], a, -jnp.inf)
    m = jnp.max(a, axis=2, keepdims=True)                              # (B,S,1,H)
    Dmat = jnp.exp(a - m)                                              # (B,S,S,H)
    qk = jnp.einsum("bqhd,bshd->bqsh", q.astype(jnp.float32),
                    k.astype(jnp.float32)) * hd ** -0.5
    C = qk * Dmat
    n = jnp.maximum(jnp.abs(C.sum(axis=2)), jnp.exp(-m[:, :, 0, :]))   # (B,S,H)
    y = jnp.einsum("bqsh,bshd->bqhd", C, v.astype(jnp.float32)) \
        / n[..., None]
    y = y.reshape(B, S, Hh * hd).astype(cd)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)
         * (1.0 + params["norm_w"].astype(jnp.float32))).astype(cd)
    y = y * jax.nn.silu(gate)
    out = y @ params["down"].astype(cd)
    if return_state:
        # reconstruct the recurrent state at position S-1 from the parallel
        # quantities: m_T = max_s a[T,s];  C = sum_s e^{a-m} k v^T;  n likewise
        aT = a[:, -1, :, :]                                   # (B,S,H)
        mT = m[:, -1, 0, :]                                   # (B,H)
        wgt = jnp.exp(aT - mT[:, None, :])                    # (B,S,H)
        kf = k.astype(jnp.float32)
        vf = v.astype(jnp.float32)
        Cmat = jnp.einsum("bsh,bshk,bshv->bhkv", wgt, kf, vf)
        nvec = jnp.einsum("bsh,bshk->bhk", wgt, kf)
        return out, (Cmat, nvec, mT)
    return out


def mlstm_step(params, x_t, state, cfg: ArchConfig):
    """Recurrent mLSTM step. state = (Cmat (B,H,dk,dv), n (B,H,dk), m (B,H))."""
    B = x_t.shape[0]
    cd = cfg.cdtype
    Hh = cfg.n_heads
    up = x_t @ params["up"].astype(cd)
    u, gate = jnp.split(up, 2, axis=-1)
    q = (u @ params["wq"].astype(cd)).reshape(B, Hh, -1).astype(jnp.float32)
    k = (u @ params["wk"].astype(cd)).reshape(B, Hh, -1).astype(jnp.float32)
    v = (u @ params["wv"].astype(cd)).reshape(B, Hh, -1).astype(jnp.float32)
    hd = q.shape[-1]
    gates = u[:, 0].astype(jnp.float32) @ params["wif"] + params["if_bias"]
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)                        # (B,H)
    logf = jax.nn.log_sigmoid(f_pre)
    Cm, n, m = state
    m_new = jnp.maximum(logf + m, i_pre)
    fdec = jnp.exp(logf + m - m_new)
    iamp = jnp.exp(i_pre - m_new)
    Cm = Cm * fdec[..., None, None] \
        + iamp[..., None, None] * k[:, :, :, None] * v[:, :, None, :]
    n = n * fdec[..., None] + iamp[..., None] * k
    qs = q * hd ** -0.5
    num = jnp.einsum("bhk,bhkv->bhv", qs, Cm)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qs, n)),
                      jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(B, 1, Hh * hd).astype(cd)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)
         * (1.0 + params["norm_w"].astype(jnp.float32))).astype(cd)
    y = y * jax.nn.silu(gate)
    return y @ params["down"].astype(cd), (Cm, n, m_new)


# ================================== sLSTM ====================================

def init_slstm(key, cfg: ArchConfig):
    D = cfg.d_model
    ks = split_keys(key, 3)
    return {
        "w": dense_init(ks[0], (D, 4 * D), cfg.pdtype),
        "r": dense_init(ks[1], (4, D), cfg.pdtype, scale=0.02),  # diag recurrent
        "b": jnp.zeros((4 * D,), jnp.float32),
        "down": dense_init(ks[2], (D, D), cfg.pdtype),
    }


def _slstm_cell(params, u_t, carry):
    """u_t: (B, 4D) preactivations; carry = (h, c, n, m) each (B, D)."""
    h, c, n, m = carry
    D = h.shape[-1]
    r = params["r"].astype(jnp.float32)
    rec = h[:, None, :] * r[None, :, :]                                # (B,4,D)
    pre = u_t.reshape(-1, 4, D).astype(jnp.float32) + rec \
        + params["b"].reshape(4, D)[None]
    zi, ii, fi, oi = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    m_new = jnp.maximum(jax.nn.log_sigmoid(fi) + m, ii)
    i_g = jnp.exp(ii - m_new)
    f_g = jnp.exp(jax.nn.log_sigmoid(fi) + m - m_new)
    c_new = f_g * c + i_g * jnp.tanh(zi)
    n_new = f_g * n + i_g
    h_new = jax.nn.sigmoid(oi) * c_new / jnp.maximum(n_new, 1.0)
    return h_new, c_new, n_new, m_new


def slstm_seq(params, x, cfg: ArchConfig, return_state: bool = False):
    B, S, D = x.shape
    cd = cfg.cdtype
    u = x @ params["w"].astype(cd)

    def step(carry, u_t):
        carry = _slstm_cell(params, u_t, carry)
        return carry, carry[0]

    init = (jnp.zeros((B, D), jnp.float32), jnp.zeros((B, D), jnp.float32),
            jnp.zeros((B, D), jnp.float32), jnp.full((B, D), -1e30, jnp.float32))
    final, hs = jax.lax.scan(step, init, u.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2).astype(cd)
    out = y @ params["down"].astype(cd)
    if return_state:
        return out, final
    return out


def slstm_step(params, x_t, state, cfg: ArchConfig):
    u = (x_t @ params["w"].astype(cfg.cdtype))[:, 0]
    carry = _slstm_cell(params, u, state)
    y = carry[0][:, None, :].astype(cfg.cdtype)
    return y @ params["down"].astype(cfg.cdtype), carry
