"""Model assembly: pattern-grouped, scanned layer stacks for all families.

Every assigned architecture is a sequence of blocks drawn from a small kind
vocabulary:

  attn         self-attention + dense MLP            (dense archs)
  attn_local   sliding-window self-attention + MLP   (gemma2 odd layers)
  attn_moe     self-attention + MoE FFN              (qwen3-moe, llama4)
  mamba        Mamba2 mixer block                    (zamba2 backbone)
  mlstm/slstm  xLSTM blocks                          (xlstm-125m)
  shared_attn  attention + MLP with SHARED weights   (zamba2 global block)
  cross        cross-attention + MLP                 (llama3.2-vision)
  enc_attn     bidirectional attention + MLP         (whisper encoder)
  dec_cross    self-attn + cross-attn + MLP          (whisper decoder)

The layer list is grouped into segments of a repeating pattern
(e.g. gemma2 = 21 x (attn_local, attn); zamba2 = 13 x (6 x mamba,
shared_attn) + 3 x mamba). Parameters are STACKED along the repeat axis and
the stack runs under jax.lax.scan — HLO size is O(pattern), not O(layers),
which keeps 94-layer × 512-device dry-run compiles tractable; caches are
scanned alongside as per-repeat slices. cfg.remat wraps the scan body in
jax.checkpoint for activation recomputation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import moe as moe_mod
from repro.models import ssm
from repro.models.base import ArchConfig, dense_init, split_keys
from repro.models.layers import (KVCache, attend, init_attn, init_mlp, mlp,
                                 rms_norm)
from repro.models.sharding import hint


# --------------------------- stack specification ----------------------------

def build_stack_spec(cfg: ArchConfig):
    """Return [(pattern: tuple[str], repeats: int), ...] for the decoder."""
    L = cfg.n_layers
    if cfg.family == "ssm" and cfg.ssm_kind == "xlstm":
        per = cfg.slstm_period
        if per and L >= per:
            pat = ("mlstm",) * (per - 1) + ("slstm",)
            segs = [(pat, L // per)]
            if L % per:
                segs.append((("mlstm",), L % per))
            return segs
        return [(("mlstm",), L)]
    if cfg.family == "hybrid":
        per = cfg.attn_period
        pat = ("mamba",) * per + ("shared_attn",)
        segs = [(pat, L // per)]
        if L % per:
            segs.append((("mamba",), L % per))
        return segs
    if cfg.family == "vlm" and cfg.cross_attn_period:
        per = cfg.cross_attn_period
        pat = ("attn",) * (per - 1) + ("cross",)
        segs = [(pat, L // per)]
        if L % per:
            segs.append((("attn",), L % per))
        return segs
    if cfg.enc_dec:
        return [(("dec_cross",), L)]
    kind = "attn_moe" if cfg.n_experts else "attn"
    if cfg.n_experts and cfg.moe_period > 1:
        pat = ("attn",) * (cfg.moe_period - 1) + ("attn_moe",)
        segs = [(pat, L // cfg.moe_period)]
        if L % cfg.moe_period:
            segs.append((("attn",), L % cfg.moe_period))
        return segs
    if cfg.local_global_period:
        pat = ("attn_local", "attn") * (cfg.local_global_period // 2)
        return [(pat, L // cfg.local_global_period)]
    return [((kind,), L)]


# ------------------------------ block init ----------------------------------

def init_block(key, cfg: ArchConfig, kind: str):
    ks = split_keys(key, 6)
    D = cfg.d_model
    p: dict[str, Any] = {"norm1": jnp.zeros((D,), cfg.pdtype)}
    if kind in ("attn", "attn_local", "attn_moe", "shared_attn", "enc_attn"):
        p["attn"] = init_attn(ks[0], cfg)
        p["norm2"] = jnp.zeros((D,), cfg.pdtype)
        p["ffn"] = (moe_mod.init_moe(ks[1], cfg) if kind == "attn_moe"
                    else init_mlp(ks[1], cfg))
    elif kind == "cross":
        p["attn"] = init_attn(ks[0], cfg)
        p["gate_attn"] = jnp.zeros((), jnp.float32)
        p["gate_ffn"] = jnp.zeros((), jnp.float32)
        p["norm2"] = jnp.zeros((D,), cfg.pdtype)
        p["ffn"] = init_mlp(ks[1], cfg)
    elif kind == "dec_cross":
        p["attn"] = init_attn(ks[0], cfg)
        p["norm_x"] = jnp.zeros((D,), cfg.pdtype)
        p["xattn"] = init_attn(ks[2], cfg)
        p["norm2"] = jnp.zeros((D,), cfg.pdtype)
        p["ffn"] = init_mlp(ks[1], cfg)
    elif kind == "mamba":
        p["mixer"] = ssm.init_mamba2(ks[0], cfg)
    elif kind == "mlstm":
        p["mixer"] = ssm.init_mlstm(ks[0], cfg)
    elif kind == "slstm":
        p["mixer"] = ssm.init_slstm(ks[0], cfg)
        p["norm2"] = jnp.zeros((D,), cfg.pdtype)
        p["ffn"] = init_mlp(ks[1], cfg, d_ff=max(4 * D // 3, 8))
    else:
        raise ValueError(kind)
    return p


def init_cache_for_kind(cfg: ArchConfig, kind: str, batch: int, max_len: int):
    Kv, hd = cfg.n_kv, cfg.head_dim
    cd = cfg.cdtype
    if kind in ("attn", "attn_local", "attn_moe", "shared_attn", "cross",
                "dec_cross"):
        c = KVCache(jnp.zeros((batch, max_len, Kv, hd), cd),
                    jnp.zeros((batch, max_len, Kv, hd), cd),
                    jnp.zeros((), jnp.int32))
        return c
    if kind == "mamba":
        inner = cfg.ssm_expand * cfg.d_model
        Hm = inner // 64
        conv_c = inner + 2 * cfg.ssm_state
        return (jnp.zeros((batch, Hm, 64, cfg.ssm_state), jnp.float32),
                jnp.zeros((batch, 3, conv_c), cd))
    if kind == "mlstm":
        inner = cfg.ssm_expand * cfg.d_model
        hd_m = inner // cfg.n_heads
        return (jnp.zeros((batch, cfg.n_heads, hd_m, hd_m), jnp.float32),
                jnp.zeros((batch, cfg.n_heads, hd_m), jnp.float32),
                jnp.full((batch, cfg.n_heads), -1e30, jnp.float32))
    if kind == "slstm":
        D = cfg.d_model
        return (jnp.zeros((batch, D), jnp.float32),
                jnp.zeros((batch, D), jnp.float32),
                jnp.zeros((batch, D), jnp.float32),
                jnp.full((batch, D), -1e30, jnp.float32))
    if kind == "enc_attn":
        return None
    raise ValueError(kind)


# ------------------------------ block apply ----------------------------------

def apply_block(p, x, cfg: ArchConfig, kind: str, *, positions,
                memory=None, memory_positions=None, cache=None,
                shared_params=None, decode: bool = False, pad=None):
    """Apply one block; returns (x, new_cache, aux_losses). `pad` ((B,)
    int32 left-pad lengths, ragged serving waves) reaches only the cached
    self-attention — recurrent mixers have no pad-mask equivalent, so the
    serving engine restricts ragged waves to attention-only stacks."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "shared_attn":
        p = dict(shared_params)
    if kind in ("attn", "attn_local", "attn_moe", "shared_attn", "enc_attn"):
        sw = cfg.sliding_window if kind == "attn_local" else None
        h = rms_norm(x, p["norm1"], cfg.rms_eps)
        a, cache = attend(p["attn"], h, cfg, positions=positions,
                          causal=(kind != "enc_attn"), sliding_window=sw,
                          cache=cache, pad=pad)
        x = x + a
        h = rms_norm(x, p["norm2"], cfg.rms_eps)
        if kind == "attn_moe":
            f, moe_aux = moe_mod.moe_ffn(p["ffn"], h, cfg)
            aux = aux + moe_aux["lb_loss"]
        else:
            f = mlp(p["ffn"], h, cfg)
        return x + f, cache, aux
    if kind == "cross":
        h = rms_norm(x, p["norm1"], cfg.rms_eps)
        a, _ = attend(p["attn"], h, cfg, positions=positions, kv=memory,
                      kv_positions=memory_positions, causal=False)
        x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * a
        h = rms_norm(x, p["norm2"], cfg.rms_eps)
        x = x + jnp.tanh(p["gate_ffn"]).astype(x.dtype) * mlp(p["ffn"], h, cfg)
        return x, cache, aux
    if kind == "dec_cross":
        h = rms_norm(x, p["norm1"], cfg.rms_eps)
        a, cache = attend(p["attn"], h, cfg, positions=positions, causal=True,
                          cache=cache, pad=pad)
        x = x + a
        h = rms_norm(x, p["norm_x"], cfg.rms_eps)
        a, _ = attend(p["xattn"], h, cfg, positions=positions, kv=memory,
                      kv_positions=memory_positions, causal=False)
        x = x + a
        h = rms_norm(x, p["norm2"], cfg.rms_eps)
        return x + mlp(p["ffn"], h, cfg), cache, aux
    if kind == "mamba":
        h = rms_norm(x, p["norm1"], cfg.rms_eps)
        if decode:
            state, conv_buf = cache
            y, state, conv_buf = ssm.mamba2_step(p["mixer"], h, state, cfg,
                                                 conv_buf)
            return x + y, (state, conv_buf), aux
        if cache is not None:   # prefill: produce the recurrent state
            y, cache = ssm.mamba2_seq(p["mixer"], h, cfg, return_state=True)
            return x + y, cache, aux
        return x + ssm.mamba2_seq(p["mixer"], h, cfg), cache, aux
    if kind == "mlstm":
        h = rms_norm(x, p["norm1"], cfg.rms_eps)
        if decode:
            y, cache = ssm.mlstm_step(p["mixer"], h, cache, cfg)
            return x + y, cache, aux
        if cache is not None:
            y, cache = ssm.mlstm_seq(p["mixer"], h, cfg, return_state=True)
            return x + y, cache, aux
        return x + ssm.mlstm_seq(p["mixer"], h, cfg), cache, aux
    if kind == "slstm":
        h = rms_norm(x, p["norm1"], cfg.rms_eps)
        if decode:
            y, cache = ssm.slstm_step(p["mixer"], h, cache, cfg)
        elif cache is not None:
            y, cache = ssm.slstm_seq(p["mixer"], h, cfg, return_state=True)
        else:
            y = ssm.slstm_seq(p["mixer"], h, cfg)
        x = x + y
        h = rms_norm(x, p["norm2"], cfg.rms_eps)
        return x + mlp(p["ffn"], h, cfg), cache, aux
    raise ValueError(kind)


def count_params(cfg: ArchConfig) -> int:
    """Exact parameter count via abstract init (no allocation)."""
    abs_tree = jax.eval_shape(Model(cfg).init, jax.random.PRNGKey(0))
    return int(sum(int(np_prod(l.shape)) for l in jax.tree.leaves(abs_tree)))


def np_prod(shape):
    n = 1
    for s in shape:
        n *= s
    return n


# ------------------------------- the model ----------------------------------

@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # ---------------- init ----------------
    def init(self, key):
        cfg = self.cfg
        ks = split_keys(key, 8)
        params: dict[str, Any] = {
            "embed": dense_init(ks[0], (cfg.vocab, cfg.d_model), cfg.pdtype,
                                scale=0.02),
            "final_norm": jnp.zeros((cfg.d_model,), cfg.pdtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab),
                                           cfg.pdtype)
        spec = build_stack_spec(cfg)
        segs = []
        kseg = split_keys(ks[2], len(spec))
        for (pattern, repeats), k in zip(spec, kseg):
            kpos = split_keys(k, len(pattern))
            seg_params = []
            for kind, kp in zip(pattern, kpos):
                if kind == "shared_attn":
                    seg_params.append(None)   # shared: stored once below
                    continue
                stack = jax.vmap(
                    functools.partial(init_block, cfg=cfg, kind=kind)
                )(jax.random.split(kp, repeats))
                seg_params.append(stack)
            segs.append(seg_params)
        params["stack"] = segs
        if any(kind == "shared_attn" for pat, _ in spec for kind in pat):
            params["shared_attn"] = init_block(ks[3], cfg, "shared_attn")
        if cfg.family == "vlm":
            params["vision_proj"] = dense_init(
                ks[4], (cfg.vision_dim, cfg.d_model), cfg.pdtype)
        if cfg.enc_dec:
            enc_stack = jax.vmap(
                functools.partial(init_block, cfg=cfg, kind="enc_attn")
            )(jax.random.split(ks[5], cfg.n_enc_layers))
            params["encoder"] = {
                "stack": enc_stack,
                "final_norm": jnp.zeros((cfg.d_model,), cfg.pdtype),
                "frame_proj": dense_init(ks[6], (cfg.vision_dim, cfg.d_model),
                                         cfg.pdtype),
            }
            # sized for the largest assigned decode cell (32k); the real
            # whisper caps at 448 decoder positions — see DESIGN.md
            params["pos_embed"] = dense_init(
                ks[7], (32_768, cfg.d_model), cfg.pdtype, scale=0.02)
        return params

    # ---------------- shared stack runner ----------------
    def _run_stack(self, params, x, *, positions, memory=None,
                   memory_positions=None, caches=None, decode=False,
                   pad=None):
        cfg = self.cfg
        spec = build_stack_spec(cfg)
        shared = params.get("shared_attn")
        aux_total = jnp.zeros((), jnp.float32)
        new_caches = []
        has_cache = caches is not None
        for si, (pattern, repeats) in enumerate(spec):
            seg_params = params["stack"][si]
            seg_cache = caches[si] if has_cache else None

            def body(carry, xs, pattern=pattern):
                xx, aux_acc = carry
                ps, cs = xs
                if cfg.seq_parallel_residual and not decode:
                    # Megatron-style sequence parallelism: the block-boundary
                    # residual (what remat saves) is sharded seq-over-TP,
                    # cutting saved-activation HBM by the TP degree
                    xx = hint(xx, "batch", "seq_mp", None)
                new_cs = []
                aux_step = jnp.zeros((), jnp.float32)
                for pi, kind in enumerate(pattern):
                    c_in = cs[pi] if has_cache else None
                    xx, c_out, aux = apply_block(
                        ps[pi], xx, cfg, kind,
                        positions=positions, memory=memory,
                        memory_positions=memory_positions, cache=c_in,
                        shared_params=shared, decode=decode, pad=pad)
                    aux_step = aux_step + aux
                    new_cs.append(c_out if has_cache else ())
                return (xx, aux_acc + aux_step), tuple(new_cs)

            body_fn = jax.checkpoint(body) if (cfg.remat and not decode
                                               and not has_cache) else body
            # scan needs uniform pytrees: shared params scan as empty tuples
            xs = (tuple(p if p is not None else () for p in seg_params),
                  tuple(seg_cache[pi] if has_cache else ()
                        for pi in range(len(pattern))))
            if cfg.scan_layers:
                (x, aux_total), seg_new_cache = jax.lax.scan(
                    body_fn, (x, aux_total), xs)
            else:
                # unrolled python loop (validation of the scan-corrected
                # roofline accounting; see EXPERIMENTS.md §Roofline)
                outs = []
                for r in range(repeats):
                    sl = jax.tree.map(lambda a: a[r], xs)
                    (x, aux_total), yc = body_fn((x, aux_total), sl)
                    outs.append(yc)
                seg_new_cache = jax.tree.map(
                    lambda *ys: jnp.stack(ys), *outs) if outs else ()
            new_caches.append(list(seg_new_cache))
        return x, new_caches, aux_total

    # ---------------- embedding / heads ----------------
    def _embed(self, params, tokens):
        cfg = self.cfg
        x = params["embed"].astype(cfg.cdtype)[tokens]
        if cfg.arch_id.startswith("gemma"):
            x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.cdtype)
        return hint(x, "batch", None, None)

    def _logits(self, params, x):
        cfg = self.cfg
        x = rms_norm(x, params["final_norm"], cfg.rms_eps)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"]).astype(cfg.cdtype)
        logits = x @ head
        if cfg.final_softcap:
            logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
        return hint(logits, "batch", None, "vocab")

    def _encode_memory(self, params, batch):
        """VLM / audio frontends (stubs provide precomputed embeddings)."""
        cfg = self.cfg
        if cfg.family == "vlm":
            mem = batch["patch_embeds"].astype(cfg.cdtype) \
                @ params["vision_proj"].astype(cfg.cdtype)
            return mem, jnp.arange(mem.shape[1])
        if cfg.enc_dec:
            enc = params["encoder"]
            mem = batch["frames"].astype(cfg.cdtype) \
                @ enc["frame_proj"].astype(cfg.cdtype)
            pos = jnp.arange(mem.shape[1])

            def body(xx, ps):
                out, _, _ = apply_block(ps, xx, cfg, "enc_attn", positions=pos)
                return out, ()

            body_fn = jax.checkpoint(body) if cfg.remat else body
            mem, _ = jax.lax.scan(body_fn, mem, enc["stack"])
            mem = rms_norm(mem, enc["final_norm"], cfg.rms_eps)
            return mem, pos
        return None, None

    # ---------------- public entry points ----------------
    def forward(self, params, batch):
        """Training forward: batch = {tokens (B,S), [patch_embeds|frames]}.
        Returns (logits (B,S,V), aux)."""
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = self._embed(params, tokens)
        memory, mem_pos = self._encode_memory(params, batch)
        positions = jnp.arange(S)[None, :].repeat(B, 0)
        if self.cfg.enc_dec:
            x = x + params["pos_embed"].astype(x.dtype)[None, :S, :]
        x, _, aux = self._run_stack(params, x, positions=positions,
                                    memory=memory, memory_positions=mem_pos)
        return self._logits(params, x), aux

    def init_cache(self, batch_size: int, max_len: int):
        cfg = self.cfg
        spec = build_stack_spec(cfg)
        caches = []
        for pattern, repeats in spec:
            seg = []
            for kind in pattern:
                one = init_cache_for_kind(cfg, kind, batch_size, max_len)
                stacked = jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (repeats,) + a.shape), one)
                seg.append(stacked)
            caches.append(seg)
        return caches

    def prefill(self, params, batch, caches, pad=None):
        """`pad` ((B,) int32 left-pad lengths) serves a ragged wave out of
        one batch: row b's first pad[b] tokens are padding, its logical
        positions run (-pad[b] .. S-1-pad[b]) so the real prompt is 0-based,
        and the pad cache slots are masked out downstream (layers.attend).
        pad=None is bitwise the pre-pad graph."""
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = self._embed(params, tokens)
        memory, mem_pos = self._encode_memory(params, batch)
        positions = jnp.arange(S)[None, :].repeat(B, 0)
        if pad is not None:
            positions = positions - pad[:, None]
        if self.cfg.enc_dec:
            x = x + params["pos_embed"].astype(x.dtype)[None, :S, :]
        x, caches, _ = self._run_stack(params, x, positions=positions,
                                       memory=memory,
                                       memory_positions=mem_pos,
                                       caches=caches, decode=False, pad=pad)
        return self._logits(params, x[:, -1:, :]), caches

    def decode_step(self, params, token, pos, caches, memory=None,
                    mem_pos=None, pad=None):
        """token: (B,1) int32; pos: () int32 current BUFFER position (cache
        slot). With `pad`, row b's logical position is pos - pad[b]."""
        B = token.shape[0]
        x = self._embed(params, token)
        if self.cfg.enc_dec:
            x = x + jax.lax.dynamic_slice_in_dim(
                params["pos_embed"].astype(x.dtype), pos, 1, 0)[None]
        positions = jnp.full((B, 1), pos, jnp.int32)
        if pad is not None:
            positions = positions - pad[:, None]
        x, caches, _ = self._run_stack(params, x, positions=positions,
                                       memory=memory, memory_positions=mem_pos,
                                       caches=caches, decode=True, pad=pad)
        return self._logits(params, x), caches
