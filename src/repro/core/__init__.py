"""BCPNN core — the paper's primary contribution in JAX.

Public API:
  BCPNNParams / human_scale / rodent_scale / test_scale  — model dimensioning
  HCUState, init_hcu_state, hcu_tick_pre, column_update, flush — HCU semantics
  NetworkState, init_network, make_connectivity, network_tick — networks
  network_run / stage_external — scan-compiled tick runtime (run = host loop)
  traces — closed-form lazy ZEP trace algebra
  RowMergeLayout — BCPNN-specific synaptic data organization
  worklist — flat-plane in-place worklist update primitives (O(touched rows)
             per tick at rodent/human scales; `worklist=` on the tick
             drivers forces the path on/off, `hcu.use_worklist` is the
             size guard)
"""
from repro.core.params import BCPNNParams, human_scale, rodent_scale, test_scale
from repro.core.hcu import (HCUState, init_hcu_state, hcu_tick_pre,
                            column_update, row_updates, periodic_update,
                            flush, dedup_rows)
from repro.core.network import (NetworkState, Connectivity, init_network,
                                make_connectivity, network_tick, network_run,
                                stage_external, run, enqueue_spikes,
                                column_updates_batched)
from repro.core.layout import RowMergeLayout
from repro.core import traces, queues, worklist

__all__ = [
    "BCPNNParams", "human_scale", "rodent_scale", "test_scale",
    "HCUState", "init_hcu_state", "hcu_tick_pre", "column_update",
    "row_updates", "periodic_update", "flush", "dedup_rows",
    "NetworkState", "Connectivity", "init_network", "make_connectivity",
    "network_tick", "network_run", "stage_external", "run",
    "enqueue_spikes", "column_updates_batched",
    "RowMergeLayout", "traces", "queues", "worklist",
]
