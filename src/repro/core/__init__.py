"""BCPNN core — the paper's primary contribution in JAX.

Architecture (PR 3, the unified TickEngine):

    Simulator  (engine.py — init / run / run_sharded / save / load facade)
        |
    drivers    network_tick / network_run (network.py, local)
               make_dist_tick / make_dist_run (distributed.py, shard_map +
               all_to_all spike exchange — spike pack/route only)
        |
    engine.tick   — THE single tick body: consume delay bucket -> plane
        |           update -> spike fanout; identical RNG stream everywhere
    TickBackend   — pluggable plane update, selected by `select_backend`:
        |-- DenseBackend     per-HCU vmap on the batched (H, R, C) view
        |                    (modes: lazy / eager golden / merged)
        `-- WorklistBackend  network-global worklist over the CANONICAL FLAT
                             (H*R, C) planes, in-place ds/dus loops (CPU) or
                             the scalar-prefetch Pallas kernel (TPU)

State is STORED flat (`layout.flat_state` layout): ij planes (H*R, C),
i-vectors (H*R,), j-vectors (H, C). `hcu_view(state)` gives the batched
view for per-HCU vmapped consumers (e.g. `flush`). All backend/mode/driver
combinations produce bitwise-identical trajectories — the eBrainII property
that every BCU runs the *same* update fabric, only the layout/parallelism
changes.

Public API:
  BCPNNParams / human_scale / rodent_scale / test_scale  — model dimensioning
  Simulator                                   — end-to-end facade (engine.py)
  TickBackend, DenseBackend, WorklistBackend, select_backend — the engine
  HCUState, init_hcu_state, hcu_tick_pre, column_update, flush — HCU semantics
  NetworkState, init_network, make_connectivity, network_tick, hcu_view
  network_run / stage_external — scan-compiled tick runtime (run = host loop)
  stack_sessions / write_sessions / take_session — session-lane batching
             (leading (S,) dim over NetworkState for the continuous-batching
             recall server, repro.launch.serve_bcpnn)
  traces — closed-form lazy ZEP trace algebra
  RowMergeLayout / FlatLayout / BlockedLayout — synaptic data organization
             (plane storage order is pluggable: `layout=` on Simulator and
             the tick drivers selects flat row-major or Row-Merge
             column-blocked tiles; trajectories are layout-invariant)
  worklist — flat-plane in-place worklist update primitives (O(touched rows)
             per tick at rodent/human scales; `worklist=` on the tick
             drivers forces the backend, `hcu.use_worklist` is the guard)
"""
from repro.core.params import BCPNNParams, human_scale, rodent_scale, test_scale
from repro.core.hcu import (HCUState, init_hcu_state, init_hcu_batch,
                            hcu_tick_pre, column_update, row_updates,
                            periodic_update, flush, dedup_rows)
from repro.core.network import (NetworkState, Connectivity, init_network,
                                make_connectivity, network_tick, network_run,
                                stage_external, run, enqueue_spikes,
                                hcu_view, select_fired, stack_sessions,
                                write_sessions, take_session)
from repro.core.layout import (RowMergeLayout, FlatLayout, BlockedLayout,
                               batched_state, flat_state)
from repro.core.engine import (Simulator, TickBackend, DenseBackend,
                               WorklistBackend, select_backend,
                               column_updates_batched)
from repro.core import traces, queues, worklist

__all__ = [
    "BCPNNParams", "human_scale", "rodent_scale", "test_scale",
    "Simulator", "TickBackend", "DenseBackend", "WorklistBackend",
    "select_backend",
    "HCUState", "init_hcu_state", "init_hcu_batch", "hcu_tick_pre",
    "column_update", "row_updates", "periodic_update", "flush", "dedup_rows",
    "NetworkState", "Connectivity", "init_network", "make_connectivity",
    "network_tick", "network_run", "stage_external", "run",
    "enqueue_spikes", "hcu_view", "select_fired", "column_updates_batched",
    "stack_sessions", "write_sessions", "take_session",
    "RowMergeLayout", "FlatLayout", "BlockedLayout", "batched_state",
    "flat_state", "traces", "queues", "worklist",
]
