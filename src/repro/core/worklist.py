"""Worklist-driven in-place plane updates: the O(touched rows) tick core.

The paper's lazy model guarantees that per-tick synaptic traffic scales with
*spikes*, not synapses (§VI.D) — 36 row updates + 1 column update per HCU per
ms, never the whole (R, C) matrix. The scan-compiled runtime of PR 1 broke
that guarantee on the implementation side: every per-HCU vmapped
gather->update->scatter made XLA materialize a copy of the full scan-carried
`(H, R, C)` plane per scatter (XLA:CPU cannot alias a scatter whose operand
has other uses), so per-tick memory traffic was O(planes).

This module restores the paper's property with a network-global *worklist*
over the flat `(H*R, C)` planes (`repro.core.layout`) — which, since the
TickEngine refactor, are the CANONICAL STORED layout of `NetworkState.hcus`
(no per-tick flatten/unflatten: the scan carry is the flat layout itself,
consumed by `engine.WorklistBackend`):

  * one deduplicated `(cap_total,)` worklist of global row indices is built
    per tick (`build_worklist`), compacted valid-first exactly the way
    `cap_fire` compacts fired columns;
  * plane reads/writes happen ONLY through `lax.dynamic_slice` /
    `lax.dynamic_update_slice` inside `while_loop` bodies, the one access
    pattern XLA buffer assignment keeps in place on a scan carry (measured:
    a fancy gather next to a loop forces full-plane copies; ds/dus loops do
    not), and the loops early-exit at the valid-entry count — traffic and
    trip count are O(touched rows);
  * the trace math itself is NOT reimplemented here. Two loop forms exist:
    the FUSED form (`fused_stage_compute` + `write_rows`, the lazy default
    since PR 4) inlines the engine-supplied row math into the staging loop
    and computes ONLY the nv valid entries; the three-phase form
    (`read_rows` -> vmapped compute -> `write_rows`) stages touched rows
    into dense h-major buffers and runs the *identical* vmapped compute
    graph the per-HCU path runs over every slot. Both are bitwise-identical
    to the dense path where pinned — but NOT automatically: XLA:CPU codegen
    (exp lowering, FMA contraction) is context-sensitive at the 1-ulp
    level, which is why the merged mode keeps the three-phase form (see
    docs/NUMERICS.md for the measured FMA case). A further hard rule: a
    loop body must access each carried buffer in ONE direction only —
    read-only or write-only. A body that both dynamic-slices and
    dynamic-update-slices the same carried plane forces XLA:CPU to copy the
    full plane PER ITERATION (measured ~200x at rodent16), which is why the
    writeback is a separate loop rather than folded into the compute loop.

On TPU the same worklist drives the scalar-prefetch Pallas kernel
(`repro.kernels.bcpnn_update.worklist_update_kernel_call`), whose grid
iterates worklist entries and DMAs only the touched `(1, C)` row blocks,
aliased in place. `repro.core.engine` orchestrates both (size-guarded like
`hcu.DENSE_CELLS_MAX`, see `hcu.use_worklist`); this module holds the
backend-independent loop primitives.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.layout import FlatLayout, as_blocked, global_row


def _lay(layout, n_rows: int | None = None):
    """Resolve the per-call PlaneLayout: the flat accessors (exactly the
    historical inline dynamic-slice expressions — flat graphs are unchanged
    by the seam) unless a BlockedLayout is passed."""
    return as_blocked(layout) or FlatLayout(rows=n_rows)


def build_worklist(rows_u: jnp.ndarray, n_rows: int):
    """Build the network-global worklist from per-HCU deduped row slots.

    rows_u: (H, A) per-HCU deduplicated row indices (padding == n_rows).
    Returns (g_row, order, nv):
      g_row (H*A,) int32 — global flat row index h*R + r per slot, h-major
                           slot order; padding slots == H*R (sentinel);
      order (H*A,) int32 — stable compaction permutation, valid slots first
                           (same idiom as network.select_fired);
      nv    ()     int32 — number of valid entries (= loop trip count).

    Rows are already unique network-wide: `dedup_rows` dedups within each
    HCU and rows of different HCUs map to disjoint global indices.
    """
    n_hcu, A = rows_u.shape
    valid = rows_u < n_rows
    g = jnp.where(valid,
                  global_row(jnp.arange(n_hcu, dtype=jnp.int32)[:, None],
                             rows_u, n_rows),
                  n_hcu * n_rows)
    order, nv = compact_mask(valid.reshape(-1))
    return g.reshape(-1).astype(jnp.int32), order, nv


def compact_mask(mask: jnp.ndarray):
    """Stable valid-first compaction of a boolean mask WITHOUT a sort.

    Returns (order, count): order (N,) int32 with order[e] = index of the
    (e+1)-th True entry for e < count (padding positions hold 0, never read
    by the early-exiting loops). True entry i lands at position
    cumsum(mask)[i] - 1 — a scatter, not an argsort: XLA:CPU's sort has
    shown compilation-context-sensitive miscompilation next to the in-place
    while-loop machinery, and a prefix sum is cheaper anyway.
    """
    N = mask.shape[0]
    pos = jnp.cumsum(mask) - 1
    order = jnp.zeros((N,), jnp.int32).at[
        jnp.where(mask, pos, N)].set(jnp.arange(N, dtype=jnp.int32),
                                     mode="drop")
    return order, jnp.sum(mask).astype(jnp.int32)


# ----------------------------- row worklist ---------------------------------

def read_rows(flats, g_row, order, nv, layout=None):
    """Stage worklist rows into dense h-major (H*A, C) buffers.

    flats: tuple of stored planes in `layout`'s order — flat (H*R, C) by
    default (read-only here). For each valid worklist entry
    (slot = order[e], e < nv), buffer position `slot` receives the logical
    plane row `g_row[slot]`; padding slots stay zero (their values feed only
    computations whose results are dropped or zero-masked). One
    dynamic_slice per plane per entry — no fancy gather, so the planes stay
    in-place-aliasable for the write loop.
    """
    lay = _lay(layout)
    C = lay.cols if as_blocked(layout) else flats[0].shape[1]
    cap_total = g_row.shape[0]
    bufs = tuple(jnp.zeros((cap_total, C), f.dtype) for f in flats)

    def body(s):
        e, bufs = s
        slot = order[e]
        r = g_row[slot]
        bufs = tuple(
            jax.lax.dynamic_update_slice(b, lay.read_row(f, r), (slot, 0))
            for b, f in zip(bufs, flats))
        return e + 1, bufs

    return jax.lax.while_loop(lambda s: s[0] < nv, body,
                              (jnp.asarray(0, jnp.int32), bufs))[1]


def write_rows(flats, ivecs, g_row, order, nv, vals, iv_vals, now,
               layout=None):
    """Write the row worklist back in place.

    flats:  (zij, eij, pij, wij, tij) stored planes (flat (H*R, C) default);
    ivecs:  (zi, ei, pi, ti) flat (H*R,) i-vectors (layout-independent);
    vals:   (z1, e1, p1, w1) h-major (H*A, C) value buffers;
    iv_vals:(zi', ei', pi') h-major (H*A,) i-vector values.
    Entry e < nv rewrites the logical plane row g_row[order[e]] from value
    slot order[e] and its i-vector cell; Tij/ti are stamped to `now`. Every
    write is a dynamic_update_slice on a while_loop carry — the in-place
    pattern — and only touched rows are visited (the per-HCU path's
    `mode="drop"` scatters wrote exactly this set).
    """
    lay = _lay(layout)
    C = lay.cols if as_blocked(layout) else flats[0].shape[1]

    def body(s):
        e, flats, ivecs = s
        slot = order[e]
        r = g_row[slot]
        row = lambda v: jax.lax.dynamic_slice(v, (slot, 0), (1, C))
        zf, ef, pf, wf, tf = flats
        vz, ve, vp, vw = vals
        zf = lay.write_row(zf, r, row(vz))
        ef = lay.write_row(ef, r, row(ve))
        pf = lay.write_row(pf, r, row(vp))
        wf = lay.write_row(wf, r, row(vw))
        tf = lay.stamp_row(tf, r, now)
        one = lambda v: jax.lax.dynamic_slice(v, (slot,), (1,))
        zv, ev, pv, tv = ivecs
        zv = jax.lax.dynamic_update_slice(zv, one(iv_vals[0]), (r,))
        ev = jax.lax.dynamic_update_slice(ev, one(iv_vals[1]), (r,))
        pv = jax.lax.dynamic_update_slice(pv, one(iv_vals[2]), (r,))
        tv = jax.lax.dynamic_update_slice(
            tv, jnp.full((1,), now, tv.dtype), (r,))
        return e + 1, (zf, ef, pf, wf, tf), (zv, ev, pv, tv)

    out = jax.lax.while_loop(lambda s: s[0] < nv, body,
                             (jnp.asarray(0, jnp.int32), flats, ivecs))
    return out[1], out[2]


def fused_stage_compute(flats, g_row, order, nv, row_math, layout=None):
    """Fused stage+compute pass: one loop that reads each touched row and
    runs the row math on it IN THE SAME ITERATION, writing the results to
    compact h-major value buffers.

    Replaces the first two of the three phases (`read_rows` staging +
    vmapped compute): the old form staged every slot and then computed the
    WHOLE (cap_total, C) buffer — padding slots included — where this loop
    computes exactly the nv valid entries. The writeback stays the separate
    `write_rows` loop: XLA:CPU keeps a while-loop carry in place only when
    each carried buffer is accessed in ONE direction per loop (read-only or
    write-only); a body that dynamic-slices and dynamic-update-slices the
    same carried plane forces a full-plane copy PER ITERATION (measured:
    ~200x slower at rodent16 — see docs/NUMERICS.md). Here the planes are
    read-only and the value buffers write-only, so everything stays in
    place.

      flats:    (zij, eij, pij, tij) flat (H*R, C) planes (read-only; note
                Wij is not needed — it is recomputed);
      row_math: row_math(slot, z, e, p, t) -> (z1, e1, p1, w1) on (1, C)
                blocks — MUST be the same cell formulas the vmapped compute
                runs (the engine passes closures over `bcpnn_ref` math;
                bitwise identity across the block-shape change is pinned by
                tests/test_worklist.py and the head fixtures);

    Returns (z1, e1, p1, w1) value buffers, each (cap_total, C) h-major,
    zeros at padding slots (their WTA drive terms are zero-count, and
    `write_rows` never reads them).
    """
    lay = _lay(layout)
    C = lay.cols if as_blocked(layout) else flats[0].shape[1]
    cap_total = g_row.shape[0]
    vals = tuple(jnp.zeros((cap_total, C), jnp.float32) for _ in range(4))
    dus = jax.lax.dynamic_update_slice

    def body(s):
        e, vals = s
        slot = order[e]
        r = g_row[slot]
        ds = lambda f: lay.read_row(f, r)
        z1, e1, p1, w1 = row_math(slot, ds(flats[0]), ds(flats[1]),
                                  ds(flats[2]), ds(flats[3]))
        vals = (dus(vals[0], z1, (slot, 0)), dus(vals[1], e1, (slot, 0)),
                dus(vals[2], p1, (slot, 0)), dus(vals[3], w1, (slot, 0)))
        return e + 1, vals

    return jax.lax.while_loop(lambda s: s[0] < nv, body,
                              (jnp.asarray(0, jnp.int32), vals))[1]


# ----------------------------- column worklist -------------------------------

def fused_col_stage_compute(flats, h_idx, j_idx, n_fired, n_rows: int,
                            col_math, layout=None):
    """Fused column stage+compute pass: one loop that reads each fired
    (R, 1) column block and runs the column math on it IN THE SAME
    ITERATION, writing the results to compact (K, R) value buffers.

    The column twin of `fused_stage_compute` (the PR 4 row recipe): it
    replaces the first two of the three column phases (`read_cols` staging +
    vmapped compute) — the old form staged every fired-batch slot and then
    computed the WHOLE (K, R) buffer, padding slots included, where this
    loop computes exactly the n_fired valid entries. The writeback stays the
    separate `write_cols` loop, per the one-direction loop rule
    (docs/NUMERICS.md): here the planes are read-only and the value buffers
    write-only, so everything stays in place.

      flats:    (zij, eij, pij, tij) flat (H*R, C) planes (read-only; Wij
                is not needed — it is recomputed);
      h_idx/j_idx: (K,) compacted fired batch (valid prefix of length
                n_fired, as produced by network.select_fired);
      col_math: col_math(e, z, ee, pp, tt) -> (z1, e1, p1, w1) on (R,)
                columns — MUST be the same cell formulas the vmapped
                compute runs (the engine passes closures over `bcpnn_ref`
                math; bitwise identity across the block-shape change is
                pinned by tests/test_worklist.py and the head fixtures).

    Returns (z1, e1, p1, w1) value buffers, each (K, R), zeros at padding
    slots (`write_cols` never reads them).
    """
    lay = _lay(layout, n_rows)
    K = h_idx.shape[0]
    vals = tuple(jnp.zeros((K, n_rows), jnp.float32) for _ in range(4))
    dus = jax.lax.dynamic_update_slice

    def body(s):
        e, vals = s
        ds = lambda f: lay.read_col(f, h_idx[e], j_idx[e])
        z1, e1, p1, w1 = col_math(e, ds(flats[0]), ds(flats[1]),
                                  ds(flats[2]), ds(flats[3]))
        vals = tuple(dus(v, val.reshape(1, n_rows), (e, 0))
                     for v, val in zip(vals, (z1, e1, p1, w1)))
        return e + 1, vals

    return jax.lax.while_loop(lambda s: s[0] < n_fired, body,
                              (jnp.asarray(0, jnp.int32), vals))[1]


def read_cols(flats, h_idx, j_idx, n_fired, n_rows: int, layout=None):
    """Stage fired columns into compact (K, R) buffers.

    h_idx/j_idx: (K,) compacted fired batch (valid prefix of length n_fired,
    as produced by network.select_fired). In the flat plane, HCU h's column
    j is the (R, 1) block at (h*R, j) — one dynamic_slice each; the blocked
    layout reads the Tr (xr, 1) tile fragments instead (`layout.read_col`).
    """
    lay = _lay(layout, n_rows)
    K = h_idx.shape[0]
    bufs = tuple(jnp.zeros((K, n_rows), f.dtype) for f in flats)

    def body(s):
        e, bufs = s
        bufs = tuple(
            jax.lax.dynamic_update_slice(
                b, lay.read_col(f, h_idx[e], j_idx[e]).reshape(1, n_rows),
                (e, 0))
            for b, f in zip(bufs, flats))
        return e + 1, bufs

    return jax.lax.while_loop(lambda s: s[0] < n_fired, body,
                              (jnp.asarray(0, jnp.int32), bufs))[1]


def write_cols(flats, h_idx, j_idx, n_fired, vals, now, n_rows: int,
               layout=None):
    """Write updated columns back in place ((R, 1) blocks; Tij stamped)."""
    lay = _lay(layout, n_rows)

    def body(s):
        e, flats = s
        h, j = h_idx[e], j_idx[e]
        col = lambda v: jax.lax.dynamic_slice(v, (e, 0), (1, n_rows))
        zf, ef, pf, wf, tf = flats
        zf = lay.write_col(zf, h, j, col(vals[0]))
        ef = lay.write_col(ef, h, j, col(vals[1]))
        pf = lay.write_col(pf, h, j, col(vals[2]))
        wf = lay.write_col(wf, h, j, col(vals[3]))
        tf = lay.stamp_col(tf, h, j, now)
        return e + 1, (zf, ef, pf, wf, tf)

    return jax.lax.while_loop(lambda s: s[0] < n_fired, body,
                              (jnp.asarray(0, jnp.int32), flats))[1]


def patch_cells(zf, pa_idx, n_patch, rows_u, ziv, fired, n_rows: int,
                layout=None):
    """Merged-mode same-tick patch: add Zi(now) to cell (row, fired_j) for
    every row touched THIS tick in every fired (non-overflow) HCU, in place.

    pa_idx: (H,) compacted HCU indices (valid prefix n_patch); rows_u (H, A)
    this tick's deduped rows; ziv (H, A) post-increment Zi values. Mirrors
    `merged.hcu_tick_merged`'s `zij.at[rows_u, safe_j].add(...)` — unique
    rows, so add order is immaterial; padding rows are skipped exactly where
    `mode="drop"` dropped them.
    """
    lay = _lay(layout, n_rows)
    A = rows_u.shape[1]

    def body(s):
        e, zf = s
        h = pa_idx[e]
        j = jnp.maximum(fired[h], 0)

        def inner(a, zf):
            r = rows_u[h, a]
            add = lambda zf: lay.add_cell(zf, h, r, j, ziv[h, a])
            return jax.lax.cond(r < n_rows, add, lambda z: z, zf)

        return e + 1, jax.lax.fori_loop(0, A, inner, zf)

    return jax.lax.while_loop(lambda s: s[0] < n_patch, body,
                              (jnp.asarray(0, jnp.int32), zf))[1]
