"""eBrainIII-style merged column updates (paper §IX future work, item 2):

    "The BCPNN algorithm has been tweaked to eliminate the column updates
     and merge them with row updates."

This module implements that tweak EXACTLY (up to a bounded ring depth) and
validates it against the eager golden model:

On an output spike at MCU j (time t_j) the only per-cell state change is
    Zij += Zi(t_j)                      (then ordinary decay)
Since Zi decays deterministically between row-i touches, a later row update
at time t can reconstruct every missed j-spike contribution from the spike
TIME alone:
    Zi(t_j) = Zi(Tij) * exp(-(t_j - Tij)/tau_zi)
and the E/P cascade is integrated piecewise (decay to t_j, bump Z, decay on)
using the same closed form — the semigroup property makes the composition
exact. Each HCU therefore keeps only a per-column ring of the last M output
spike times (C x M int32 ~ 100x4 B — vs the 10,000-cell column write it
replaces); ring overflow truncates spikes older than the M most recent,
whose residual influence decays as exp(-dt/tau_z') (~e^-8 after 20 ms).

Effect on the worst-case ms budget (paper EQ2): the column term (R cells)
disappears —
    cells: 36*C + R = 13,600  ->  36*C = 3,600   (3.8x, human scale)
which is precisely the "dramatically lower ... requirements" the paper
projects for eBrainIII. Quantified in benchmarks and EXPERIMENTS.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hcu as H
from repro.core.params import BCPNNParams
from repro.core.traces import ZEP, bayesian_weight, decay_zep

RING_DEPTH = 8
RING_EMPTY = -(10 ** 6)


def init_ring(p: BCPNNParams):
    """Per-column output-spike time ring, oldest-first (kept sorted by
    construction: times are pushed in increasing order)."""
    return jnp.full((p.cols, RING_DEPTH), RING_EMPTY, jnp.int32)


def push_ring(ring, j, t):
    """Record output spike (column j, time t); masked no-op for j < 0."""
    active = j >= 0
    safe_j = jnp.maximum(j, 0)
    row = ring[safe_j]
    new_row = jnp.concatenate([row[1:], jnp.asarray([t], jnp.int32)])
    row = jnp.where(active, new_row, row)
    return ring.at[safe_j].set(row)


def row_updates_merged(st: H.HCUState, ring, rows, now, p: BCPNNParams,
                       touch_only: bool = False):
    """Row updates with deferred (merged) column contributions.

    Identical signature/semantics to hcu.row_updates, but each cell's lazy
    decay is integrated piecewise across the output-spike times recorded in
    `ring`, injecting Zi(t_j) bumps where a column update would have.
    touch_only=True decays/reconstructs without injecting input spikes
    (used by flush_merged). Returns (state', w_rows, counts, rows_u).
    """
    R = p.rows
    kij, ki = H.coeffs_ij(p), H.coeffs_i(p)
    rows_u, counts = H.dedup_rows(rows, R)
    if touch_only:
        counts = jnp.zeros_like(counts)
    safe = jnp.minimum(rows_u, R - 1)

    # --- i-vector lazy decay + spike increment ------------------------------
    zi_g, ei_g, pi_g, ti_g = (st.zi[safe], st.ei[safe], st.pi[safe],
                              st.ti[safe])
    d_i = (now - ti_g).astype(zi_g.dtype)
    zep_i = decay_zep(ZEP(zi_g, ei_g, pi_g), d_i, ki)
    zi_new = zep_i.z + counts

    # --- ij cells: piecewise decay across ring spike times ------------------
    g = lambda plane: plane[safe]                       # (A, C)
    z, e, pp = g(st.zij), g(st.eij), g(st.pij)
    t0 = g(st.tij)                                      # (A, C) int32
    t0f = t0.astype(jnp.float32)
    nowf = jnp.asarray(now, jnp.float32)
    b_prev = t0f
    zep = ZEP(z, e, pp)
    for m in range(RING_DEPTH):                         # oldest -> newest
        tm = ring[:, m].astype(jnp.float32)[None, :]    # (1, C) -> bcast
        b = jnp.clip(tm, t0f, nowf)                     # segment boundary
        zep = decay_zep(zep, b - b_prev, kij)
        bump = (tm > t0f) & (tm <= nowf)
        # Zi at the spike time, from the i-vector value at its last stamp
        zi_at = zi_g[:, None] * jnp.exp(
            -(tm - ti_g.astype(jnp.float32)[:, None]) * (1.0 / p.tau_zi))
        zep = ZEP(zep.z + jnp.where(bump, zi_at, 0.0), zep.e, zep.p)
        b_prev = b
    zep = decay_zep(zep, nowf - b_prev, kij)            # tail segment

    # --- own (row) spike increment + Bayesian weight ------------------------
    z1 = zep.z + counts[:, None] * st.zj[None, :]
    w1 = bayesian_weight(zep.p, zep_i.p[:, None], st.pj[None, :], p.eps)

    st = H.write_rows(st, rows_u, now, p, z1, zep.e, zep.p, w1,
                      zi_new, zep_i.e, zep_i.p)
    return st, w1, counts, rows_u


def column_flush_merged(st: H.HCUState, ring, j, now, apply_fire,
                        p: BCPNNParams) -> H.HCUState:
    """Bring column j fully current: piecewise-integrate its pending ring
    spikes into all R cells, optionally apply a fire happening at `now`,
    and stamp the column. Used when the ring would overflow — so the
    classic column write happens once per RING_DEPTH fires, not per fire
    (the eBrainIII amortization), and the mode stays EXACT."""
    kij, ki = H.coeffs_ij(p), H.coeffs_i(p)
    # last-axis gather/scatter: no (R, C) transpose materialization
    gcol = lambda plane: jax.lax.dynamic_index_in_dim(plane, j, 1, False)
    z, e, pp = gcol(st.zij), gcol(st.eij), gcol(st.pij)     # (R,)
    t0f = gcol(st.tij).astype(jnp.float32)
    tif = st.ti.astype(jnp.float32)
    nowf = jnp.asarray(now, jnp.float32)
    zep = ZEP(z, e, pp)
    b_prev = t0f
    for m in range(RING_DEPTH):
        tm = ring[j, m].astype(jnp.float32)
        b = jnp.clip(tm, t0f, nowf)
        zep = decay_zep(zep, b - b_prev, kij)
        bump = (tm > t0f) & (tm <= nowf)
        zi_at = st.zi * jnp.exp(-(tm - tif) * (1.0 / p.tau_zi))
        zep = ZEP(zep.z + jnp.where(bump, zi_at, 0.0), zep.e, zep.p)
        b_prev = b
    zep = decay_zep(zep, nowf - b_prev, kij)
    # the fire at `now` itself (Zi(now) from the lazily-decayed i-vector)
    zi_now = st.zi * jnp.exp(-(nowf - tif) * (1.0 / p.tau_zi))
    z1 = zep.z + jnp.where(apply_fire, zi_now, 0.0)
    pi_now = decay_zep(ZEP(st.zi, st.ei, st.pi),
                       (nowf - tif), ki).p
    w1 = bayesian_weight(zep.p, pi_now, st.pj[j], p.eps)

    def put(plane, val):
        old = jax.lax.dynamic_index_in_dim(plane, j, 1, False)
        new = jnp.where(apply_fire, val, old)
        return plane.at[:, j].set(new)

    return st._replace(
        zij=put(st.zij, z1), eij=put(st.eij, zep.e), pij=put(st.pij, zep.p),
        wij=put(st.wij, w1),
        tij=put(st.tij.astype(jnp.float32),
                jnp.full_like(t0f, now)).astype(jnp.int32))


def hcu_tick_merged(st: H.HCUState, ring, rows, now, key, p: BCPNNParams):
    """One merged-mode HCU tick: j-vec decay, merged row updates, WTA, and
    (instead of a column update) a ring push + Zj bump for the fired MCU.

    Two consistency mechanisms (both validated vs the golden model):
      * same-tick patch: rows updated THIS tick are stamped Tij == now, so
        the strict `t_spike > Tij` ledger can't credit them a fire also at
        `now` — those A<=36 cells are patched directly;
      * overflow flush: when the fired column's ring is full, the column is
        flushed classically (with the current fire applied) and its ring
        cleared — one column write per RING_DEPTH fires instead of per fire,
        keeping the mode exact under any firing pattern."""
    st = H._decay_jvec(st, p)
    st, w_rows, counts, rows_u = row_updates_merged(st, ring, rows, now, p)
    st, fired_j = H.periodic_update(st, w_rows, counts, now, key, p)
    active = fired_j >= 0
    safe_j = jnp.maximum(fired_j, 0)
    overflow = active & (ring[safe_j, 0] != RING_EMPTY)

    # overflow path: classic (amortized) column flush, fire applied, no push
    st = column_flush_merged(st, ring, safe_j, now, overflow, p)
    ring = ring.at[safe_j].set(
        jnp.where(overflow, jnp.full((RING_DEPTH,), RING_EMPTY, jnp.int32),
                  ring[safe_j]))

    # normal path: defer via ring; patch only this tick's touched rows
    ziv = st.zi[jnp.minimum(rows_u, p.rows - 1)]      # post-increment Zi(now)
    st = st._replace(zij=st.zij.at[rows_u, safe_j].add(
        jnp.where(active & ~overflow, ziv, 0.0), mode="drop"))
    ring = push_ring(ring, jnp.where(overflow, -1, fired_j), now)

    zj = st.zj.at[safe_j].add(jnp.where(active, 1.0, 0.0))
    return st._replace(zj=zj), ring, fired_j


def flush_merged(st: H.HCUState, ring, now, p: BCPNNParams):
    """Bring every cell current (ring contributions applied): touch all rows
    with zero counts, then recompute W (comparable to hcu.flush output)."""
    R = p.rows
    n_batches = -(-R // 64)
    for b in range(n_batches):
        rows = jnp.arange(b * 64, min((b + 1) * 64, R), dtype=jnp.int32)
        rows = jnp.pad(rows, (0, 64 - rows.shape[0]), constant_values=R)
        st, _, _, _ = row_updates_merged(st, ring, rows, now, p,
                                         touch_only=True)
    return st


def worst_case_cells_merged(p: BCPNNParams) -> dict:
    """EQ2 with merged columns: the R-cell column term disappears."""
    classic = p.active_queue * p.cols + p.rows
    merged = p.active_queue * p.cols
    return {"classic_cells": classic, "merged_cells": merged,
            "reduction": classic / merged}
