"""eBrainIII-style merged column updates (paper §IX future work, item 2):

    "The BCPNN algorithm has been tweaked to eliminate the column updates
     and merge them with row updates."

This module implements that tweak EXACTLY (up to a bounded ring depth) and
validates it against the eager golden model:

On an output spike at MCU j (time t_j) the only per-cell state change is
    Zij += Zi(t_j)                      (then ordinary decay)
Since Zi decays deterministically between row-i touches, a later row update
at time t can reconstruct every missed j-spike contribution from the spike
TIME alone:
    Zi(t_j) = Zi(Tij) * exp(-(t_j - Tij)/tau_zi)
and the E/P cascade is integrated piecewise (decay to t_j, bump Z, decay on)
using the same closed form — the semigroup property makes the composition
exact. Each HCU therefore keeps only a per-column ring of the last M output
spike times (C x M int32 ~ 100x4 B — vs the 10,000-cell column write it
replaces); ring overflow truncates spikes older than the M most recent,
whose residual influence decays as exp(-dt/tau_z') (~e^-8 after 20 ms).

Effect on the worst-case ms budget (paper EQ2): the column term (R cells)
disappears —
    cells: 36*C + R = 13,600  ->  36*C = 3,600   (3.8x, human scale)
which is precisely the "dramatically lower ... requirements" the paper
projects for eBrainIII. Quantified in benchmarks and EXPERIMENTS.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hcu as H
from repro.core.params import BCPNNParams
from repro.core.traces import ZEP, bayesian_weight, decay_zep

RING_DEPTH = 8
RING_EMPTY = -(10 ** 6)


def init_ring(p: BCPNNParams):
    """Per-column output-spike time ring, oldest-first (kept sorted by
    construction: times are pushed in increasing order)."""
    return jnp.full((p.cols, RING_DEPTH), RING_EMPTY, jnp.int32)


def push_ring(ring, j, t):
    """Record output spike (column j, time t); masked no-op for j < 0."""
    active = j >= 0
    safe_j = jnp.maximum(j, 0)
    row = ring[safe_j]
    new_row = jnp.concatenate([row[1:], jnp.asarray([t], jnp.int32)])
    row = jnp.where(active, new_row, row)
    return ring.at[safe_j].set(row)


def merged_row_math(z, e, pp, t0, ring, zi_g, ti_g, counts, zj, pi_dec, pj,
                    now, p: BCPNNParams):
    """Merged (A, C)-block row update: piecewise ring integration + spike
    increment + Bayesian weight. Returns (z1, e1, p1, w1).

    The single compute graph shared by the per-HCU vmap path
    (`row_updates_merged`) and the flat-plane worklist path
    (`engine._merged_worklist_update`): both vmap THIS function over the
    HCU batch, so XLA sees identical shapes/broadcasts and the two paths
    stay bitwise-identical. The optimization barriers seal the graph into
    its own fusion island: without them XLA contracts mul+add chains into
    FMAs differently depending on what producer/consumer ops get fused in
    (gather vs staged buffer), which perturbs results at the 1-ulp level.
    """
    (z, e, pp, t0, ring, zi_g, ti_g, counts, zj, pi_dec, pj) = \
        jax.lax.optimization_barrier(
            (z, e, pp, t0, ring, zi_g, ti_g, counts, zj, pi_dec, pj))
    kij = H.coeffs_ij(p)
    t0f = t0.astype(jnp.float32)
    nowf = jnp.asarray(now, jnp.float32)
    b_prev = t0f
    zep = ZEP(z, e, pp)
    for m in range(RING_DEPTH):                         # oldest -> newest
        tm = ring[:, m].astype(jnp.float32)[None, :]    # (1, C) -> bcast
        b = jnp.clip(tm, t0f, nowf)                     # segment boundary
        zep = decay_zep(zep, b - b_prev, kij)
        bump = (tm > t0f) & (tm <= nowf)
        # Zi at the spike time, from the i-vector value at its last stamp
        zi_at = zi_g[:, None] * jnp.exp(
            -(tm - ti_g.astype(jnp.float32)[:, None]) * (1.0 / p.tau_zi))
        zep = ZEP(zep.z + jnp.where(bump, zi_at, 0.0), zep.e, zep.p)
        b_prev = b
    zep = decay_zep(zep, nowf - b_prev, kij)            # tail segment

    # --- own (row) spike increment + Bayesian weight ------------------------
    z1 = zep.z + counts[:, None] * zj[None, :]
    w1 = bayesian_weight(zep.p, pi_dec[:, None], pj[None, :], p.eps)
    return jax.lax.optimization_barrier((z1, zep.e, zep.p, w1))


def row_updates_merged(st: H.HCUState, ring, rows, now, p: BCPNNParams,
                       touch_only: bool = False):
    """Row updates with deferred (merged) column contributions.

    Identical signature/semantics to hcu.row_updates, but each cell's lazy
    decay is integrated piecewise across the output-spike times recorded in
    `ring`, injecting Zi(t_j) bumps where a column update would have
    (`merged_row_math`). touch_only=True decays/reconstructs without
    injecting input spikes (used by flush_merged).
    Returns (state', w_rows, counts, rows_u).
    """
    R = p.rows
    ki = H.coeffs_i(p)
    rows_u, counts = H.dedup_rows(rows, R)
    if touch_only:
        counts = jnp.zeros_like(counts)
    safe = jnp.minimum(rows_u, R - 1)

    # --- i-vector lazy decay + spike increment ------------------------------
    zi_g, ti_g = st.zi[safe], st.ti[safe]
    zep_i = H.ivec_decay(zi_g, st.ei[safe], st.pi[safe], ti_g, now, p)
    zi_new = zep_i.z + counts

    # --- ij cells: piecewise decay across ring spike times ------------------
    g = lambda plane: plane[safe]                       # (A, C)
    z1, e1, p1, w1 = merged_row_math(
        g(st.zij), g(st.eij), g(st.pij), g(st.tij), ring, zi_g, ti_g,
        counts, st.zj, zep_i.p, st.pj, now, p)

    st = H.write_rows(st, rows_u, now, p, z1, e1, p1, w1,
                      zi_new, zep_i.e, zep_i.p)
    return st, w1, counts, rows_u


def merged_col_math(z, e, pp, t0, ring_row, zi, ei, pi, ti, pj_j, apply_fire,
                    now, p: BCPNNParams):
    """Merged (R,)-column flush: piecewise ring integration + optional fire
    at `now` + Bayesian weight. Returns (z1, e1, p1, w1).

    Shared compute graph between the per-HCU vmap path
    (`column_flush_merged`) and the worklist overflow pass, sealed into its
    own fusion island for the same bitwise-identity reason as
    `merged_row_math`. ring_row (M,) is the fired column's ring;
    zi/ei/pi/ti the HCU's full i-vector.
    """
    (z, e, pp, t0, ring_row, zi, ei, pi, ti, pj_j, apply_fire) = \
        jax.lax.optimization_barrier(
            (z, e, pp, t0, ring_row, zi, ei, pi, ti, pj_j, apply_fire))
    kij, ki = H.coeffs_ij(p), H.coeffs_i(p)
    t0f = t0.astype(jnp.float32)
    tif = ti.astype(jnp.float32)
    nowf = jnp.asarray(now, jnp.float32)
    zep = ZEP(z, e, pp)
    b_prev = t0f
    for m in range(RING_DEPTH):
        tm = ring_row[m].astype(jnp.float32)
        b = jnp.clip(tm, t0f, nowf)
        zep = decay_zep(zep, b - b_prev, kij)
        bump = (tm > t0f) & (tm <= nowf)
        zi_at = zi * jnp.exp(-(tm - tif) * (1.0 / p.tau_zi))
        zep = ZEP(zep.z + jnp.where(bump, zi_at, 0.0), zep.e, zep.p)
        b_prev = b
    zep = decay_zep(zep, nowf - b_prev, kij)
    # the fire at `now` itself (Zi(now) from the lazily-decayed i-vector)
    zi_now = zi * jnp.exp(-(nowf - tif) * (1.0 / p.tau_zi))
    z1 = zep.z + jnp.where(apply_fire, zi_now, 0.0)
    pi_now = decay_zep(ZEP(zi, ei, pi), (nowf - tif), ki).p
    w1 = bayesian_weight(zep.p, pi_now, pj_j, p.eps)
    return jax.lax.optimization_barrier((z1, zep.e, zep.p, w1))


def column_flush_merged(st: H.HCUState, ring, j, now, apply_fire,
                        p: BCPNNParams) -> H.HCUState:
    """Bring column j fully current: piecewise-integrate its pending ring
    spikes into all R cells, optionally apply a fire happening at `now`,
    and stamp the column. Used when the ring would overflow — so the
    classic column write happens once per RING_DEPTH fires, not per fire
    (the eBrainIII amortization), and the mode stays EXACT."""
    # last-axis gather/scatter: no (R, C) transpose materialization
    gcol = lambda plane: jax.lax.dynamic_index_in_dim(plane, j, 1, False)
    z1, e1, p1, w1 = merged_col_math(
        gcol(st.zij), gcol(st.eij), gcol(st.pij), gcol(st.tij), ring[j],
        st.zi, st.ei, st.pi, st.ti, st.pj[j], apply_fire, now, p)

    def put(plane, val):
        old = jax.lax.dynamic_index_in_dim(plane, j, 1, False)
        new = jnp.where(apply_fire, val, old)
        return plane.at[:, j].set(new)

    return st._replace(
        zij=put(st.zij, z1), eij=put(st.eij, e1), pij=put(st.pij, p1),
        wij=put(st.wij, w1),
        tij=put(st.tij.astype(jnp.float32),
                jnp.full_like(z1, now)).astype(jnp.int32))


def hcu_tick_merged(st: H.HCUState, ring, rows, now, key, p: BCPNNParams):
    """One merged-mode HCU tick: j-vec decay, merged row updates, WTA, and
    (instead of a column update) a ring push + Zj bump for the fired MCU.

    Two consistency mechanisms (both validated vs the golden model):
      * same-tick patch: rows updated THIS tick are stamped Tij == now, so
        the strict `t_spike > Tij` ledger can't credit them a fire also at
        `now` — those A<=36 cells are patched directly;
      * overflow flush: when the fired column's ring is full, the column is
        flushed classically (with the current fire applied) and its ring
        cleared — one column write per RING_DEPTH fires instead of per fire,
        keeping the mode exact under any firing pattern."""
    st = H._decay_jvec(st, p)
    st, w_rows, counts, rows_u = row_updates_merged(st, ring, rows, now, p)
    st, fired_j = H.periodic_update(st, w_rows, counts, now, key, p)
    active = fired_j >= 0
    safe_j = jnp.maximum(fired_j, 0)
    overflow = active & (ring[safe_j, 0] != RING_EMPTY)

    # overflow path: classic (amortized) column flush, fire applied, no push
    st = column_flush_merged(st, ring, safe_j, now, overflow, p)
    ring = ring.at[safe_j].set(
        jnp.where(overflow, jnp.full((RING_DEPTH,), RING_EMPTY, jnp.int32),
                  ring[safe_j]))

    # normal path: defer via ring; patch only this tick's touched rows
    ziv = st.zi[jnp.minimum(rows_u, p.rows - 1)]      # post-increment Zi(now)
    st = st._replace(zij=st.zij.at[rows_u, safe_j].add(
        jnp.where(active & ~overflow, ziv, 0.0), mode="drop"))
    ring = push_ring(ring, jnp.where(overflow, -1, fired_j), now)

    zj = st.zj.at[safe_j].add(jnp.where(active, 1.0, 0.0))
    return st._replace(zj=zj), ring, fired_j


def flush_merged(st: H.HCUState, ring, now, p: BCPNNParams):
    """Bring every cell current (ring contributions applied): touch all rows
    with zero counts, then recompute W (comparable to hcu.flush output)."""
    R = p.rows
    n_batches = -(-R // 64)
    for b in range(n_batches):
        rows = jnp.arange(b * 64, min((b + 1) * 64, R), dtype=jnp.int32)
        rows = jnp.pad(rows, (0, 64 - rows.shape[0]), constant_values=R)
        st, _, _, _ = row_updates_merged(st, ring, rows, now, p,
                                         touch_only=True)
    return st


def worst_case_cells_merged(p: BCPNNParams) -> dict:
    """EQ2 with merged columns: the R-cell column term disappears."""
    classic = p.active_queue * p.cols + p.rows
    merged = p.active_queue * p.cols
    return {"classic_cells": classic, "merged_cells": merged,
            "reduction": classic / merged}
