"""BCPNN model parameters and scale presets.

Scales follow the paper (§II.A for human scale, §VII.C for rodent scale):
  human : 2M HCUs, R=10000 synaptic rows, C=100 MCUs/HCU
  rodent: 32K HCUs, R=1200,  C=70
Trace time constants follow the standard spiking BCPNN literature
(Tully, Hennig & Lansner 2014): tau_z ~ 5 ms, tau_e ~ 100 ms, tau_p ~ 1000 ms.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class BCPNNParams:
    # --- network dimensions -------------------------------------------------
    n_hcu: int = 16          # total HCUs in the network
    rows: int = 10_000       # R: synaptic inputs per HCU (i index)
    cols: int = 100          # C: MCUs per HCU (j index)
    fanout: int = 100        # output spike fanout (target HCUs per spike)

    # --- trace time constants (ms) -----------------------------------------
    tau_zi: float = 5.0
    tau_zj: float = 5.0
    tau_e: float = 100.0
    tau_p: float = 1000.0
    tau_m: float = 10.0      # support/membrane integration constant

    # --- rates & dimensioning (paper §II.A, §IV) ----------------------------
    dt_ms: float = 1.0            # simulation tick
    in_rate: float = 10.0         # mean input spikes / ms / HCU (Poisson lambda)
    out_rate: float = 0.1         # mean output spikes / ms / HCU (100 /s)
    active_queue: int = 36        # worst-case spikes/ms (Fig 7 dimensioning)
    max_delay: int = 16           # delay-queue horizon (ms); mean biological delay 4 ms
    mean_delay: float = 4.0

    # --- numerics ------------------------------------------------------------
    eps: float = 1e-4        # probability floor for log()
    p_init: float = 0.01     # initial P-trace background activity
    wta_temp: float = 1.0    # soft-WTA softmax temperature

    def __post_init__(self):
        # closed-form decay requires distinct time constants
        tz = self.tau_z_ij
        assert abs(tz - self.tau_e) > 1e-6 and abs(self.tau_e - self.tau_p) > 1e-6 \
            and abs(tz - self.tau_p) > 1e-6, "tau_z', tau_e, tau_p must be distinct"

    @property
    def tau_z_ij(self) -> float:
        """Effective time constant of the Zij = Zi*Zj product trace."""
        return (self.tau_zi * self.tau_zj) / (self.tau_zi + self.tau_zj)

    # --- derived requirement numbers (paper Table 1) -------------------------
    @property
    def cell_bytes(self) -> int:
        return 6 * 4  # 192-bit cell: Zij,Eij,Pij,Wij,Tij,(pad) as f32

    @property
    def hcu_storage_bytes(self) -> int:
        return self.rows * self.cols * self.cell_bytes

    @property
    def network_storage_bytes(self) -> int:
        return self.n_hcu * self.hcu_storage_bytes


def human_scale(n_hcu: int = 2_000_000) -> BCPNNParams:
    return BCPNNParams(n_hcu=n_hcu, rows=10_000, cols=100, fanout=100)


def rodent_scale(n_hcu: int = 32_000) -> BCPNNParams:
    return BCPNNParams(n_hcu=n_hcu, rows=1200, cols=70, fanout=100)


def test_scale(n_hcu: int = 4, rows: int = 64, cols: int = 16) -> BCPNNParams:
    """Tiny preset for unit tests and CPU smoke runs."""
    return BCPNNParams(n_hcu=n_hcu, rows=rows, cols=cols, fanout=min(8, n_hcu),
                       active_queue=8, max_delay=8)
