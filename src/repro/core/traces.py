"""Closed-form lazy evaluation of the BCPNN Z -> E -> P trace cascade.

The ODE system between spikes (paper Fig. 2):

    tau_z dZ/dt = -Z                 (Z decays exponentially)
    tau_e dE/dt =  Z - E
    tau_p dP/dt =  E - P

has the exact solution over a gap of ``dt`` (all in ms):

    ez = exp(-dt/tau_z), ee = exp(-dt/tau_e), ep = exp(-dt/tau_p)
    Z(dt) = Z0 * ez
    E(dt) = E0 * ee + Z0 * (ez - ee) * tau_z/(tau_z - tau_e)
    P(dt) = P0 * ep + (E0 - Z0*a) * (ee - ep) * tau_e/(tau_e - tau_p)
                    + Z0 * a * (ez - ep) * tau_z/(tau_z - tau_p)
    with a = tau_z/(tau_z - tau_e)

This module is the single source of truth for that algebra; the Pallas kernel
(`repro.kernels.bcpnn_update`) and the pure-jnp oracle (`repro.kernels.bcpnn_ref`)
both reproduce it and are tested against each other and against a small-step
Euler integration of the ODEs (tests/test_traces.py).

The semigroup property  decay(d1+d2) == decay(d2) o decay(d1)  is what makes
*lazy* evaluation exact: skipping N silent ticks and applying one integrated
decay is bit-for-bit equivalent (up to fp rounding) to N per-tick decays.
This is the paper's key algorithmic device (§II.A.2, "Lazy evaluation and
Time stamping").
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class ZEP(NamedTuple):
    """A Z->E->P trace triplet (arrays broadcast together)."""
    z: jnp.ndarray
    e: jnp.ndarray
    p: jnp.ndarray


class DecayCoeffs(NamedTuple):
    """Precomputed per-(tau_z,tau_e,tau_p) rational coefficients."""
    inv_tau_z: float
    inv_tau_e: float
    inv_tau_p: float
    c_ze: float   # tau_z / (tau_z - tau_e)
    c_ep: float   # tau_e / (tau_e - tau_p)
    c_zp: float   # tau_z / (tau_z - tau_p)


def make_coeffs(tau_z: float, tau_e: float, tau_p: float) -> DecayCoeffs:
    return DecayCoeffs(
        inv_tau_z=1.0 / tau_z,
        inv_tau_e=1.0 / tau_e,
        inv_tau_p=1.0 / tau_p,
        c_ze=tau_z / (tau_z - tau_e),
        c_ep=tau_e / (tau_e - tau_p),
        c_zp=tau_z / (tau_z - tau_p),
    )


def decay_zep(zep: ZEP, dt, k: DecayCoeffs) -> ZEP:
    """Propagate a ZEP triplet across a silent gap of ``dt`` ms (closed form).

    ``dt`` may be any non-negative array broadcastable with the traces.
    dt == 0 is the exact identity (ez = ee = ep = 1, difference terms vanish),
    which is what makes same-tick row+column updates compose correctly.
    """
    dt = jnp.asarray(dt, dtype=zep.z.dtype)
    ez = jnp.exp(-dt * k.inv_tau_z)
    ee = jnp.exp(-dt * k.inv_tau_e)
    ep = jnp.exp(-dt * k.inv_tau_p)
    z0, e0, p0 = zep
    e1 = e0 * ee + z0 * (ez - ee) * k.c_ze
    p1 = (p0 * ep
          + (e0 - z0 * k.c_ze) * (ee - ep) * k.c_ep
          + z0 * k.c_ze * (ez - ep) * k.c_zp)
    return ZEP(z0 * ez, e1, p1)


def euler_zep(zep: ZEP, dt: float, n_steps: int, k: DecayCoeffs) -> ZEP:
    """Explicit-Euler reference integration (for tests only)."""
    z, e, p = (jnp.asarray(x, jnp.float64 if False else jnp.float32) for x in zep)
    h = dt / n_steps
    for _ in range(n_steps):
        z, e, p = (z + h * (-z * k.inv_tau_z),
                   e + h * ((z - e) * k.inv_tau_e),
                   p + h * ((e - p) * k.inv_tau_p))
    return ZEP(z, e, p)


def bayesian_weight(p_ij, p_i, p_j, eps: float):
    """w_ij = log( P_ij / (P_i * P_j) ), regularized (paper Fig. 1/2)."""
    return jnp.log((p_ij + eps * eps) / ((p_i + eps) * (p_j + eps)))


def bias(p_j, eps: float):
    """b_j = log(P_j) — MCU prior activation."""
    return jnp.log(p_j + eps)
