"""Spike-queue dimensioning math (paper §IV, Fig 7).

Pure analysis utilities — the runtime queues themselves live in network.py.
Reproduces:
  * EQ1: P(x or more spikes in a ms) for Poisson(lambda=10) arrivals,
  * the queue-size-36 operating point (~30% chance of one drop per month),
  * the induced worst-case bandwidth / compute load (§IV.A):
      - 640 KB/HCU/ms synaptic traffic, 0.5 MFLOP/ms/HCU (paper's numbers
        are reproduced analytically in benchmarks/table1_requirements.py).
"""
from __future__ import annotations

import math


def p_x_or_more(x: int, lam: float) -> float:
    """Complement CDF: probability of >= x spikes in one ms (paper EQ1)."""
    # 1 - sum_{k=0}^{x-1} e^-lam lam^k / k!
    acc = 0.0
    term = math.exp(-lam)
    for k in range(x):
        acc += term
        term *= lam / (k + 1)
    return max(0.0, 1.0 - acc)


def drop_probability_per_ms(queue_size: int, lam: float) -> float:
    """Probability that at least one spike is dropped in a given ms."""
    return p_x_or_more(queue_size + 1, lam)


def expected_drops_per_month(queue_size: int, lam: float) -> float:
    ms_per_month = 1000.0 * 3600.0 * 24.0 * 30.0
    return drop_probability_per_ms(queue_size, lam) * ms_per_month


def min_queue_for_monthly_drop_budget(lam: float, budget: float = 1.0,
                                      max_q: int = 128) -> int:
    """Smallest queue size with expected drops/month <= budget (paper: 36)."""
    for q in range(1, max_q):
        if expected_drops_per_month(q, lam) <= budget:
            return q
    return max_q


def worst_case_ms_load(p) -> dict:
    """Worst-case per-ms load for queue-size spikes (paper §IV.A, EQ2).

    Returns bytes moved to/from synaptic storage and cell updates required.
    """
    q = p.active_queue
    cell_b = p.cell_bytes
    row_cells = p.cols
    col_cells = p.rows
    # rows: fetch+update+writeback; column: same; periodic: local SRAM only
    cells = q * row_cells + col_cells
    rw_bytes = 2 * cells * cell_b
    return {
        "worst_case_spikes": q,
        "cells_touched": cells,
        "bytes_per_ms": rw_bytes,
        "bandwidth_GBs": rw_bytes / 1e6,          # per ms -> per s is x1000
        "flops_per_ms": cells * FLOPS_PER_CELL,
    }


# FLOPs of one fused lazy cell update, counted from the closed-form datapath
# (traces.decay_zep + Hebbian increment + bayesian_weight):
#   3 exp (8 flop each by convention), 1 log (8), 1 div (4),
#   muls/adds of the closed form: ~20  -> ~60 flop/cell.
# The paper's 0.5 MFLOP/ms/HCU over ~13.6k worst-case cells implies ~40-110
# flop/cell depending on transcendental accounting — same order.
FLOPS_PER_CELL = 60
