"""Synaptic data organization: Row-Merge tiling and the flat worklist layout.

Two layout concerns live here, both instances of the paper's central theme
(§V.E, §VI.D): the memory layout must make the *touched* synaptic state —
not the whole matrix — the unit of traffic.

1. Row-Merge tiling (paper Fig 9-10), TPU-adapted
-------------------------------------------------
The (R=10000, C=100) synaptic matrix is accessed as rows (per input spike)
AND columns (per output spike). Direct row-major mapping makes a column
access cost one DRAM row-miss per cell. Row-Merge block-interleaves X x X
blocks so a column access hits X cells per DRAM row, minimizing total misses
at X = 10:

    rowmiss(X) = (row_rate * X + col_rate * C/X * C_groups) ...
    paper form: 10000 * (X + 100/X) * 2 per second, min at X = 10.

TPU adaptation: the DRAM row (page) becomes the HBM->VMEM DMA tile. A naive
row-major column access DMAs (8,128) tiles to use 1 lane-column each, i.e.
128x waste in the lane dim. We re-derive the same objective for tiles:

    bytes_touched(Xr, Xc) per second =
        row_rate * ceil(C/Xc) * tile_bytes        (a row crosses C/Xc tiles)
      + col_rate * ceil(R/Xr) * tile_bytes        (a column crosses R/Xr tiles)

and store the matrix as (R/Xr, C/Xc, Xr, Xc) so each tile is contiguous.
With f32 SoA planes the hardware-native tile is (8, 128); because C=100 < 128
a whole logical row fits one tile-row, so the TPU-optimal point degenerates
to Xc = C (pad to 128) and Xr = 8: rows cost 1 tile, columns cost R/8 tiles
— the exact analogue of the paper's conclusion that the layout must serve
BOTH patterns, with the optimum set by the access-rate ratio (100:1).

`benchmarks/fig10_rowmerge.py` sweeps X for the paper's DRAM cost model
(reproducing Fig 10: min at X=10, 5x better than direct) and the TPU tile
model side by side.

2. Flat (H*R, C) canonical layout (paper §VI.D: traffic scales with spikes)
--------------------------------------------------------------------------
The flat layout is the CANONICAL stored form of `NetworkState.hcus`
(`flat_state` below; since the TickEngine refactor): ij planes `(H*R, C)`,
i-vectors `(H*R,)`, j-vectors `(H, C)`. Every touched synaptic row is
addressable by a single global index

    g = h * R + r          (`global_row` below).

Because the layouts are row-major reinterpretations of the same buffer
(`flat_state` / `batched_state` and the per-plane `flatten_plane` /
`unflatten_plane` are reshapes, i.e. bitcasts), per-HCU vmapped code gets
the batched `(H, R, C)` view for free (`network.hcu_view`), checkpoints
persist the flat form (old batched-layout checkpoints migrate through
`checkpoint.restore_network`), and HCU shards stay whole under the
distributed runtime (device d owns flat rows [d*h_local*R, (d+1)*h_local*R)).
What the flat addressing buys is the update
*pattern*: one deduplicated network-wide worklist of global row indices per
tick, consumed by `lax.dynamic_slice`/`dynamic_update_slice` loops (CPU) or
a scalar-prefetch Pallas grid (TPU, `kernels.bcpnn_update.
worklist_update_kernel_call`), both of which rewrite only the touched
`(1, C)` row tiles in place. The per-HCU vmapped gather->update->scatter
forms they replace made XLA materialize a full `(H, R, C)` copy per scatter
on the scan-carried planes — O(planes) traffic per tick, the exact failure
mode the paper's lazy update exists to avoid. A fired column in the flat
view is the `(R, 1)` block at offset `(h*R, j)`, so column updates stay
expressible as single dynamic slices too (`col_offset`).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


# ----------------------------- paper's DRAM model ---------------------------

def dram_row_misses_per_s(x: int, rows: int = 10_000, cols: int = 100,
                          row_rate: float = 10_000.0, col_rate: float = 100.0):
    """Paper Fig 10 objective. X must divide `cols`.

    Under Row-Merge with X x X blocks (DRAM row capacity = one `cols`-cell
    matrix row), a row access touches X DRAM rows (its cells are spread over
    the X merged rows of its group) and a column access touches rows/X DRAM
    rows (X column cells co-located per merged row):

        rowmiss(X) = (row_rate * X + col_rate * rows/X) * 2   (read+write)

    At the paper's rates (row_rate=10000/s, col_rate=100/s, R=10000) this is
    their stated closed form 10000 * (X + 100/X) * 2 — min at X = 10, 5.05x
    better than the direct X = 1 mapping (tests/test_layout.py pins both).
    """
    return (row_rate * x + col_rate * (rows / x)) * 2.0


def paper_fig10_table(rows=10_000, cols=100):
    xs = [x for x in range(1, cols + 1) if cols % x == 0]
    return {x: dram_row_misses_per_s(x, rows, cols) for x in xs}


# ----------------------------- TPU tile model -------------------------------

def tile_bytes_touched_per_s(xr: int, xc: int, rows: int, cols: int,
                             row_rate: float, col_rate: float,
                             bytes_per_cell: int = 20):
    """Bytes DMA'd HBM<->VMEM per second under (xr, xc) tiling (read+write)."""
    tile_b = xr * xc * bytes_per_cell
    tiles_per_row = -(-cols // xc)
    tiles_per_col = -(-rows // xr)
    return 2.0 * tile_b * (row_rate * tiles_per_row + col_rate * tiles_per_col)


def best_tile(rows: int, cols: int, row_rate: float, col_rate: float,
              candidates=((8, 128), (8, 256), (16, 128), (32, 128), (8, 512),
                          (64, 128), (128, 128), (256, 128))):
    scored = {c: tile_bytes_touched_per_s(c[0], min(c[1], cols), rows, cols,
                                          row_rate, col_rate)
              for c in candidates}
    best = min(scored, key=scored.get)
    return best, scored


# ----------------------------- layout transform -----------------------------

@dataclasses.dataclass(frozen=True)
class RowMergeLayout:
    """Bijective (R, C) <-> (R/xr, C/xc, xr, xc) tiled layout.

    The tiled form is how synaptic planes are stored in HBM so that both the
    row-update and the column-update Pallas kernels fetch whole contiguous
    tiles (the TPU translation of 'DRAM row == matrix row').
    """
    rows: int
    cols: int
    xr: int = 8
    xc: int = 128

    @property
    def padded_rows(self) -> int:
        return -(-self.rows // self.xr) * self.xr

    @property
    def padded_cols(self) -> int:
        return -(-self.cols // self.xc) * self.xc

    def pack(self, plane: jnp.ndarray) -> jnp.ndarray:
        """(R, C) -> (R'/xr, C'/xc, xr, xc), zero-padded."""
        R, C = plane.shape
        assert (R, C) == (self.rows, self.cols)
        p = jnp.pad(plane, ((0, self.padded_rows - R), (0, self.padded_cols - C)))
        t = p.reshape(self.padded_rows // self.xr, self.xr,
                      self.padded_cols // self.xc, self.xc)
        return t.transpose(0, 2, 1, 3)

    def unpack(self, tiled: jnp.ndarray) -> jnp.ndarray:
        t = tiled.transpose(0, 2, 1, 3).reshape(self.padded_rows,
                                                self.padded_cols)
        return t[: self.rows, : self.cols]

    def row_tiles(self, r: int):
        """Tile coordinates a logical row touches: (tile_r, all tile_cs)."""
        return r // self.xr, np.arange(self.padded_cols // self.xc)

    def col_tiles(self, c: int):
        return np.arange(self.padded_rows // self.xr), c // self.xc


# ----------------------------- flat worklist layout --------------------------

# HCUState fields stored flat (leading axis H*R) in the canonical layout; the
# j-vector/support fields (zj, ej, pj, h) keep their (H, C) shape — they are
# per-HCU dense and always current, so there is nothing to flatten.
_FLAT_PLANE_FIELDS = ("zij", "eij", "pij", "wij", "tij")
_FLAT_VEC_FIELDS = ("zi", "ei", "pi", "ti")


def flat_state(hcus):
    """Batched (H, R, C)/(H, R) HCUState -> the CANONICAL flat layout.

    ij planes become (H*R, C), i-vectors (H*R,); j-vectors stay (H, C).
    Pure reshapes (row-major bitcasts) — values are untouched, so the two
    layouts are bitwise-interchangeable views of the same network.
    """
    upd = {f: flatten_plane(getattr(hcus, f)) for f in _FLAT_PLANE_FIELDS}
    upd.update({f: flatten_vec(getattr(hcus, f)) for f in _FLAT_VEC_FIELDS})
    return hcus._replace(**upd)


def batched_state(hcus, n_hcu: int):
    """Canonical flat HCUState -> the per-HCU batched (H, R, C)/(H, R) view
    that `jax.vmap`-over-HCUs code consumes (zero-copy inverse of
    `flat_state`)."""
    upd = {f: unflatten_plane(getattr(hcus, f), n_hcu)
           for f in _FLAT_PLANE_FIELDS}
    upd.update({f: unflatten_vec(getattr(hcus, f), n_hcu)
                for f in _FLAT_VEC_FIELDS})
    return hcus._replace(**upd)


def flatten_plane(plane: jnp.ndarray) -> jnp.ndarray:
    """(H, R, C) -> (H*R, C) flat view (zero-copy: row-major bitcast)."""
    H, R, C = plane.shape
    return plane.reshape(H * R, C)


def unflatten_plane(flat: jnp.ndarray, n_hcu: int) -> jnp.ndarray:
    """(H*R, C) -> (H, R, C) batched view (zero-copy inverse)."""
    HR, C = flat.shape
    return flat.reshape(n_hcu, HR // n_hcu, C)


def flatten_vec(vec: jnp.ndarray) -> jnp.ndarray:
    """(H, R) i-vector plane -> (H*R,) flat view."""
    H, R = vec.shape
    return vec.reshape(H * R)


def unflatten_vec(flat: jnp.ndarray, n_hcu: int) -> jnp.ndarray:
    return flat.reshape(n_hcu, flat.shape[0] // n_hcu)


def global_row(h, r, rows: int):
    """(hcu, row) -> global flat row index; broadcastable."""
    return h * rows + r


def col_offset(h, j, rows: int):
    """Flat-plane offset of HCU ``h``'s column ``j``: the (R, 1) block at
    (h*R, j) — a fired column is one dynamic slice in the flat view."""
    return h * rows, j


# ----------------------------- pluggable plane layout ------------------------
#
# The PHYSICAL storage order of the ij planes is a pluggable property of the
# canonical state. A PlaneLayout is a frozen hashable value object (usable as
# a jit static argument) with two duties:
#
#   * whole-plane conversion: `store` (canonical flat (H*R, C) -> stored
#     form) and `load` (inverse) — pure f32/int32 data movement, so every
#     layout holds bitwise-identical logical values;
#   * traced accessors for the worklist loops: read/write/stamp of one
#     logical row ((1, C)), one logical column ((R,)), and one cell — the
#     exact seam `repro.core.worklist`'s dynamic-slice loops go through.
#
# Two implementations:
#
#   * FlatLayout — the historical row-major (H*R, C) storage (DEFAULT). Its
#     accessors emit exactly the dynamic-slice expressions the worklist
#     loops always emitted, so flat compute graphs are UNCHANGED by the
#     abstraction (the bitwise-frozen contract of docs/NUMERICS.md).
#   * BlockedLayout — the Row-Merge/column-blocked variant: each HCU's
#     (R, C) plane is stored as (R'/xr, C'/xc, xr, xc) tiles (network-wide:
#     (H*Tr, Tc, xr, xc)), zero-padded to tile multiples. A fired column
#     then touches Tr contiguous (xr, 1)-strided fragments instead of R
#     isolated cells — ~R*xc*4/64 cache lines instead of R (the paper's
#     Fig 9-10 trade re-derived for 64 B lines; `cache_lines_touched_per_s`
#     is the model, `benchmarks/fig10_rowmerge.py` the sweep). At the TPU
#     degenerate point (xr=8, xc=128 >= C) the stored form reshapes to the
#     row-padded flat view the Pallas megakernels already consume, so only
#     index remapping changes (`flat_view`/`pad_row_index`).
#
# Layout is storage order, NOT math: the worklist loop bodies feed the same
# sealed compute islands the same logical (1, C)/(R,) blocks under either
# layout, so trajectories stay fixture-pinned bitwise (the A/B is pinned by
# tests/test_engine_fixtures.py::test_layout_ab).

def cache_lines_touched_per_s(xr: int, xc: int, rows: int, cols: int,
                              row_rate: float, col_rate: float,
                              line_bytes: int = 64, cell_bytes: int = 4):
    """CPU twin of `tile_bytes_touched_per_s`: 64 B cache lines touched per
    second under (xr, xc) blocking (read+write). A logical row touches
    ceil(C/xc) tile-row segments of xc contiguous cells each; a logical
    column touches ceil(R/xr) tiles, min(xr, ceil(xr*xc*cell/line)) lines
    each (within a tile the column's xr cells sit at stride xc*cell). The
    flat layout is the (1, cols) point: ~ceil(C*cell/line) lines per row,
    R lines per column."""
    seg = max(1, -(-(xc * cell_bytes) // line_bytes))
    lines_row = -(-cols // xc) * seg
    per_tile = min(xr, -(-(xr * xc * cell_bytes) // line_bytes))
    lines_col = -(-rows // xr) * per_tile
    return 2.0 * (row_rate * lines_row + col_rate * lines_col)


@dataclasses.dataclass(frozen=True)
class FlatLayout:
    """The canonical row-major (H*R, C) storage — the DEFAULT PlaneLayout.

    `layout=None` everywhere means this layout; the class exists so the
    accessor seam has a concrete flat implementation (tests exercise it
    directly). Its methods emit exactly the dynamic-slice expressions the
    worklist loops historically inlined — same primitives, same operands —
    which is what keeps flat graphs bitwise-frozen. ``rows`` is only needed
    by the column/cell accessors (the flat column offset is h*R)."""
    rows: int | None = None

    def store(self, flat: jnp.ndarray) -> jnp.ndarray:
        return flat

    def load(self, stored: jnp.ndarray) -> jnp.ndarray:
        return stored

    def read_row(self, f, g):
        return jax.lax.dynamic_slice(f, (g, 0), (1, f.shape[1]))

    def write_row(self, f, g, val):
        return jax.lax.dynamic_update_slice(f, val, (g, 0))

    def stamp_row(self, f, g, now):
        return jax.lax.dynamic_update_slice(
            f, jnp.full((1, f.shape[1]), now, f.dtype), (g, 0))

    def read_col(self, f, h, j):
        off, j = col_offset(h, j, self.rows)
        return jax.lax.dynamic_slice(
            f, (off, j), (self.rows, 1)).reshape(self.rows)

    def write_col(self, f, h, j, val):
        """``val``: any R-element block (the callers pass the raw (1, R)
        staging slice; one reshape here, exactly the historical sequence)."""
        off, j = col_offset(h, j, self.rows)
        return jax.lax.dynamic_update_slice(
            f, val.reshape(self.rows, 1), (off, j))

    def stamp_col(self, f, h, j, now):
        off, j = col_offset(h, j, self.rows)
        return jax.lax.dynamic_update_slice(
            f, jnp.full((self.rows, 1), now, f.dtype), (off, j))

    def add_cell(self, f, h, r, j, delta):
        g = global_row(h, r, self.rows)
        cell = jax.lax.dynamic_slice(f, (g, j), (1, 1))
        return jax.lax.dynamic_update_slice(f, cell + delta, (g, j))


@dataclasses.dataclass(frozen=True)
class BlockedLayout:
    """Row-Merge/column-blocked plane storage: (H*Tr, Tc, xr, xc) tiles.

    Per HCU this is exactly `RowMergeLayout(rows, cols, xr, xc).pack`
    (pinned by tests/test_layout.py); network-wide the H per-HCU tile grids
    are stacked along the leading axis, so HCU h's tiles are the Tr
    consecutive tile-rows starting at h*Tr. Pad cells (r >= R or j >= C)
    never feed compute — row/column/cell accessors only ever address valid
    logical coordinates, and `load` slices padding off — so their values are
    free to be garbage (writes fill them with zeros / stamp values).
    """
    rows: int
    cols: int
    xr: int = 8
    xc: int = 4

    @property
    def padded_rows(self) -> int:
        return -(-self.rows // self.xr) * self.xr

    @property
    def padded_cols(self) -> int:
        return -(-self.cols // self.xc) * self.xc

    @property
    def row_tiles_n(self) -> int:        # Tr
        return self.padded_rows // self.xr

    @property
    def col_tiles_n(self) -> int:        # Tc
        return self.padded_cols // self.xc

    @property
    def tpu_degenerate(self) -> bool:
        """One column-tile (xc >= C): the stored form is the row-padded flat
        view (`flat_view`), which the Pallas megakernels consume natively."""
        return self.col_tiles_n == 1

    def plane_shape(self, n_hcu: int):
        return (n_hcu * self.row_tiles_n, self.col_tiles_n, self.xr, self.xc)

    # -- whole-plane conversion (pure data movement, bitwise) ---------------
    def store(self, flat: jnp.ndarray) -> jnp.ndarray:
        """(H*R, C) canonical flat -> (H*Tr, Tc, xr, xc), zero-padded."""
        HR, C = flat.shape
        H = HR // self.rows
        p = flat.reshape(H, self.rows, C)
        p = jnp.pad(p, ((0, 0), (0, self.padded_rows - self.rows),
                        (0, self.padded_cols - C)))
        t = p.reshape(H, self.row_tiles_n, self.xr,
                      self.col_tiles_n, self.xc).transpose(0, 1, 3, 2, 4)
        return t.reshape(H * self.row_tiles_n, self.col_tiles_n,
                         self.xr, self.xc)

    def load(self, stored: jnp.ndarray) -> jnp.ndarray:
        """Inverse of `store`: padding sliced off."""
        H = stored.shape[0] // self.row_tiles_n
        t = stored.reshape(H, self.row_tiles_n, self.col_tiles_n,
                           self.xr, self.xc).transpose(0, 1, 3, 2, 4)
        p = t.reshape(H, self.padded_rows,
                      self.padded_cols)[:, : self.rows, : self.cols]
        return p.reshape(H * self.rows, self.cols)

    # -- traced worklist accessors ------------------------------------------
    def read_row(self, f, g):
        """Global flat row index g -> the logical (1, C) row."""
        h, r = g // self.rows, g % self.rows
        blk = jax.lax.dynamic_slice(
            f, (h * self.row_tiles_n + r // self.xr, 0, r % self.xr, 0),
            (1, self.col_tiles_n, 1, self.xc))
        return blk.reshape(1, self.padded_cols)[:, : self.cols]

    def _row_block(self, val):
        pc = self.padded_cols
        if pc != self.cols:
            val = jnp.pad(val, ((0, 0), (0, pc - self.cols)))
        return val.reshape(1, self.col_tiles_n, 1, self.xc)

    def write_row(self, f, g, val):
        h, r = g // self.rows, g % self.rows
        return jax.lax.dynamic_update_slice(
            f, self._row_block(val.astype(f.dtype)),
            (h * self.row_tiles_n + r // self.xr, 0, r % self.xr, 0))

    def stamp_row(self, f, g, now):
        h, r = g // self.rows, g % self.rows
        return jax.lax.dynamic_update_slice(
            f, jnp.full((1, self.col_tiles_n, 1, self.xc), now, f.dtype),
            (h * self.row_tiles_n + r // self.xr, 0, r % self.xr, 0))

    def read_col(self, f, h, j):
        """HCU h's logical column j -> (R,)."""
        blk = jax.lax.dynamic_slice(
            f, (h * self.row_tiles_n, j // self.xc, 0, j % self.xc),
            (self.row_tiles_n, 1, self.xr, 1))
        return blk.reshape(self.padded_rows)[: self.rows]

    def _col_block(self, val):
        pr = self.padded_rows
        val = val.reshape(self.rows)
        if pr != self.rows:
            val = jnp.pad(val, (0, pr - self.rows))
        return val.reshape(self.row_tiles_n, 1, self.xr, 1)

    def write_col(self, f, h, j, val):
        return jax.lax.dynamic_update_slice(
            f, self._col_block(val.astype(f.dtype)),
            (h * self.row_tiles_n, j // self.xc, 0, j % self.xc))

    def stamp_col(self, f, h, j, now):
        return jax.lax.dynamic_update_slice(
            f, jnp.full((self.row_tiles_n, 1, self.xr, 1), now, f.dtype),
            (h * self.row_tiles_n, j // self.xc, 0, j % self.xc))

    def add_cell(self, f, h, r, j, delta):
        idx = (h * self.row_tiles_n + r // self.xr, j // self.xc,
               r % self.xr, j % self.xc)
        cell = jax.lax.dynamic_slice(f, idx, (1, 1, 1, 1))
        return jax.lax.dynamic_update_slice(f, cell + delta, idx)

    # -- Pallas megakernel plumbing (degenerate point only) -----------------
    def flat_view(self, stored: jnp.ndarray) -> jnp.ndarray:
        """Degenerate (Tc == 1) stored plane as the row-padded flat
        (H*R', C') view — a pure reshape, so the scalar-prefetch megakernel
        BlockSpecs (kernels/bcpnn_update.py) need no layout variant: only
        the row indices are remapped (`pad_row_index`)."""
        assert self.tpu_degenerate
        return stored.reshape(stored.shape[0] * self.xr, self.xc)

    def from_flat_view(self, view: jnp.ndarray) -> jnp.ndarray:
        return view.reshape(view.shape[0] // self.xr, 1, self.xr, self.xc)

    def pad_row_index(self, g, n_hcu: int):
        """Canonical flat row index (sentinel n_hcu*R) -> row-padded view
        index (sentinel n_hcu*R', routed onto the kernels' junk rows)."""
        rp = self.padded_rows
        return jnp.where(g < n_hcu * self.rows,
                         (g // self.rows) * rp + g % self.rows,
                         n_hcu * rp)

    def pad_ivec(self, v, n_hcu: int):
        """(H*R,) i-vector -> (H*R',) zero-padded (the fused row megakernel
        shares one row-index stream between planes and i-vectors)."""
        if self.padded_rows == self.rows:
            return v
        return jnp.pad(v.reshape(n_hcu, self.rows),
                       ((0, 0), (0, self.padded_rows - self.rows))) \
            .reshape(-1)

    def unpad_ivec(self, v, n_hcu: int):
        if self.padded_rows == self.rows:
            return v
        return v.reshape(n_hcu, self.padded_rows)[:, : self.rows].reshape(-1)


def as_blocked(layout) -> BlockedLayout | None:
    """Normalize a layout argument for engine/worklist branching: None for
    the flat default (None or FlatLayout), else the BlockedLayout."""
    if layout is None or isinstance(layout, FlatLayout):
        return None
    return layout


def resolve_layout(layout, p) -> BlockedLayout | None:
    """User-facing layout spec -> normalized static-arg form (None == flat).

    Accepts None / "flat" / a PlaneLayout instance / "blocked" (the CPU
    cache-line sweet spot, `cpu_blocked`) / "blocked_tpu" (the (8, 128)
    degenerate point, `tpu_blocked`)."""
    if layout is None or layout == "flat" or isinstance(layout, FlatLayout):
        return None
    if layout == "blocked":
        return cpu_blocked(p)
    if layout == "blocked_tpu":
        return tpu_blocked(p)
    if isinstance(layout, BlockedLayout):
        return layout
    raise ValueError(f"unknown plane layout {layout!r}")


# CPU column-blocked sweet spot (measured at human_col, see
# benchmarks/fig10_rowmerge.py -> BENCH_layout.json): xc*4 B spans a quarter
# cache line, so a fired column touches ~R*xc*4/64 = R/4 lines instead of R,
# while a row pays ceil(C/xc) segments instead of ~7 lines — the right trade
# at the paper's 100:1 row:column *access*-rate but R-cell column size.
CPU_BLOCK_XR = 8
CPU_BLOCK_XC = 4


def cpu_blocked(p) -> BlockedLayout:
    return BlockedLayout(rows=p.rows, cols=p.cols,
                         xr=CPU_BLOCK_XR, xc=CPU_BLOCK_XC)


def tpu_blocked(p) -> BlockedLayout:
    return BlockedLayout(rows=p.rows, cols=p.cols, xr=8, xc=128)


def layout_tag(layout) -> str:
    """Checkpoint-manifest tag for a layout (parse: `layout_from_tag`)."""
    lay = as_blocked(layout)
    if lay is None:
        return "flat"
    return f"blocked:xr={lay.xr},xc={lay.xc}"


def layout_from_tag(tag: str, p) -> BlockedLayout | None:
    if tag in (None, "", "flat"):
        return None
    if tag.startswith("blocked:"):
        kv = dict(kv.split("=") for kv in tag[len("blocked:"):].split(","))
        return BlockedLayout(rows=p.rows, cols=p.cols,
                             xr=int(kv["xr"]), xc=int(kv["xc"]))
    raise ValueError(f"unknown layout tag {tag!r}")


def store_hcus(hcus, layout):
    """Canonical-flat HCUState -> the layout's stored form (ij planes only;
    i-/j-vectors are layout-independent). No-op for flat."""
    lay = as_blocked(layout)
    if lay is None:
        return hcus
    return hcus._replace(**{f: lay.store(getattr(hcus, f))
                            for f in _FLAT_PLANE_FIELDS})


def load_hcus(hcus, layout):
    """Inverse of `store_hcus` (stored form -> canonical flat)."""
    lay = as_blocked(layout)
    if lay is None:
        return hcus
    return hcus._replace(**{f: lay.load(getattr(hcus, f))
                            for f in _FLAT_PLANE_FIELDS})


def convert_hcus(hcus, src, dst):
    """Re-store an HCUState from layout `src` to layout `dst` (either may be
    None == flat). Pure data movement through the canonical flat form —
    logical values are bitwise-preserved (the checkpoint cross-layout
    restore shim, tests/test_checkpoint.py)."""
    s, d = as_blocked(src), as_blocked(dst)
    if s == d:
        return hcus
    return store_hcus(load_hcus(hcus, s), d)
