"""Synaptic data organization: Row-Merge tiling and the flat worklist layout.

Two layout concerns live here, both instances of the paper's central theme
(§V.E, §VI.D): the memory layout must make the *touched* synaptic state —
not the whole matrix — the unit of traffic.

1. Row-Merge tiling (paper Fig 9-10), TPU-adapted
-------------------------------------------------
The (R=10000, C=100) synaptic matrix is accessed as rows (per input spike)
AND columns (per output spike). Direct row-major mapping makes a column
access cost one DRAM row-miss per cell. Row-Merge block-interleaves X x X
blocks so a column access hits X cells per DRAM row, minimizing total misses
at X = 10:

    rowmiss(X) = (row_rate * X + col_rate * C/X * C_groups) ...
    paper form: 10000 * (X + 100/X) * 2 per second, min at X = 10.

TPU adaptation: the DRAM row (page) becomes the HBM->VMEM DMA tile. A naive
row-major column access DMAs (8,128) tiles to use 1 lane-column each, i.e.
128x waste in the lane dim. We re-derive the same objective for tiles:

    bytes_touched(Xr, Xc) per second =
        row_rate * ceil(C/Xc) * tile_bytes        (a row crosses C/Xc tiles)
      + col_rate * ceil(R/Xr) * tile_bytes        (a column crosses R/Xr tiles)

and store the matrix as (R/Xr, C/Xc, Xr, Xc) so each tile is contiguous.
With f32 SoA planes the hardware-native tile is (8, 128); because C=100 < 128
a whole logical row fits one tile-row, so the TPU-optimal point degenerates
to Xc = C (pad to 128) and Xr = 8: rows cost 1 tile, columns cost R/8 tiles
— the exact analogue of the paper's conclusion that the layout must serve
BOTH patterns, with the optimum set by the access-rate ratio (100:1).

`benchmarks/fig10_rowmerge.py` sweeps X for the paper's DRAM cost model
(reproducing Fig 10: min at X=10, 5x better than direct) and the TPU tile
model side by side.

2. Flat (H*R, C) canonical layout (paper §VI.D: traffic scales with spikes)
--------------------------------------------------------------------------
The flat layout is the CANONICAL stored form of `NetworkState.hcus`
(`flat_state` below; since the TickEngine refactor): ij planes `(H*R, C)`,
i-vectors `(H*R,)`, j-vectors `(H, C)`. Every touched synaptic row is
addressable by a single global index

    g = h * R + r          (`global_row` below).

Because the layouts are row-major reinterpretations of the same buffer
(`flat_state` / `batched_state` and the per-plane `flatten_plane` /
`unflatten_plane` are reshapes, i.e. bitcasts), per-HCU vmapped code gets
the batched `(H, R, C)` view for free (`network.hcu_view`), checkpoints
persist the flat form (old batched-layout checkpoints migrate through
`checkpoint.restore_network`), and HCU shards stay whole under the
distributed runtime (device d owns flat rows [d*h_local*R, (d+1)*h_local*R)).
What the flat addressing buys is the update
*pattern*: one deduplicated network-wide worklist of global row indices per
tick, consumed by `lax.dynamic_slice`/`dynamic_update_slice` loops (CPU) or
a scalar-prefetch Pallas grid (TPU, `kernels.bcpnn_update.
worklist_update_kernel_call`), both of which rewrite only the touched
`(1, C)` row tiles in place. The per-HCU vmapped gather->update->scatter
forms they replace made XLA materialize a full `(H, R, C)` copy per scatter
on the scan-carried planes — O(planes) traffic per tick, the exact failure
mode the paper's lazy update exists to avoid. A fired column in the flat
view is the `(R, 1)` block at offset `(h*R, j)`, so column updates stay
expressible as single dynamic slices too (`col_offset`).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


# ----------------------------- paper's DRAM model ---------------------------

def dram_row_misses_per_s(x: int, rows: int = 10_000, cols: int = 100,
                          row_rate: float = 10_000.0, col_rate: float = 100.0):
    """Paper Fig 10 objective. X must divide `cols`.

    A row access touches X DRAM rows (its blocks are spread over X merged
    rows); a column access touches C/X DRAM rows per row-group, and there are
    R / X row-groups... the paper folds rates so that:
        rowmiss(X) = row_rate * X + col_rate * (rows/ x_groups)  with
    their stated closed form  10000 * (X + 100/X) * 2  (read+write).
    """
    return (row_rate * x + col_rate * (rows / x) * (cols / cols)) * 2.0


def paper_fig10_table(rows=10_000, cols=100):
    xs = [x for x in range(1, cols + 1) if cols % x == 0]
    return {x: dram_row_misses_per_s(x, rows, cols) for x in xs}


# ----------------------------- TPU tile model -------------------------------

def tile_bytes_touched_per_s(xr: int, xc: int, rows: int, cols: int,
                             row_rate: float, col_rate: float,
                             bytes_per_cell: int = 20):
    """Bytes DMA'd HBM<->VMEM per second under (xr, xc) tiling (read+write)."""
    tile_b = xr * xc * bytes_per_cell
    tiles_per_row = -(-cols // xc)
    tiles_per_col = -(-rows // xr)
    return 2.0 * tile_b * (row_rate * tiles_per_row + col_rate * tiles_per_col)


def best_tile(rows: int, cols: int, row_rate: float, col_rate: float,
              candidates=((8, 128), (8, 256), (16, 128), (32, 128), (8, 512),
                          (64, 128), (128, 128), (256, 128))):
    scored = {c: tile_bytes_touched_per_s(c[0], min(c[1], cols), rows, cols,
                                          row_rate, col_rate)
              for c in candidates}
    best = min(scored, key=scored.get)
    return best, scored


# ----------------------------- layout transform -----------------------------

@dataclasses.dataclass(frozen=True)
class RowMergeLayout:
    """Bijective (R, C) <-> (R/xr, C/xc, xr, xc) tiled layout.

    The tiled form is how synaptic planes are stored in HBM so that both the
    row-update and the column-update Pallas kernels fetch whole contiguous
    tiles (the TPU translation of 'DRAM row == matrix row').
    """
    rows: int
    cols: int
    xr: int = 8
    xc: int = 128

    @property
    def padded_rows(self) -> int:
        return -(-self.rows // self.xr) * self.xr

    @property
    def padded_cols(self) -> int:
        return -(-self.cols // self.xc) * self.xc

    def pack(self, plane: jnp.ndarray) -> jnp.ndarray:
        """(R, C) -> (R'/xr, C'/xc, xr, xc), zero-padded."""
        R, C = plane.shape
        assert (R, C) == (self.rows, self.cols)
        p = jnp.pad(plane, ((0, self.padded_rows - R), (0, self.padded_cols - C)))
        t = p.reshape(self.padded_rows // self.xr, self.xr,
                      self.padded_cols // self.xc, self.xc)
        return t.transpose(0, 2, 1, 3)

    def unpack(self, tiled: jnp.ndarray) -> jnp.ndarray:
        t = tiled.transpose(0, 2, 1, 3).reshape(self.padded_rows,
                                                self.padded_cols)
        return t[: self.rows, : self.cols]

    def row_tiles(self, r: int):
        """Tile coordinates a logical row touches: (tile_r, all tile_cs)."""
        return r // self.xr, np.arange(self.padded_cols // self.xc)

    def col_tiles(self, c: int):
        return np.arange(self.padded_rows // self.xr), c // self.xc


# ----------------------------- flat worklist layout --------------------------

# HCUState fields stored flat (leading axis H*R) in the canonical layout; the
# j-vector/support fields (zj, ej, pj, h) keep their (H, C) shape — they are
# per-HCU dense and always current, so there is nothing to flatten.
_FLAT_PLANE_FIELDS = ("zij", "eij", "pij", "wij", "tij")
_FLAT_VEC_FIELDS = ("zi", "ei", "pi", "ti")


def flat_state(hcus):
    """Batched (H, R, C)/(H, R) HCUState -> the CANONICAL flat layout.

    ij planes become (H*R, C), i-vectors (H*R,); j-vectors stay (H, C).
    Pure reshapes (row-major bitcasts) — values are untouched, so the two
    layouts are bitwise-interchangeable views of the same network.
    """
    upd = {f: flatten_plane(getattr(hcus, f)) for f in _FLAT_PLANE_FIELDS}
    upd.update({f: flatten_vec(getattr(hcus, f)) for f in _FLAT_VEC_FIELDS})
    return hcus._replace(**upd)


def batched_state(hcus, n_hcu: int):
    """Canonical flat HCUState -> the per-HCU batched (H, R, C)/(H, R) view
    that `jax.vmap`-over-HCUs code consumes (zero-copy inverse of
    `flat_state`)."""
    upd = {f: unflatten_plane(getattr(hcus, f), n_hcu)
           for f in _FLAT_PLANE_FIELDS}
    upd.update({f: unflatten_vec(getattr(hcus, f), n_hcu)
                for f in _FLAT_VEC_FIELDS})
    return hcus._replace(**upd)


def flatten_plane(plane: jnp.ndarray) -> jnp.ndarray:
    """(H, R, C) -> (H*R, C) flat view (zero-copy: row-major bitcast)."""
    H, R, C = plane.shape
    return plane.reshape(H * R, C)


def unflatten_plane(flat: jnp.ndarray, n_hcu: int) -> jnp.ndarray:
    """(H*R, C) -> (H, R, C) batched view (zero-copy inverse)."""
    HR, C = flat.shape
    return flat.reshape(n_hcu, HR // n_hcu, C)


def flatten_vec(vec: jnp.ndarray) -> jnp.ndarray:
    """(H, R) i-vector plane -> (H*R,) flat view."""
    H, R = vec.shape
    return vec.reshape(H * R)


def unflatten_vec(flat: jnp.ndarray, n_hcu: int) -> jnp.ndarray:
    return flat.reshape(n_hcu, flat.shape[0] // n_hcu)


def global_row(h, r, rows: int):
    """(hcu, row) -> global flat row index; broadcastable."""
    return h * rows + r


def col_offset(h, j, rows: int):
    """Flat-plane offset of HCU ``h``'s column ``j``: the (R, 1) block at
    (h*R, j) — a fired column is one dynamic slice in the flat view."""
    return h * rows, j
