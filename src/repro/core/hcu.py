"""Hyper Column Unit (HCU) state and the three BCPNN update types.

Per the paper (§II.A.2) an HCU services three atomic sub-threads each 1 ms tick:
  * row updates     — one per incoming spike (lazy, touches one (C,) row)
  * column update   — on output spike (lazy, touches one (R,) column)
  * periodic update — support integration + soft winner-take-all

State is structure-of-arrays (TPU-friendly planes) instead of the ASIC's
192-bit AoS cells; the field set is identical: Zij, Eij, Pij, Wij, Tij.
The j-vector is always kept current (decayed every tick) — it is the paper's
"stored locally in SRAM, excluded from synaptic bandwidth" structure. The
i-vector and the ij-matrix are lazy (timestamped).

All functions are pure and per-HCU; `repro.core.network` vmaps them over the
local HCU batch and shard_maps across devices.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.params import BCPNNParams
from repro.core.traces import ZEP, bias, decay_zep, make_coeffs
from repro.kernels import ops

# jax 0.4.x has no vmap batching rule for optimization_barrier (identity per
# operand, so the rule is trivial); the sealed compute islands below are
# used under vmap, so register it when missing.
try:  # pragma: no cover - exercised only on jax versions lacking the rule
    from jax._src.lax.lax import optimization_barrier_p as _opt_barrier_p
    from jax.interpreters import batching as _batching

    if _opt_barrier_p not in _batching.primitive_batchers:
        def _opt_barrier_batcher(args, dims, **params):
            return _opt_barrier_p.bind(*args), dims
        _batching.primitive_batchers[_opt_barrier_p] = _opt_barrier_batcher
except (ImportError, AttributeError):
    pass

# Below this many cells the scatter-free write paths (fused where / one-hot
# reduce) win on XLA CPU's fixed per-scatter cost; above it they would touch
# O(cells) per tick and break the lazy-traffic property (paper EQ2), so the
# O(touched) scatter forms are kept for rodent/human scales.
DENSE_CELLS_MAX = 1 << 16


def use_worklist(p: "BCPNNParams", override: bool | None = None) -> bool:
    """Size guard for the network-global worklist tick runtime.

    Above DENSE_CELLS_MAX cells per HCU the per-HCU vmapped
    gather->update->scatter forms make XLA copy the full scan-carried
    (H, R, C) planes per scatter, so rodent/human scales switch to the flat
    (H*R, C) worklist path (`repro.core.worklist`): in-place dynamic-slice
    loops (CPU) or the scalar-prefetch Pallas kernel (TPU) that touch only
    O(worklist) rows per tick. Below the threshold the toy sizes keep their
    current fused dense forms (same guard philosophy as DENSE_CELLS_MAX).
    ``override`` (the `worklist=` runtime argument) forces either path —
    tests use it to A/B the two on small sizes; both are bitwise-identical.
    """
    if override is not None:
        return bool(override)
    return p.rows * p.cols > DENSE_CELLS_MAX


def use_fused_rows(p: "BCPNNParams", override: bool | None = None) -> bool:
    """Guard for the fused (single-pass) worklist row phase.

    The fused row phase replaces the worklist backend's three-phase row
    update — staging gather loop, vmapped compute over every staged slot,
    writeback loop — with a fused stage+compute loop over the valid entries
    only (`worklist.fused_stage_compute` + the in-place writeback loop on
    CPU, `ops.fused_row_update`'s scalar-prefetch megakernel on TPU). It only
    ever applies inside `engine.WorklistBackend`, so `use_worklist`'s
    R*C > DENSE_CELLS_MAX size guard is its size guard too: the dense forms
    at small scale are untouched. ``override`` (the `fused=` runtime
    argument) forces either form — tests use it to A/B the fused pass
    against the split loops; both are bitwise-identical
    (tests/test_worklist.py, tests/test_engine_fixtures.py).
    """
    if override is not None:
        return bool(override)
    return True


def use_fused_cols(p: "BCPNNParams", override: bool | None = None) -> bool:
    """Guard for the fused (single-pass) worklist column phase.

    The column twin of `use_fused_rows`: replaces the worklist backend's
    three-phase lazy column update — `worklist.read_cols` staging loop,
    vmapped compute over every fired-batch slot, `worklist.write_cols`
    writeback — with a fused stage+compute loop over the n_fired valid
    entries only (`worklist.fused_col_stage_compute` + the in-place
    writeback loop on CPU, `ops.fused_col_update`'s scalar-prefetch
    megakernel on TPU). Applies only inside `engine.WorklistBackend`'s LAZY
    mode — the merged column flush keeps its shared `merged_col_math` island
    untouched — so `use_worklist`'s size guard is its size guard too.
    ``override`` (the `fused_cols=` runtime argument) forces either form —
    tests use it to A/B the fused pass against the staged loops; both are
    bitwise-identical (tests/test_worklist.py, tests/test_engine_fixtures.py).
    """
    if override is not None:
        return bool(override)
    return True


class HCUState(NamedTuple):
    # synaptic ij-matrix planes, (R, C)
    zij: jnp.ndarray
    eij: jnp.ndarray
    pij: jnp.ndarray
    wij: jnp.ndarray
    tij: jnp.ndarray      # int32 timestamps (ms)
    # presynaptic i-vector, (R,) each — lazy, timestamped
    zi: jnp.ndarray
    ei: jnp.ndarray
    pi: jnp.ndarray
    ti: jnp.ndarray       # int32
    # postsynaptic j-vector, (C,) each — always current
    zj: jnp.ndarray
    ej: jnp.ndarray
    pj: jnp.ndarray
    # support membrane, (C,)
    h: jnp.ndarray


def coeffs_ij(p: BCPNNParams):
    return make_coeffs(p.tau_z_ij, p.tau_e, p.tau_p)


def coeffs_i(p: BCPNNParams):
    return make_coeffs(p.tau_zi, p.tau_e, p.tau_p)


def coeffs_j(p: BCPNNParams):
    return make_coeffs(p.tau_zj, p.tau_e, p.tau_p)


def init_hcu_state(p: BCPNNParams, dtype=jnp.float32) -> HCUState:
    R, C = p.rows, p.cols
    z0 = jnp.zeros((R, C), dtype)
    pij0 = jnp.full((R, C), p.p_init * p.p_init, dtype)
    pi0 = jnp.full((R,), p.p_init, dtype)
    pj0 = jnp.full((C,), p.p_init, dtype)
    w0 = jnp.log((pij0 + p.eps**2) / ((pi0[:, None] + p.eps) * (pj0[None, :] + p.eps)))
    return HCUState(
        zij=z0, eij=jnp.zeros((R, C), dtype), pij=pij0, wij=w0.astype(dtype),
        tij=jnp.zeros((R, C), jnp.int32),
        zi=jnp.zeros((R,), dtype), ei=jnp.zeros((R,), dtype), pi=pi0,
        ti=jnp.zeros((R,), jnp.int32),
        zj=jnp.zeros((C,), dtype), ej=jnp.zeros((C,), dtype), pj=pj0,
        h=jnp.zeros((C,), dtype),
    )


def init_hcu_batch(p: BCPNNParams, n_hcu: int, dtype=jnp.float32) -> HCUState:
    """Network HCU batch in the CANONICAL FLAT layout (`repro.core.layout`):
    ij planes (H*R, C), i-vectors (H*R,), j-vectors/support (H, C).

    This is the layout `NetworkState.hcus` stores and the worklist tick
    engine consumes natively; per-HCU vmapped code gets the (H, R, C) view
    via `layout.batched_state`. The initial values are identical to tiling
    `init_hcu_state` n_hcu times (the init has no per-HCU variation).
    """
    s = init_hcu_state(p, dtype)
    tile2 = lambda x: jnp.tile(x, (n_hcu, 1))          # (R, C) -> (H*R, C)
    tile1 = lambda x: jnp.tile(x, n_hcu)               # (R,)   -> (H*R,)
    rep = lambda x: jnp.broadcast_to(x, (n_hcu,) + x.shape).copy()
    return HCUState(
        zij=tile2(s.zij), eij=tile2(s.eij), pij=tile2(s.pij),
        wij=tile2(s.wij), tij=tile2(s.tij),
        zi=tile1(s.zi), ei=tile1(s.ei), pi=tile1(s.pi), ti=tile1(s.ti),
        zj=rep(s.zj), ej=rep(s.ej), pj=rep(s.pj), h=rep(s.h),
    )


def dedup_rows(rows: jnp.ndarray, n_rows: int):
    """Aggregate duplicate row indices in a fixed-size spike slot array.

    rows: (A,) int32, padding slots == n_rows (out of range).
    Returns (unique_rows, counts): duplicates are merged into the first
    occurrence (count = multiplicity); non-first duplicates and padding become
    index n_rows with count 0, which gathers clipped (harmless) and scatters
    dropped (JAX OOB-scatter drop semantics).
    """
    # O(A log A) sort + segment bounds via cummax/cummin (replaces the old
    # all-pairs O(A^2) comparison matrix; scatter-free — each segment's
    # count is its end bound minus its start bound)
    A = rows.shape[0]
    a = jnp.sort(rows)
    idx = jnp.arange(A)
    brk = a[1:] != a[:-1]
    first = jnp.concatenate([jnp.array([True]), brk])
    last = jnp.concatenate([brk, jnp.array([True])])
    start = jax.lax.cummax(jnp.where(first, idx, 0))
    end = jax.lax.cummin(jnp.where(last, idx + 1, A), reverse=True)
    counts = (end - start).astype(jnp.float32)             # multiplicity per slot
    keep = first & (a < n_rows)
    rows_u = jnp.where(keep, a, n_rows)
    counts_u = jnp.where(keep, counts, 0.0)
    return rows_u, counts_u


def _decay_jvec(st: HCUState, p: BCPNNParams) -> HCUState:
    """Per-tick exact decay of the locally-held j-vector."""
    zep = decay_zep(ZEP(st.zj, st.ej, st.pj), p.dt_ms, coeffs_j(p))
    return st._replace(zj=zep.z, ej=zep.e, pj=zep.p)


def ivec_decay(zi_g, ei_g, pi_g, ti_g, now, p: BCPNNParams) -> ZEP:
    """Lazy decay of gathered i-vector traces to `now`, as a sealed fusion
    island (optimization barriers on inputs and outputs).

    Shared by the per-HCU vmap paths (`row_updates`,
    `engine.column_updates_batched`, merged) and the worklist paths: the
    seal keeps XLA from contracting the decay's mul+add chains into FMAs
    differently depending on the fused producer/consumer (plane gather vs
    staged buffer), which would diverge the two paths at the 1-ulp level.
    """
    zi_g, ei_g, pi_g, ti_g = jax.lax.optimization_barrier(
        (zi_g, ei_g, pi_g, ti_g))
    d_i = (now - ti_g).astype(zi_g.dtype)
    zep = decay_zep(ZEP(zi_g, ei_g, pi_g), d_i, coeffs_i(p))
    return ZEP(*jax.lax.optimization_barrier(tuple(zep)))


def row_updates(st: HCUState, rows: jnp.ndarray, now, p: BCPNNParams,
                backend: str | None = None):
    """Apply lazy row updates for incoming spikes.

    rows: (A,) int32 row indices, padding == p.rows. `now` int32 scalar (ms).
    Assumes the j-vector has already been decayed to `now` this tick.
    Returns (state', w_rows, counts, rows_u) — w_rows are the freshly updated
    Bayesian weight rows used by the periodic support computation.
    """
    R = p.rows
    rows_u, counts = dedup_rows(rows, R)
    safe = jnp.minimum(rows_u, R - 1)

    # --- i-vector lazy decay + spike increment for the touched rows --------
    zep_i = ivec_decay(st.zi[safe], st.ei[safe], st.pi[safe], st.ti[safe],
                       now, p)
    zi_new = zep_i.z + counts
    # --- ij-matrix row update (the fused kernel) ---------------------------
    g = lambda plane: plane[safe]            # (A, C) gathered rows
    z1, e1, p1, w1, t1 = ops.row_update(
        g(st.zij), g(st.eij), g(st.pij), g(st.tij), now,
        counts, st.zj, zep_i.p, st.pj, coeffs_ij(p), p.eps, backend=backend,
        wij=g(st.wij))

    st = write_rows(st, rows_u, now, p, z1, e1, p1, w1,
                    zi_new, zep_i.e, zep_i.p)
    return st, w1, counts, rows_u


def write_rows(st: HCUState, rows_u, now, p: BCPNNParams,
               zij, eij, pij, wij, zi, ei, pi) -> HCUState:
    """Write back a row update: (A, C) plane rows + (A,) i-vector entries at
    `rows_u` (padding == p.rows dropped), stamping Tij/ti to `now`.

    Two bitwise-identical branches (shared by lazy and merged row updates):
    below DENSE_CELLS_MAX the timestamp writes are fused wheres and the
    i-vector writes are fused one-hot reduces (exactly one hit per touched
    row, so the select is bit-exact) — XLA CPU scatters carry a high fixed
    per-op cost, and these were 5 of the 9 scatters on the tick hot path.
    At scale the O(touched)-traffic scatter forms are kept (paper EQ2).
    """
    R = p.rows
    scat = lambda plane, val: plane.at[rows_u].set(val, mode="drop")
    if R * p.cols <= DENSE_CELLS_MAX:
        onehot = (rows_u[:, None] == jnp.arange(R)[None, :])   # (A, R)
        touched = jnp.any(onehot, axis=0)
        ohf = onehot.astype(st.zi.dtype)
        # sum-of-products (not a matvec: a fused bcast-mul + reduce avoids
        # the tiny-matmul fixed cost on CPU); one nonzero per column
        blendv = lambda vec, val: jnp.where(
            touched, jnp.sum(val[:, None] * ohf, axis=0), vec)
        return st._replace(
            zij=scat(st.zij, zij), eij=scat(st.eij, eij),
            pij=scat(st.pij, pij), wij=scat(st.wij, wij),
            tij=jnp.where(touched[:, None], now, st.tij),
            zi=blendv(st.zi, zi), ei=blendv(st.ei, ei),
            pi=blendv(st.pi, pi),
            ti=jnp.where(touched, now, st.ti),
        )
    return st._replace(
        zij=scat(st.zij, zij), eij=scat(st.eij, eij), pij=scat(st.pij, pij),
        wij=scat(st.wij, wij),
        tij=scat(st.tij, jnp.full((rows_u.shape[0], p.cols), now, jnp.int32)),
        zi=st.zi.at[rows_u].set(zi, mode="drop"),
        ei=st.ei.at[rows_u].set(ei, mode="drop"),
        pi=st.pi.at[rows_u].set(pi, mode="drop"),
        ti=st.ti.at[rows_u].set(jnp.full(rows_u.shape, now, st.ti.dtype),
                                mode="drop"),
    )


def periodic_math(h_vec, pj, w_rows, counts, now, key, p: BCPNNParams):
    """Support integration + soft WTA on the raw (C,) leaves.

    The leaf-level form of `periodic_update`: the engine vmaps THIS over
    (h, pj) network planes so the flat canonical state never has to be
    regrouped into per-HCU NamedTuples just to run the WTA. Same ops, same
    RNG stream as the per-HCU wrapper.
    Returns (h', fired_j).
    """
    decay_m = jnp.exp(-p.dt_ms / p.tau_m)
    drive = jnp.sum(counts[:, None] * w_rows, axis=0)          # (C,)
    h = h_vec * decay_m + drive
    s = h + bias(pj, p.eps)
    # soft WTA: fire with prob out_rate*dt; winner ~ softmax(s / T)
    k_gate, k_win = jax.random.split(key)
    fire = jax.random.uniform(k_gate) < p.out_rate * p.dt_ms
    winner = jax.random.categorical(k_win, s / p.wta_temp)
    fired_j = jnp.where(fire, winner, -1).astype(jnp.int32)
    return h, fired_j


def periodic_update(st: HCUState, w_rows, counts, now, key, p: BCPNNParams):
    """Support integration + soft WTA (paper's 'periodic update', every ms).

    w_rows (A, C): freshly recomputed weight rows of this tick's spikes.
    Returns (state', fired_j) with fired_j == -1 when the HCU stays silent.
    """
    h, fired_j = periodic_math(st.h, st.pj, w_rows, counts, now, key, p)
    return st._replace(h=h), fired_j


def column_update(st: HCUState, j: jnp.ndarray, now, p: BCPNNParams,
                  backend: str | None = None) -> HCUState:
    """Apply the lazy column update for output spike at MCU column ``j``.

    Always computes (static shapes); masked to a no-op when j < 0. The paper
    splits the column into 100 row-sized chunks — here the kernel grid does.
    """
    active = j >= 0
    safe_j = jnp.maximum(j, 0)
    # presynaptic traces brought to `now` on the fly (no writeback: values
    # only, i-vector stays lazy — avoids a (R,) scatter per output spike)
    d_i = (now - st.ti).astype(st.zi.dtype)
    zep_i = decay_zep(ZEP(st.zi, st.ei, st.pi), d_i, coeffs_i(p))

    # gather/scatter along the last axis directly — the transpose round trip
    # (`plane.T.at[j].set(..).T`) materialized two full (R, C) copies per call
    g = lambda plane: jax.lax.dynamic_index_in_dim(plane, safe_j, 1, False)
    z1, e1, p1, w1, t1 = ops.col_update(
        g(st.zij), g(st.eij), g(st.pij), g(st.tij), now,
        zep_i.z, zep_i.p, st.pj[safe_j], coeffs_ij(p), p.eps, backend=backend,
        w_col=g(st.wij))

    def put(plane, val):
        col = jax.lax.dynamic_index_in_dim(plane, safe_j, 1, False)
        new = jnp.where(active, val, col)
        return plane.at[:, safe_j].set(new)

    st = st._replace(zij=put(st.zij, z1), eij=put(st.eij, e1),
                     pij=put(st.pij, p1), wij=put(st.wij, w1),
                     tij=put(st.tij, t1))
    # postsynaptic Z increment AFTER the column used pre-increment zj
    zj = st.zj.at[safe_j].add(jnp.where(active, 1.0, 0.0))
    return st._replace(zj=zj)


def hcu_tick_pre(st: HCUState, rows, now, key, p: BCPNNParams,
                 backend: str | None = None):
    """j-vector decay + row updates + periodic/WTA (vmap-able part of a tick).

    The column update is batched across HCUs at network level (only fired
    HCUs pay for it) — see engine.column_updates_batched.
    """
    st = _decay_jvec(st, p)
    st, w_rows, counts, _ = row_updates(st, rows, now, p, backend=backend)
    st, fired_j = periodic_update(st, w_rows, counts, now, key, p)
    return st, fired_j


def flush(st: HCUState, now, p: BCPNNParams) -> HCUState:
    """Bring every lazy trace current to `now` (checkpoint/inspection/tests).

    Equivalent to the paper's implicit end-of-run synchronization; after a
    flush, lazy and eager states are directly comparable plane-by-plane.
    """
    kij, ki = coeffs_ij(p), coeffs_i(p)
    d_ij = (now - st.tij).astype(st.zij.dtype)
    zep = decay_zep(ZEP(st.zij, st.eij, st.pij), d_ij, kij)
    d_i = (now - st.ti).astype(st.zi.dtype)
    zi = decay_zep(ZEP(st.zi, st.ei, st.pi), d_i, ki)
    w = jnp.log((zep.p + p.eps**2)
                / ((zi.p[:, None] + p.eps) * (st.pj[None, :] + p.eps)))
    return st._replace(
        zij=zep.z, eij=zep.e, pij=zep.p, wij=w,
        tij=jnp.full_like(st.tij, now),
        zi=zi.z, ei=zi.e, pi=zi.p, ti=jnp.full_like(st.ti, now))
