"""Distributed BCPNN runtime: shard_map over HCUs + all_to_all spike exchange.

Paper mapping (§III.A, §VI.E): the eBrainII hierarchy is
    BCU (chip)  >  H-Cube (vault, P=4 HCUs)  >  HCU
with a pipelined binary-tree spike NoC inside a BCU. On a TPU pod the
hierarchy becomes
    pod  >  chip  >  local HCU batch (vmap)
and the spike NoC becomes a bucketed `jax.lax.all_to_all` over the mesh —
justified by the paper's own observation that spike traffic is three orders
of magnitude below synaptic bandwidth, so a fixed-capacity exchange sits far
below the ICI roofline (see EXPERIMENTS.md roofline: collective term).

Because every HCU's state is self-contained ("no memory consistency
problem", §II.B), HCU shards are freely relocatable: elastic re-sharding and
failure recovery move whole HCUs between devices without any consistency
protocol (see repro.runtime.elastic).

Two drivers, same per-device tick body (`_local_tick`):
  * make_dist_tick — one compiled sharded tick per call (host loop);
  * make_dist_run  — the scan-compiled twin of `network.network_run`: the
    whole pre-staged (T, H, A_ext) input runs in ONE compiled computation,
    all_to_all exchanges included — zero host round-trips per tick.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import inspect

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.6 exposes shard_map at top level
    from jax import shard_map as _shard_map
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

# replication checking kwarg was renamed check_rep -> check_vma across jax
# versions; resolve whichever this jax has (disabled either way: the spike
# exchange's all_to_all is deliberately unreplicated).
_CHECK_KW = ("check_vma" if "check_vma"
             in inspect.signature(_shard_map).parameters else "check_rep")


def shard_map(f, mesh, in_specs, out_specs):
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: False})

from repro.core import hcu as H
from repro.core import network as N
from repro.core.params import BCPNNParams


class RouteConfig(NamedTuple):
    """Static capacities of the spike exchange."""
    cap_fire: int        # max simultaneously fired HCUs per device per tick
    cap_route: int       # max messages per (src dev -> dst dev) pair per tick
    pack: bool = True    # pack each spike into one int32 (paper Fig 3 format)


def default_route_config(p: BCPNNParams, h_local: int,
                         n_dev: int | None = None) -> RouteConfig:
    """Dimension the exchange the way the paper dimensions its queues (§IV):
    Poisson-tail capacity with a months-scale drop budget, NOT worst case.

    Expected messages per (src dev -> dst dev) pair per tick:
        lam = out_rate * h_local * fanout / n_dev
    cap_route = smallest q with <= 1 expected drop/month at Poisson(lam)
    (overflows are counted in drops_fire — same budget discipline as the
    36-deep active queue).
    """
    from repro.core.queues import min_queue_for_monthly_drop_budget
    cap_fire = max(2, int(0.35 * h_local) + 1)
    if n_dev is None:
        return RouteConfig(cap_fire=cap_fire, cap_route=cap_fire * p.fanout)
    lam = max(p.out_rate * h_local * p.fanout / n_dev, 0.1)
    cap = min_queue_for_monthly_drop_budget(lam, budget=1.0, max_q=4096)
    cap = min(max(8, cap), cap_fire * p.fanout)
    return RouteConfig(cap_fire=cap_fire, cap_route=cap)


def _pack_bits(p: BCPNNParams, h_local: int):
    loc_bits = max((h_local - 1).bit_length(), 1)
    row_bits = (p.rows).bit_length()              # rows value == invalid marker
    dly_bits = max((p.max_delay - 1).bit_length(), 1)
    assert loc_bits + row_bits + dly_bits + 1 <= 31, "spike word overflow"
    return loc_bits, row_bits, dly_bits


def pack_spikes(dest_loc, dest_row, delay, valid, p: BCPNNParams,
                h_local: int):
    """One spike == one int32 word (paper Fig 3: dest HCU | row | delay)."""
    lb, rb, db = _pack_bits(p, h_local)
    w = (dest_loc & ((1 << lb) - 1))
    w = (w << rb) | (dest_row & ((1 << rb) - 1))
    w = (w << db) | (delay & ((1 << db) - 1))
    w = (w << 1) | valid.astype(jnp.int32)
    return w


def unpack_spikes(w, p: BCPNNParams, h_local: int):
    lb, rb, db = _pack_bits(p, h_local)
    valid = (w & 1) == 1
    delay = (w >> 1) & ((1 << db) - 1)
    dest_row = (w >> (1 + db)) & ((1 << rb) - 1)
    dest_loc = (w >> (1 + db + rb)) & ((1 << lb) - 1)
    return dest_loc, dest_row, delay, valid


def _local_tick(state: N.NetworkState, conn: N.Connectivity,
                ext_rows: jnp.ndarray, p: BCPNNParams, rc: RouteConfig,
                axis, eager: bool, backend, worklist: bool | None = None):
    """Per-device body executed under shard_map."""
    h_local = state.delay_rows.shape[0]
    ndev = jax.lax.psum(1, axis)
    dev = jax.lax.axis_index(axis)
    t = state.t + 1

    # ---- consume bucket, row updates, WTA (identical to single-device) ----
    state, bucket = N.consume_bucket(state, t, p, h_local)
    rows = jnp.concatenate([bucket, ext_rows], axis=1)

    k_t = jax.random.fold_in(state.base_key, t)
    # RNG folded by GLOBAL hcu id => invariant to device count (elasticity)
    gids = dev * h_local + jnp.arange(h_local)
    keys = jax.vmap(lambda g: jax.random.fold_in(k_t, g))(gids)
    if eager:
        hcus, fired = jax.vmap(
            lambda s, r, k: N.reference.eager_tick(s, r, t, k, p)
        )(state.hcus, rows, keys)
        h_idx, j_idx, n_drop = N._select_fired(fired, rc.cap_fire)
    else:
        # vmap path or flat-plane worklist path by size guard — the same
        # shared body as the single-device tick, so sharded trajectories
        # stay bitwise-identical across the two forms. Columns here are
        # unconditional (no lax.cond), matching the historical sharded tick.
        hcus, fired, h_idx, j_idx, n_drop = N.lazy_batch_update(
            state.hcus, rows, t, keys, p, rc.cap_fire, backend=backend,
            worklist=worklist, cond_columns=False)
    state = state._replace(hcus=hcus, t=t,
                           drops_fire=state.drops_fire + n_drop)

    # ---- fan out: build per-destination-device buckets -------------------
    safe_h = jnp.minimum(h_idx, h_local - 1)
    dest_h = conn.dest_hcu[safe_h, j_idx].reshape(-1)       # global ids (K*F,)
    dest_r = conn.dest_row[safe_h, j_idx].reshape(-1)
    dly = conn.delay[safe_h, j_idx].reshape(-1)
    valid = jnp.repeat(h_idx < h_local, p.fanout)

    dest_dev = dest_h // h_local
    dest_loc = dest_h % h_local
    key = jnp.where(valid, dest_dev, ndev)
    rank = N._rank_within_key(key)
    ok = valid & (rank < rc.cap_route)
    route_drops = jnp.sum(valid) - jnp.sum(ok)
    flat = jnp.where(ok, dest_dev * rc.cap_route + rank, ndev * rc.cap_route)

    def bucketize(vals, fill):
        buf = jnp.full((ndev * rc.cap_route,), fill, jnp.int32)
        return buf.at[flat].set(vals, mode="drop").reshape(ndev, rc.cap_route)

    if rc.pack:
        # one int32 per spike (paper Fig 3 spike word): 4x less ICI traffic
        words = pack_spikes(dest_loc, dest_r, dly, ok, p, h_local)
        send = bucketize(jnp.where(ok, words, 0), 0)   # (ndev, cap_route)
        recv = jax.lax.all_to_all(send, axis, split_axis=0, concat_axis=0,
                                  tiled=False).reshape(ndev * rc.cap_route)
        d_loc, d_row, d_dly, d_ok = unpack_spikes(recv, p, h_local)
        state = N.enqueue_spikes(state, d_loc, d_row, d_dly, d_ok, p,
                                 h_local)
    else:
        send = jnp.stack([
            bucketize(dest_loc, 0),
            bucketize(dest_r, p.rows),        # p.rows == invalid row marker
            bucketize(dly, 1),
            bucketize(jnp.where(ok, 1, 0).astype(jnp.int32), 0),
        ], axis=-1)                            # (ndev, cap_route, 4)
        recv = jax.lax.all_to_all(send, axis, split_axis=0, concat_axis=0,
                                  tiled=False).reshape(ndev * rc.cap_route, 4)
        state = N.enqueue_spikes(
            state, recv[:, 0], recv[:, 1], recv[:, 2],
            recv[:, 3] == 1, p, h_local)
    return state._replace(drops_fire=state.drops_fire + route_drops), fired


def _shard_specs(axes):
    """(state, conn, per-HCU, replicated) PartitionSpecs for an HCU shard."""
    spec_h = P(axes)      # shard leading (HCU) dim over the flattened axes
    rep = P()
    state_specs = N.NetworkState(
        hcus=H.HCUState(*([spec_h] * len(H.HCUState._fields))),
        delay_rows=spec_h, delay_count=spec_h,
        t=rep, drops_in=rep, drops_fire=rep, base_key=rep)
    conn_specs = N.Connectivity(spec_h, spec_h, spec_h)
    return state_specs, conn_specs, spec_h, rep


def make_dist_tick(mesh: Mesh, p: BCPNNParams, rc: RouteConfig,
                   axis="hcu", eager: bool = False,
                   backend: str | None = None, donate: bool = True,
                   worklist: bool | None = None):
    """Build the sharded tick: state/conn/ext sharded over `axis`, which may
    be a single mesh axis name or a tuple of axis names (flattened).
    `worklist` forces the flat-plane worklist update path on/off (default:
    auto by size, `hcu.use_worklist`)."""
    axes = axis if isinstance(axis, tuple) else (axis,)
    state_specs, conn_specs, spec_h, _ = _shard_specs(axes)

    fn = shard_map(
        functools.partial(_local_tick, p=p, rc=rc, axis=axes,
                          eager=eager, backend=backend, worklist=worklist),
        mesh=mesh,
        in_specs=(state_specs, conn_specs, spec_h),
        out_specs=(state_specs, spec_h),
    )
    # donating the state lets XLA scatter the touched rows/columns in place
    # — the lazy model's bytes-per-tick then match the paper's traffic
    # budget instead of copying whole synaptic planes (§Perf iteration)
    return jax.jit(fn, donate_argnums=(0,) if donate else ())


def make_dist_run(mesh: Mesh, p: BCPNNParams, rc: RouteConfig,
                  axis="hcu", eager: bool = False,
                  backend: str | None = None, donate: bool = True,
                  worklist: bool | None = None):
    """Scan-compiled multi-tick sharded driver (network_run's sharded twin).

    Returns fn(state, conn, ext) -> (state', fired (T, H)) where ext is the
    pre-staged (T, H, A_ext) tensor sharded on the HCU axis. The whole
    T-tick loop — including the per-tick all_to_all spike exchange — runs
    inside ONE compiled computation: zero host round-trips, exactly the
    per-tick trajectory of `make_dist_tick` applied T times. At worklist
    scales (`hcu.use_worklist`, or forced via `worklist=`) each device's
    plane updates run through the in-place flat-plane worklist loops, so
    per-device traffic per tick is O(touched rows) instead of O(planes).
    """
    axes = axis if isinstance(axis, tuple) else (axis,)
    state_specs, conn_specs, spec_h, _ = _shard_specs(axes)
    ext_spec = P(None, axes)            # (T, H_local, A): time replicated
    fired_spec = P(None, axes)

    def _local_run(state, conn, ext):
        def body(s, e):
            return _local_tick(s, conn, e, p=p, rc=rc, axis=axes,
                               eager=eager, backend=backend,
                               worklist=worklist)
        return jax.lax.scan(body, state, ext)

    fn = shard_map(
        _local_run,
        mesh=mesh,
        in_specs=(state_specs, conn_specs, ext_spec),
        out_specs=(state_specs, fired_spec),
    )
    return jax.jit(fn, donate_argnums=(0,) if donate else ())


def shard_network(mesh: Mesh, state: N.NetworkState, conn: N.Connectivity,
                  axis="hcu"):
    """Place an (already materialized) network onto the mesh."""
    axes = axis if isinstance(axis, tuple) else (axis,)
    spec_h, rep = P(axes), P()
    sh = lambda spec: lambda x: jax.device_put(x, NamedSharding(mesh, spec))
    state = N.NetworkState(
        hcus=jax.tree.map(sh(spec_h), state.hcus),
        delay_rows=sh(spec_h)(state.delay_rows),
        delay_count=sh(spec_h)(state.delay_count),
        t=sh(rep)(state.t), drops_in=sh(rep)(state.drops_in),
        drops_fire=sh(rep)(state.drops_fire), base_key=sh(rep)(state.base_key))
    conn = jax.tree.map(sh(spec_h), conn)
    return state, conn
