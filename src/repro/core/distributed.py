"""Distributed BCPNN runtime: shard_map over HCUs + all_to_all spike exchange.

Paper mapping (§III.A, §VI.E): the eBrainII hierarchy is
    BCU (chip)  >  H-Cube (vault, P=4 HCUs)  >  HCU
with a pipelined binary-tree spike NoC inside a BCU. On a TPU pod the
hierarchy becomes
    pod  >  chip  >  local HCU batch (vmap)
and the spike NoC becomes the capacity-bounded sparse exchange
(`SparseExchange`): only fired (dest, row, delay) triples travel, packed one
int32 per spike into per-destination buckets sized by the Fig 7 Poisson
math (`default_route_config`), shipped with one `jax.lax.all_to_all` per
tick that the engine issues BEFORE the column plane phase and consumes
after it (latency overlap). Justified by the paper's own observation that
spike traffic is three orders of magnitude below synaptic bandwidth, so the
exchange sits far below the ICI roofline — measured against that bound by
`benchmarks/weak_scaling.py` (see `launch/roofline.py` collective term).

Because every HCU's state is self-contained ("no memory consistency
problem", §II.B), HCU shards are freely relocatable: elastic re-sharding and
failure recovery move whole HCUs between devices without any consistency
protocol (see repro.runtime.elastic).

Engine routing (PR 3)
---------------------
The per-device tick is `repro.core.engine.tick` — the SAME body every local
driver runs — with two shard-specific parameters:

  * ``gid_base = device_index * h_local`` so the per-HCU RNG stream folds
    GLOBAL HCU ids (trajectories invariant to device count, the elasticity
    contract);
  * ``route`` = the pack + all_to_all spike exchange defined here
    (`SparseExchange`), replacing the local direct enqueue; its split
    send/recv phases bracket the column plane update so the collective is
    in flight while columns run (`overlap=`, default on — bitwise the same
    trajectory as the sequential exchange).

This module therefore contains ONLY spike pack/exchange and shard plumbing —
no tick math. The sharded worklist path (rodent/human scales) comes for free
from `engine.WorklistBackend`: each device's scan carry is its local slice
of the canonical flat (H*R, C) planes, updated in place, O(touched rows) per
device per tick. The canonical flat layout shards exactly like the batched
one did (leading axis = h_local * R rows per device).

Two drivers, same per-device tick body:
  * make_dist_tick — one compiled sharded tick per call (host loop);
  * make_dist_run  — the scan-compiled twin of `network.network_run`: the
    whole pre-staged (T, H, A_ext) input runs in ONE compiled computation,
    all_to_all exchanges included — zero host round-trips per tick.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import inspect

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.6 exposes shard_map at top level
    from jax import shard_map as _shard_map
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

# replication checking kwarg was renamed check_rep -> check_vma across jax
# versions; resolve whichever this jax has (disabled either way: the spike
# exchange's all_to_all is deliberately unreplicated).
_CHECK_KW = ("check_vma" if "check_vma"
             in inspect.signature(_shard_map).parameters else "check_rep")


def shard_map(f, mesh, in_specs, out_specs):
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: False})

from repro.core import engine as E
from repro.core import hcu as H
from repro.core import network as N
from repro.core.params import BCPNNParams


class RouteConfig(NamedTuple):
    """Static capacities of the spike exchange."""
    cap_fire: int        # max simultaneously fired HCUs per device per tick
    cap_route: int       # max messages per (src dev -> dst dev) pair per tick
    pack: bool = True    # pack each spike into one int32 (paper Fig 3 format)


def default_route_config(p: BCPNNParams, h_local: int,
                         n_dev: int | None = None) -> RouteConfig:
    """Dimension the exchange the way the paper dimensions its queues (§IV):
    Poisson-tail capacity with a months-scale drop budget, NOT worst case.

    Expected messages per (src dev -> dst dev) pair per tick:
        lam = out_rate * h_local * fanout / n_dev
    cap_route = smallest q with <= 1 expected drop/month at Poisson(lam)
    (overflows are counted in drops_fire — same budget discipline as the
    36-deep active queue).
    """
    from repro.core.queues import min_queue_for_monthly_drop_budget
    cap_fire = max(2, int(0.35 * h_local) + 1)
    if n_dev is None:
        return RouteConfig(cap_fire=cap_fire, cap_route=cap_fire * p.fanout)
    lam = max(p.out_rate * h_local * p.fanout / n_dev, 0.1)
    cap = min_queue_for_monthly_drop_budget(lam, budget=1.0, max_q=4096)
    cap = min(max(8, cap), cap_fire * p.fanout)
    return RouteConfig(cap_fire=cap_fire, cap_route=cap)


def lossless_route_config(p: BCPNNParams, h_local: int) -> RouteConfig:
    """Worst-case exchange dimensioning: capacity never binds (every device
    can fire all of its HCUs and route their entire fanout to one peer), so
    the exchange drops nothing and — because padded route slots carry no
    trajectory-relevant bits — the logical trajectory is bitwise invariant
    to the mesh shape. This is the elasticity contract `ElasticRunner`
    relies on when it remaps HCUs onto a smaller mesh (`RouteConfig` is
    re-derived per device count; see docs/RESILIENCE.md)."""
    return RouteConfig(cap_fire=max(h_local, 1),
                       cap_route=max(h_local, 1) * p.fanout)


def _pack_bits(p: BCPNNParams, h_local: int):
    loc_bits = max((h_local - 1).bit_length(), 1)
    row_bits = (p.rows).bit_length()              # rows value == invalid marker
    dly_bits = max((p.max_delay - 1).bit_length(), 1)
    assert loc_bits + row_bits + dly_bits + 1 <= 31, "spike word overflow"
    return loc_bits, row_bits, dly_bits


def pack_spikes(dest_loc, dest_row, delay, valid, p: BCPNNParams,
                h_local: int):
    """One spike == one int32 word (paper Fig 3: dest HCU | row | delay)."""
    lb, rb, db = _pack_bits(p, h_local)
    w = (dest_loc & ((1 << lb) - 1))
    w = (w << rb) | (dest_row & ((1 << rb) - 1))
    w = (w << db) | (delay & ((1 << db) - 1))
    w = (w << 1) | valid.astype(jnp.int32)
    return w


def unpack_spikes(w, p: BCPNNParams, h_local: int):
    lb, rb, db = _pack_bits(p, h_local)
    valid = (w & 1) == 1
    delay = (w >> 1) & ((1 << db) - 1)
    dest_row = (w >> (1 + db)) & ((1 << rb) - 1)
    dest_loc = (w >> (1 + db + rb)) & ((1 << lb) - 1)
    return dest_loc, dest_row, delay, valid


class SparseExchange:
    """Split-phase sparse spike routing: the distributed tick's spike NoC.

    Only fired work travels. `send` compacts the fired batch's fanout into
    per-destination capacity-bounded buckets of packed (dest, row, delay)
    spike words — sized by `default_route_config`'s Fig 7 Poisson-tail
    dimensioning, overflow counted into the `drops_route` Fig 7 class — and
    issues the all_to_all. `recv` unpacks the delivered words and enqueues
    them into the local delay queues.

    `engine.tick` drives the two phases around the column plane update
    (send -> columns -> recv), so the collective is in flight while the
    column plane traffic runs — the paper's bandwidth asymmetry (§I: spike
    traffic is ~3 orders of magnitude below synaptic traffic) makes the
    exchange the cheap side of that overlap. Neither phase reads what the
    other writes (exchange: delay queues + drop counters; columns: ij
    planes), so the overlapped trajectory is bitwise the sequential one —
    calling the object itself runs send+recv back-to-back (the pre-overlap
    exchange, kept as the `overlap=False` A/B escape hatch).
    """

    def __init__(self, p: BCPNNParams, rc: RouteConfig, axis, ndev, h_local):
        self.p, self.rc, self.axis = p, rc, axis
        self.ndev, self.h_local = ndev, h_local

    def send(self, state, dest_h, dest_r, dly, valid, p_, n_):
        p, rc, ndev, h_local = self.p, self.rc, self.ndev, self.h_local
        dest_dev = dest_h // h_local
        dest_loc = dest_h % h_local
        key = jnp.where(valid, dest_dev, ndev)
        rank = N._rank_within_key(key)
        ok = valid & (rank < rc.cap_route)
        route_drops = jnp.sum(valid) - jnp.sum(ok)
        flat = jnp.where(ok, dest_dev * rc.cap_route + rank,
                         ndev * rc.cap_route)

        def bucketize(vals, fill):
            buf = jnp.full((ndev * rc.cap_route,), fill, jnp.int32)
            return buf.at[flat].set(vals, mode="drop").reshape(ndev,
                                                               rc.cap_route)

        if rc.pack:
            # one int32 per spike (paper Fig 3 spike word): 4x less ICI
            # traffic
            words = pack_spikes(dest_loc, dest_r, dly, ok, p, h_local)
            send = bucketize(jnp.where(ok, words, 0), 0)  # (ndev, cap_route)
        else:
            send = jnp.stack([
                bucketize(dest_loc, 0),
                bucketize(dest_r, p.rows),    # p.rows == invalid row marker
                bucketize(dly, 1),
                bucketize(jnp.where(ok, 1, 0).astype(jnp.int32), 0),
            ], axis=-1)                        # (ndev, cap_route, 4)
        recv = jax.lax.all_to_all(send, self.axis, split_axis=0,
                                  concat_axis=0, tiled=False)
        # route-capacity overflow is its own Fig 7 class (drops_route), not
        # fired-batch overflow: HealthMonitor budgets the two separately
        state = state._replace(drops_route=state.drops_route + route_drops)
        return state, recv

    def recv(self, state, inflight, p_, n_):
        p, rc, ndev, h_local = self.p, self.rc, self.ndev, self.h_local
        if rc.pack:
            recv = inflight.reshape(ndev * rc.cap_route)
            d_loc, d_row, d_dly, d_ok = unpack_spikes(recv, p, h_local)
            return N.enqueue_spikes(state, d_loc, d_row, d_dly, d_ok, p,
                                    h_local)
        recv = inflight.reshape(ndev * rc.cap_route, 4)
        return N.enqueue_spikes(state, recv[:, 0], recv[:, 1], recv[:, 2],
                                recv[:, 3] == 1, p, h_local)

    def __call__(self, state, dest_h, dest_r, dly, valid, p_, n_):
        state, inflight = self.send(state, dest_h, dest_r, dly, valid,
                                    p_, n_)
        return self.recv(state, inflight, p_, n_)


def _exchange_route(p: BCPNNParams, rc: RouteConfig, axis, ndev, h_local,
                    overlap: bool = True):
    """Build the sharded spike-routing hook for `engine.tick`. With
    `overlap` (the default) this is the `SparseExchange` object itself and
    the tick runs it split around the column phase; without, a plain
    callable running the same exchange sequentially after columns — the
    historical route hook, bitwise the same trajectory."""
    ex = SparseExchange(p, rc, axis, ndev, h_local)
    if overlap:
        return ex

    def route(state, dest_h, dest_r, dly, valid, p_, n_):
        return ex(state, dest_h, dest_r, dly, valid, p_, n_)

    return route


def _local_tick(state: N.NetworkState, conn: N.Connectivity,
                ext_rows: jnp.ndarray, p: BCPNNParams, rc: RouteConfig,
                axis, be: "E.TickBackend", overlap: bool = True):
    """Per-device body executed under shard_map: `engine.tick` with the
    all_to_all spike route and a global-HCU-id RNG base. Columns run
    unconditionally (no lax.cond), matching the historical sharded tick."""
    h_local = state.delay_rows.shape[0]
    ndev = jax.lax.psum(1, axis)
    dev = jax.lax.axis_index(axis)
    return E.tick(state, conn, ext_rows, p, be, rc.cap_fire,
                  gid_base=dev * h_local,
                  route=_exchange_route(p, rc, axis, ndev, h_local,
                                        overlap=overlap),
                  cond_columns=False)


def _shard_specs(axes):
    """(state, conn, per-HCU, replicated) PartitionSpecs for an HCU shard.

    The canonical flat hcus leaves shard on their leading axis exactly like
    the batched ones did: device d owns flat rows [d*h_local*R,
    (d+1)*h_local*R) — whole HCUs, never split rows."""
    spec_h = P(axes)      # shard leading (HCU / H*R) dim over the axes
    rep = P()
    state_specs = N.NetworkState(
        hcus=H.HCUState(*([spec_h] * len(H.HCUState._fields))),
        delay_rows=spec_h, delay_count=spec_h,
        t=rep, drops_in=rep, drops_fire=rep, drops_route=rep, base_key=rep)
    conn_specs = N.Connectivity(spec_h, spec_h, spec_h)
    return state_specs, conn_specs, spec_h, rep


def make_dist_tick(mesh: Mesh, p: BCPNNParams, rc: RouteConfig,
                   axis="hcu", eager: bool = False,
                   backend: str | None = None, donate: bool = True,
                   worklist: bool | None = None,
                   fused: bool | None = None,
                   fused_cols: bool | None = None,
                   overlap: bool = True):
    """Build the sharded tick: state/conn/ext sharded over `axis`, which may
    be a single mesh axis name or a tuple of axis names (flattened).
    `worklist` forces the worklist engine backend on/off (default: auto by
    size, `hcu.use_worklist`); `fused` forces its single-pass fused row
    phase (default: on, `hcu.use_fused_rows`) and `fused_cols` its
    single-pass fused column phase (default: on, `hcu.use_fused_cols`).
    `overlap` (default on) issues the spike all_to_all before the column
    phase so its latency hides behind column traffic — bitwise the same
    trajectory as the sequential exchange (`SparseExchange`)."""
    axes = axis if isinstance(axis, tuple) else (axis,)
    state_specs, conn_specs, spec_h, _ = _shard_specs(axes)
    be = E.select_backend(p, eager=eager, worklist=worklist, kernel=backend,
                          fused=fused, fused_cols=fused_cols)

    def local(state, conn, ext):
        state, fired = _local_tick(be.carry_in(state, p), conn, ext,
                                   p=p, rc=rc, axis=axes, be=be,
                                   overlap=overlap)
        return be.carry_out(state, p), fired

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(state_specs, conn_specs, spec_h),
        out_specs=(state_specs, spec_h),
    )
    # donating the state lets XLA scatter the touched rows/columns in place
    # — the lazy model's bytes-per-tick then match the paper's traffic
    # budget instead of copying whole synaptic planes (§Perf iteration)
    return jax.jit(fn, donate_argnums=(0,) if donate else ())


def make_dist_run(mesh: Mesh, p: BCPNNParams, rc: RouteConfig,
                  axis="hcu", eager: bool = False,
                  backend: str | None = None, donate: bool = True,
                  worklist: bool | None = None,
                  fused: bool | None = None,
                  fused_cols: bool | None = None,
                  overlap: bool = True):
    """Scan-compiled multi-tick sharded driver (network_run's sharded twin).

    Returns fn(state, conn, ext) -> (state', fired (T, H)) where ext is the
    pre-staged (T, H, A_ext) tensor sharded on the HCU axis. The whole
    T-tick loop — including the per-tick all_to_all spike exchange — runs
    inside ONE compiled computation: zero host round-trips, exactly the
    per-tick trajectory of `make_dist_tick` applied T times. At worklist
    scales (`hcu.use_worklist`, or forced via `worklist=`) each device scans
    over its local slice of the canonical flat planes in place, so
    per-device traffic per tick is O(touched rows) instead of O(planes).
    """
    axes = axis if isinstance(axis, tuple) else (axis,)
    state_specs, conn_specs, spec_h, _ = _shard_specs(axes)
    ext_spec = P(None, axes)            # (T, H_local, A): time replicated
    fired_spec = P(None, axes)
    be = E.select_backend(p, eager=eager, worklist=worklist, kernel=backend,
                          fused=fused, fused_cols=fused_cols)

    def _local_run(state, conn, ext):
        def body(s, e):
            return _local_tick(s, conn, e, p=p, rc=rc, axis=axes, be=be,
                               overlap=overlap)
        state, fired = jax.lax.scan(body, be.carry_in(state, p), ext)
        return be.carry_out(state, p), fired

    fn = shard_map(
        _local_run,
        mesh=mesh,
        in_specs=(state_specs, conn_specs, ext_spec),
        out_specs=(state_specs, fired_spec),
    )
    return jax.jit(fn, donate_argnums=(0,) if donate else ())


def shard_network(mesh: Mesh, state: N.NetworkState, conn: N.Connectivity,
                  axis="hcu"):
    """Place an (already materialized) network onto the mesh."""
    axes = axis if isinstance(axis, tuple) else (axis,)
    spec_h, rep = P(axes), P()
    sh = lambda spec: lambda x: jax.device_put(x, NamedSharding(mesh, spec))
    state = N.NetworkState(
        hcus=jax.tree.map(sh(spec_h), state.hcus),
        delay_rows=sh(spec_h)(state.delay_rows),
        delay_count=sh(spec_h)(state.delay_count),
        t=sh(rep)(state.t), drops_in=sh(rep)(state.drops_in),
        drops_fire=sh(rep)(state.drops_fire),
        drops_route=(None if state.drops_route is None
                     else sh(rep)(state.drops_route)),
        base_key=sh(rep)(state.base_key))
    conn = jax.tree.map(sh(spec_h), conn)
    return state, conn
