"""Unified TickEngine: one tick pipeline behind pluggable plane backends.

The paper maps every BCU onto the *same* tiled compute fabric regardless of
scale (§III, §VI) — one update pipeline, parameterized by layout. This module
is that pipeline's software form. A network tick always has the same skeleton

    consume delay bucket -> plane update (rows / WTA / columns) -> fan out

and only the *plane update* differs by regime. The regimes are captured by
the `TickBackend` protocol with two implementations:

  * `DenseBackend`    — toy sizes: per-HCU `jax.vmap` over the batched
                        (H, R, C) view, with the fused dense write forms
                        (modes: "lazy", "eager" golden reference, "merged").
  * `WorklistBackend` — rodent/human scales: a network-global deduplicated
                        worklist over the canonical flat (H*R, C) planes.
                        The lazy row AND column phases are FUSED by
                        default: one stage+compute loop over the valid
                        entries (`worklist.fused_stage_compute` rows /
                        `worklist.fused_col_stage_compute` columns) + the
                        in-place writeback loop on CPU, or the
                        `ops.fused_row_update` / `ops.fused_col_update`
                        scalar-prefetch megakernels on TPU (`fused=` /
                        `fused_cols=` force either form, see
                        `hcu.use_fused_rows` / `hcu.use_fused_cols`); the
                        merged row phase uses the three-phase loops
                        (modes: "lazy", "merged"; docs/NUMERICS.md explains
                        why merged stays three-phase).

`select_backend(p, ...)` picks by the `hcu.use_worklist` size guard (the
`worklist=` runtime argument forces either); both backends produce
bitwise-identical trajectories (tests/test_worklist.py,
tests/test_engine_fixtures.py).

Canonical state layout
----------------------
`NetworkState.hcus` STORES the flat layout (`repro.core.layout`): ij planes
(H*R, C), i-vectors (H*R,), j-vectors (H, C). The WorklistBackend consumes it
natively — its scan carry is the stored layout, so the per-tick
flatten/unflatten round-trips of the previous runtime are gone. The
DenseBackend adapts once per compiled region via `carry_in`/`carry_out`
(zero-copy reshapes at the jit/scan boundary, never inside the tick body), so
its per-tick compute graph is exactly the historical per-HCU one — which is
what keeps trajectories bitwise-identical across the refactor (XLA:CPU fused
codegen is context-sensitive at 1 ulp; same-code-same-shape is the only safe
discipline).

One deliberate exception: the merged-mode overflow column flush runs on a
batched view *inside* the worklist tick. That flush is already a documented
O(H*R) per-tick trade (see `_merged_worklist_update`), and reusing the
per-HCU `column_flush_merged` graph verbatim is what keeps merged worklist
trajectories bitwise-identical to the vmapped path.

Execution drivers — `network_tick` / `network_run` (core/network.py) and
`make_dist_tick` / `make_dist_run` (core/distributed.py) — are thin wrappers:
they pick a backend, adapt the carry, and call `tick`. The sharded drivers
reuse the SAME `tick` body with a custom spike `route` (pack + all_to_all)
and a global-HCU-id RNG base, so the sharded worklist path needs no code of
its own. eBrainII correspondence: a `TickBackend` is the BCU tile's update
datapath; `tick` is the per-ms schedule (§II.A.2's three atomic sub-threads);
the `route` hook is the spike NoC port.

`Simulator` is the user-facing facade: init / run / run_sharded / save /
load (with the legacy-layout checkpoint migration shim) in a few lines.
"""
from __future__ import annotations

from typing import NamedTuple, Protocol

import jax
import jax.numpy as jnp

from repro.core import hcu as H
from repro.core import layout as L
from repro.core import network as N
from repro.core import reference
from repro.core import worklist as WL
from repro.core.params import BCPNNParams
from repro.core.traces import ZEP, decay_zep
from repro.kernels import ops


# ---------------------------------------------------------------------------
# shared plane-update building blocks
# ---------------------------------------------------------------------------

def _fired_mask(h_idx, j_idx, n: int, cols: int):
    """(H, C) mask of this tick's fired (hcu, column) cells; padding
    h_idx == n never matches arange(n)."""
    return jnp.any(
        (h_idx[:, None, None] == jnp.arange(n)[None, :, None])
        & (j_idx[:, None, None] == jnp.arange(cols)[None, None, :]),
        axis=0)


def _bump_zj(zj, h_idx, j_idx, n: int, p: BCPNNParams):
    """Postsynaptic Z increment for the compacted fired batch — the same
    two bitwise-identical branches (fused where below DENSE_CELLS_MAX,
    scatter-add above) shared by `column_updates_batched` and
    `_column_worklist`, so the worklist/vmap equivalence contract cannot
    silently diverge through an edit to one copy."""
    if n * p.rows * p.cols <= H.DENSE_CELLS_MAX:
        return jnp.where(_fired_mask(h_idx, j_idx, n, zj.shape[1]),
                         zj + 1.0, zj)
    return zj.at[h_idx, j_idx].add(1.0, mode="drop")


def column_updates_batched(hcus: H.HCUState, h_idx, j_idx, now,
                           p: BCPNNParams, backend=None) -> H.HCUState:
    """Lazy column updates for the compacted fired batch (network level).

    Operates on the BATCHED (H, R, C) view. h_idx: (K,) HCU indices (== H
    for padding -> scatter-dropped); j_idx: (K,) fired MCU column per slot.

    Gathers exactly the K (R,)-columns that fired (plus the K i-vectors) —
    never whole HCU states — so the cost is K*R cells, matching the paper's
    column-update traffic budget.
    """
    n = hcus.zij.shape[0]
    R = p.rows
    safe_h = jnp.minimum(h_idx, n - 1)
    h_ix = h_idx[:, None]                     # (K,1): padding == n -> dropped
    sh_ix = safe_h[:, None]
    r_ix = jnp.arange(R)[None, :]
    j_ix = j_idx[:, None]

    gcol = lambda plane: plane[sh_ix, r_ix, j_ix]             # (K, R)
    # i-vector traces brought to `now` (values only, no writeback)
    zep_i = H.ivec_decay(hcus.zi[safe_h], hcus.ei[safe_h],
                         hcus.pi[safe_h], hcus.ti[safe_h], now, p)
    pj_sc = hcus.pj[safe_h, j_idx]                            # (K,)

    z1, e1, p1, w1, t1 = jax.vmap(
        lambda z, e, pp, t, w, zi, pi, pj: H.ops.col_update(
            z, e, pp, t, now, zi, pi, pj, H.coeffs_ij(p), p.eps,
            backend=backend, w_col=w)
    )(gcol(hcus.zij), gcol(hcus.eij), gcol(hcus.pij), gcol(hcus.tij),
      gcol(hcus.wij), zep_i.z, zep_i.p, pj_sc)

    put = lambda plane, val: plane.at[h_ix, r_ix, j_ix].set(val, mode="drop")
    hcus = hcus._replace(
        zij=put(hcus.zij, z1), eij=put(hcus.eij, e1), pij=put(hcus.pij, p1),
        wij=put(hcus.wij, w1))
    if n * R * p.cols <= H.DENSE_CELLS_MAX:
        # fused where beats scatter for the constant-valued Tij write and
        # the +1.0 Zj bump (XLA CPU scatter has a high fixed per-op cost);
        # bitwise-identical to the scatter branch.
        fired_hc = _fired_mask(h_idx, j_idx, n, hcus.zj.shape[1])
        return hcus._replace(
            tij=jnp.where(fired_hc[:, None, :], now, hcus.tij),
            zj=_bump_zj(hcus.zj, h_idx, j_idx, n, p))
    return hcus._replace(
        tij=put(hcus.tij, t1),
        zj=_bump_zj(hcus.zj, h_idx, j_idx, n, p))


def _column_batched_on_flat(hcus: H.HCUState, h_idx, j_idx, now,
                            p: BCPNNParams, backend, n: int,
                            layout=None) -> H.HCUState:
    """Run `column_updates_batched` against canonical flat planes through a
    zero-copy batched view (used by the worklist path's Pallas branch, whose
    column step has always been the batched kernel). Under a blocked layout
    the planes round-trip through the canonical flat form (pure data
    movement — bitwise) so the batched graph itself is unchanged."""
    hb = column_updates_batched(
        L.batched_state(L.load_hcus(hcus, layout), n), h_idx, j_idx, now,
        p, backend=backend)
    return L.store_hcus(L.flat_state(hb), layout)


def _row_worklist_common(hcus: H.HCUState, rows, t, p: BCPNNParams):
    """Shared lazy/merged worklist prologue on the CANONICAL FLAT layout:
    j-vector decay, per-HCU dedup, i-vector decay (identical math to
    `hcu.row_updates`) and worklist build. Returns a dict of intermediates;
    the i-vector write values are h-major flat (H*A,) arrays indexed by
    worklist slot."""
    n, A = rows.shape
    R = p.rows
    zep_j = decay_zep(ZEP(hcus.zj, hcus.ej, hcus.pj), p.dt_ms, H.coeffs_j(p))
    hcus = hcus._replace(zj=zep_j.z, ej=zep_j.e, pj=zep_j.p)
    rows_u, counts = jax.vmap(lambda r: H.dedup_rows(r, R))(rows)
    safe = jnp.minimum(rows_u, R - 1)
    # gather i-vector entries by GLOBAL flat row index (the canonical layout
    # needs no (H, R) regrouping); values are sealed by ivec_decay's barriers
    g_safe = jnp.arange(n, dtype=jnp.int32)[:, None] * R + safe   # (H, A)
    take = lambda v: v[g_safe]
    zi_g, ti_g = take(hcus.zi), take(hcus.ti)
    zep_i = H.ivec_decay(zi_g, take(hcus.ei), take(hcus.pi), ti_g, t, p)
    zi_new = zep_i.z + counts
    g_row, order, nv = WL.build_worklist(rows_u, R)
    return dict(
        hcus=hcus, n=n, A=A, rows_u=rows_u, counts=counts,
        zep_i=zep_i, zi_new=zi_new, zi_g=zi_g, ti_g=ti_g,
        g_row=g_row, order=order, nv=nv,
        iv_vals=(zi_new.reshape(-1), zep_i.e.reshape(-1),
                 zep_i.p.reshape(-1)))


def _ij_flats(hcus: H.HCUState):
    return (hcus.zij, hcus.eij, hcus.pij, hcus.wij, hcus.tij)


def _put_flats(hcus: H.HCUState, flats) -> H.HCUState:
    return hcus._replace(zij=flats[0], eij=flats[1], pij=flats[2],
                         wij=flats[3], tij=flats[4])


def _wta(hcus: H.HCUState, w_rows, counts, t, keys, p: BCPNNParams):
    """Vmapped periodic update (support integration + soft WTA) on the raw
    (H, C) support/prior planes — layout-independent, same RNG stream as
    the per-HCU `hcu.periodic_update`."""
    h_new, fired = jax.vmap(
        lambda hv, pj, w, cnt, k: H.periodic_math(hv, pj, w, cnt, t, k, p)
    )(hcus.h, hcus.pj, w_rows, counts, keys)
    return hcus._replace(h=h_new), fired


def _col_worklist_prologue(hcus: H.HCUState, h_idx, j_idx, now,
                           p: BCPNNParams, n: int):
    """Shared fused/staged column prologue: per-entry presynaptic traces
    brought to `now` (the sealed `ivec_decay` island on the (K, R) gathered
    i-vectors — identical graph in both forms, which is what keeps them
    bitwise-interchangeable) and the per-entry postsynaptic P."""
    R = p.rows
    safe_h = jnp.minimum(h_idx, n - 1)
    ivr = lambda v: v.reshape(n, R)[safe_h]                   # (K, R)
    zep_i = H.ivec_decay(ivr(hcus.zi), ivr(hcus.ei), ivr(hcus.pi),
                         ivr(hcus.ti), now, p)
    pj_sc = hcus.pj[safe_h, j_idx]                            # (K,)
    return zep_i, pj_sc


def _column_worklist(hcus: H.HCUState, h_idx, j_idx, now, p: BCPNNParams,
                     backend=None, fused: bool = True, layout=None):
    """Worklist twin of `column_updates_batched`: same compacted fired batch,
    same per-cell compute graph (bitwise-identical values), but the (R, 1)
    column blocks are read and rewritten in place through dynamic slices on
    the canonical flat planes instead of batched gather/scatter.

    ``fused`` (default, `hcu.use_fused_cols`) fuses staging and compute into
    one loop over the n_fired valid entries (`worklist.fused_col_stage_
    compute` + the in-place `write_cols` loop) — the PR 4 row recipe applied
    to columns. fused=False keeps the three-phase stage/compute/writeback
    form — bitwise-identical, kept as the A/B reference
    (tests/test_worklist.py).
    """
    n = hcus.zj.shape[0]
    R = p.rows
    n_fired = jnp.sum(h_idx < n)
    zep_i, pj_sc = _col_worklist_prologue(hcus, h_idx, j_idx, now, p, n)
    flats = _ij_flats(hcus)
    if fused:
        # fused stage+compute loop: per valid entry, read the (R, 1) column
        # block and run the SAME cell formulas the vmapped compute runs
        # (ops.col_update "ref" dispatch at (R,) — bitwise-identical to the
        # (K, R) vmapped form, pinned by the head fixtures) in the same
        # iteration — compute on n_fired entries instead of every fired-
        # batch slot. The writeback stays the separate in-place write_cols
        # loop (one-direction loop rule, docs/NUMERICS.md).
        zi_all, pi_all = zep_i.z, zep_i.p                     # (K, R)

        def col_math(e, z, ee, pp, tt):
            row = lambda v: jax.lax.dynamic_slice(v, (e, 0), (1, R)) \
                .reshape(R)
            pj_e = jax.lax.dynamic_slice(pj_sc, (e,), (1,))[0]
            z1, e1, p1, w1, _ = H.ops.col_update(
                z, ee, pp, tt, now, row(zi_all), row(pi_all), pj_e,
                H.coeffs_ij(p), p.eps, backend=backend)
            return z1, e1, p1, w1

        vals = WL.fused_col_stage_compute(
            (flats[0], flats[1], flats[2], flats[4]),
            h_idx, j_idx, n_fired, R, col_math, layout=layout)
    else:
        zb, eb, pb, tb = WL.read_cols(
            (flats[0], flats[1], flats[2], flats[4]),
            h_idx, j_idx, n_fired, R, layout=layout)
        # same vmap-of-col_update graph as column_updates_batched, fed from
        # the staged buffers (padding slots read zeros instead of clipped
        # gathers; their results are never written back)
        z1, e1, p1, w1, _ = jax.vmap(
            lambda z, e, pp, t, zi, pi, pj: H.ops.col_update(
                z, e, pp, t, now, zi, pi, pj, H.coeffs_ij(p), p.eps,
                backend=backend)
        )(zb, eb, pb, tb, zep_i.z, zep_i.p, pj_sc)
        vals = (z1, e1, p1, w1)
    flats = WL.write_cols(flats, h_idx, j_idx, n_fired, vals, now, R,
                          layout=layout)
    hcus = _put_flats(hcus, flats)
    # tij is already stamped by write_cols; only the Zj bump remains
    return hcus._replace(zj=_bump_zj(hcus.zj, h_idx, j_idx, n, p))


def _column_worklist_megakernel(hcus: H.HCUState, h_idx, j_idx, now,
                                p: BCPNNParams, backend, n: int, lay=None):
    """TPU half of the fused column phase: one scalar-prefetch Pallas
    megakernel launch (`ops.fused_col_update`) rewrites every fired (R, 1)
    column block of the five ij planes in place — Tij stamped in-kernel,
    padding fired-batch entries routed onto the junk lane. Replaces the
    batched-view kernel + gather/scatter tail the non-fused Pallas column
    step pays (`_column_batched_on_flat`).

    ``lay`` (a TPU-degenerate `layout.BlockedLayout`, Tc == 1) runs the SAME
    kernel on the row-padded flat view of the blocked planes — a pure
    reshape, since a (H*Tr, 1, xr, xc) block store is row-major (H*Pr, Pc)
    byte-for-byte. Only the engine-side indices change: each HCU spans
    `padded_rows` view rows and the presynaptic vectors are transiently
    zero-padded to match (the pad rows' outputs land on pad cells, which are
    outside the logical plane)."""
    R = p.rows
    zep_i, pj_sc = _col_worklist_prologue(hcus, h_idx, j_idx, now, p, n)
    if lay is not None:
        Pr = lay.padded_rows
        pad = (lambda v: jnp.pad(v, ((0, 0), (0, Pr - R)))) if Pr != R \
            else (lambda v: v)
        planes = tuple(lay.flat_view(f) for f in _ij_flats(hcus))
        flats = ops.fused_col_update(
            *planes, h_idx=h_idx, j_idx=j_idx, now=now,
            zi_t=pad(zep_i.z), p_i=pad(zep_i.p), pj_sc=pj_sc,
            coeffs=H.coeffs_ij(p), eps=p.eps, n_hcu=n, rows=Pr,
            backend=backend)
        flats = tuple(lay.from_flat_view(f) for f in flats)
    else:
        flats = ops.fused_col_update(
            *_ij_flats(hcus), h_idx=h_idx, j_idx=j_idx, now=now,
            zi_t=zep_i.z, p_i=zep_i.p, pj_sc=pj_sc,
            coeffs=H.coeffs_ij(p), eps=p.eps, n_hcu=n, rows=R,
            backend=backend)
    hcus = _put_flats(hcus, flats)
    return hcus._replace(zj=_bump_zj(hcus.zj, h_idx, j_idx, n, p))


def worklist_col_dispatch(kernel, fused_cols, h_idx, j_idx, t,
                          p: BCPNNParams, n: int, layout=None):
    """Pick the worklist backend's lazy column-phase implementation for the
    resolved kernel backend: the in-place loops (`_column_worklist`,
    fused or staged) on "ref", the `ops.fused_col_update` megakernel or
    the batched-view kernel on the Pallas backends. Returns a
    hcus -> hcus' closure. Exposed (not underscored) because
    `benchmarks/profile_phases.py`'s ablation harness reuses it — the
    published per-phase deltas must dispatch exactly what the engine
    dispatches.

    ``layout`` (a `layout.BlockedLayout` or None) selects the storage order
    the closures address. The Pallas megakernel only speaks the flat view,
    so a blocked layout off the TPU-degenerate point (col_tiles > 1) routes
    to the batched-view kernel, whose wrapper round-trips through canonical
    flat."""
    kb = kernel or ops.default_backend()
    lay = L.as_blocked(layout)
    if kb == "ref":
        return lambda hc: _column_worklist(hc, h_idx, j_idx, t, p,
                                           backend=kernel, fused=fused_cols,
                                           layout=lay)
    # the column megakernel selects the per-entry presynaptic lane out of
    # one 128-wide tile, so a fired batch larger than a lane tile falls
    # back to the batched-view kernel (n_hcu >= ~366 at the default
    # cap_fire formula) instead of tracing an unsatisfiable kernel
    if fused_cols and h_idx.shape[0] <= ops.bcpnn_update.DEFAULT_BLOCK_L \
            and (lay is None or lay.tpu_degenerate):
        return lambda hc: _column_worklist_megakernel(hc, h_idx, j_idx, t,
                                                      p, kb, n, lay=lay)
    return lambda hc: _column_batched_on_flat(hc, h_idx, j_idx, t, p,
                                              kernel, n, layout=lay)


def worklist_lazy_rows(hcus: H.HCUState, rows, t, p: BCPNNParams,
                       kernel: str | None = None, fused: bool = True,
                       layout=None):
    """Lazy worklist row phase on canonical flat planes: dedup + worklist
    build, in-place row rewrites (ds/dus loops on CPU, scalar-prefetch Pallas
    kernel on TPU) and the i-vector writeback. Returns (hcus', w_rows,
    common) where common carries the prologue intermediates (counts etc.).

    ``fused`` (default, `hcu.use_fused_rows`) fuses staging and compute into
    one loop over the nv valid entries (`worklist.fused_stage_compute` +
    the in-place writeback loop) on CPU, or runs the whole phase as the
    `ops.fused_row_update` megakernel on TPU (ij planes + i-vectors aliased
    in place, weight rows emitted for the WTA). fused=False keeps the
    three-phase stage/compute/writeback form — bitwise-identical, kept as
    the A/B reference (tests/test_worklist.py).

    ``layout`` (a `layout.BlockedLayout` or None): the CPU loops address the
    blocked planes directly through the layout accessors; the Pallas kernels
    run on the row-padded flat view when the layout is TPU-degenerate
    (Tc == 1 — a pure reshape) with the worklist's global row indices
    remapped onto the padded row pitch, and fall back to a canonical-flat
    round-trip otherwise.

    Exposed (not underscored) because `benchmarks/profile_phases.py` times it
    as the row-update phase.
    """
    lay = L.as_blocked(layout)
    kb = kernel or ops.default_backend()
    if lay is not None and kb in ("pallas", "pallas_interpret") \
            and not lay.tpu_degenerate:
        # off the degenerate point the kernels' flat BlockSpecs can't
        # address the tile store; round-trip through canonical flat
        hcus, w_rows, c = worklist_lazy_rows(
            L.load_hcus(hcus, lay), rows, t, p, kernel=kernel, fused=fused)
        return L.store_hcus(hcus, lay), w_rows, c
    c = _row_worklist_common(hcus, rows, t, p)
    hcus = c["hcus"]
    n, A = c["n"], c["A"]
    if kb in ("pallas", "pallas_interpret") and fused:
        # megakernel: one scalar-prefetch grid pass over SLOT-ordered
        # entries (g_row already carries the H*R sentinel on padding slots;
        # ops reroutes sentinels onto the junk row) updates ij planes AND
        # i-vectors in place and emits the h-major weight rows directly
        W = n * A
        h_of = jnp.arange(W, dtype=jnp.int32) // A
        if lay is not None:
            # degenerate blocked planes == row-padded flat view (reshape);
            # remap worklist rows onto the padded pitch (sentinel included)
            # and pad the i-vectors to match — pad rows only ever receive
            # pad-cell writes, never feed a valid row's compute
            planes = tuple(lay.flat_view(f) for f in _ij_flats(hcus))
            ivin = tuple(lay.pad_ivec(v, n)
                         for v in (hcus.zi, hcus.ei, hcus.pi, hcus.ti))
            g_rows = lay.pad_row_index(c["g_row"], n)
        else:
            planes = _ij_flats(hcus)
            ivin = (hcus.zi, hcus.ei, hcus.pi, hcus.ti)
            g_rows = c["g_row"]
        flats, ivecs, w_flat = ops.fused_row_update(
            *planes, *ivin,
            rows=g_rows, now=t, counts=c["counts"].reshape(-1),
            zj=hcus.zj[h_of], p_i=c["zep_i"].p.reshape(-1),
            pj=hcus.pj[h_of],
            zi_new=c["zi_new"].reshape(-1), ei_new=c["zep_i"].e.reshape(-1),
            pi_new=c["zep_i"].p.reshape(-1),
            coeffs=H.coeffs_ij(p), eps=p.eps, backend=kb)
        if lay is not None:
            w_flat = w_flat[:, :p.cols]
            flats = tuple(lay.from_flat_view(f) for f in flats)
            ivecs = tuple(lay.unpad_ivec(v, n) for v in ivecs)
        hcus = _put_flats(hcus, flats)._replace(
            zi=ivecs[0], ei=ivecs[1], pi=ivecs[2], ti=ivecs[3])
        w_rows = w_flat.reshape(n, A, p.cols)
    elif kb in ("pallas", "pallas_interpret"):
        # scalar-prefetch Pallas kernel: grid over worklist entries, planes
        # aliased in place (interpret mode on CPU)
        order = c["order"]
        h_of = order // A
        # padding entries get the H*R sentinel explicitly (order pads with
        # 0, which aliases a real row); ops routes sentinels onto the
        # kernel's junk row so they can never clobber a touched row
        W = order.shape[0]
        if lay is not None:
            planes = tuple(lay.flat_view(f) for f in _ij_flats(hcus))
            g_map = lay.pad_row_index(c["g_row"], n)
            sent = n * lay.padded_rows
        else:
            planes = _ij_flats(hcus)
            g_map = c["g_row"]
            sent = n * p.rows
        rows_k = jnp.where(jnp.arange(W) < c["nv"], g_map[order], sent)
        flats = ops.worklist_row_update(
            *planes, rows=rows_k, nv=c["nv"], now=t,
            counts=c["counts"].reshape(-1)[order],
            zj=hcus.zj[h_of], p_i=c["zep_i"].p.reshape(-1)[order],
            pj=hcus.pj[h_of], coeffs=H.coeffs_ij(p), eps=p.eps, backend=kb)
        w_view = flats[3]
        if lay is not None:
            flats = tuple(lay.from_flat_view(f) for f in flats)
        hcus = _put_flats(hcus, flats)
        # i-vector writeback: the O(touched) scatter forms on the flat
        # vectors (padding rows routed to the H*R sentinel -> dropped)
        g_put = jnp.where(
            c["rows_u"] < p.rows,
            jnp.arange(n, dtype=jnp.int32)[:, None] * p.rows + c["rows_u"],
            n * p.rows).reshape(-1)
        put = lambda v, val: v.at[g_put].set(val.reshape(-1), mode="drop")
        hcus = hcus._replace(
            zi=put(hcus.zi, c["zi_new"]), ei=put(hcus.ei, c["zep_i"].e),
            pi=put(hcus.pi, c["zep_i"].p),
            ti=put(hcus.ti, jnp.full(c["rows_u"].shape, t, hcus.ti.dtype)))
        w_g = w_view[jnp.minimum(g_map, sent - 1)]                # (W, C)
        if lay is not None:
            w_g = w_g[:, :p.cols]
        w_rows = jnp.where((c["g_row"] < n * p.rows)[:, None], w_g, 0.0) \
            .reshape(n, A, p.cols)
    elif fused:
        # fused stage+compute loop: per valid entry, read the (1, C) row
        # blocks and run the SAME cell formulas the vmapped compute runs
        # (ops.row_update "ref" dispatch at (1, C) — bitwise-identical to
        # the (H, A, C) fusion, pinned by the head fixtures) in the same
        # iteration — compute on nv entries instead of every staged slot.
        # The writeback stays the separate in-place write_rows loop: a loop
        # that reads AND writes the same carried plane forces a full-plane
        # copy per iteration on XLA:CPU (docs/NUMERICS.md).
        counts_f = c["counts"].reshape(-1)
        pi_f = c["zep_i"].p.reshape(-1)
        zj_all, pj_all = hcus.zj, hcus.pj
        Cc = p.cols

        def row_math(slot, z, e, pp, tt):
            h = slot // A
            one = lambda v: jax.lax.dynamic_slice(v, (slot,), (1,))
            vec = lambda v: jax.lax.dynamic_slice(
                v, (h, 0), (1, Cc)).reshape(Cc)
            z1, e1, p1, w1, _ = ops.row_update(
                z, e, pp, tt, t, one(counts_f), vec(zj_all), one(pi_f),
                vec(pj_all), H.coeffs_ij(p), p.eps, backend=kernel)
            return z1, e1, p1, w1

        flats = _ij_flats(hcus)
        ivecs = (hcus.zi, hcus.ei, hcus.pi, hcus.ti)
        vals = WL.fused_stage_compute(
            (flats[0], flats[1], flats[2], flats[4]),
            c["g_row"], c["order"], c["nv"], row_math, layout=lay)
        flats, ivecs = WL.write_rows(flats, ivecs, c["g_row"], c["order"],
                                     c["nv"], vals, c["iv_vals"], t,
                                     layout=lay)
        hcus = _put_flats(hcus, flats)._replace(
            zi=ivecs[0], ei=ivecs[1], pi=ivecs[2], ti=ivecs[3])
        w_rows = vals[3].reshape(n, A, p.cols)
    else:
        flats = _ij_flats(hcus)
        ivecs = (hcus.zi, hcus.ei, hcus.pi, hcus.ti)
        bufs = WL.read_rows((flats[0], flats[1], flats[2], flats[4]),
                            c["g_row"], c["order"], c["nv"], layout=lay)
        # the per-HCU path's exact vmapped compute graph, fed from the
        # staged buffers (bitwise-identical values; padding slots read
        # zeros, their outputs are dropped / zero-count drive terms)
        sh = lambda b: b.reshape(n, A, p.cols)
        z1, e1, p1, w1, _ = jax.vmap(
            lambda z, e, pp, tt, cnt, zj, pi, pj: H.ops.row_update(
                z, e, pp, tt, t, cnt, zj, pi, pj, H.coeffs_ij(p), p.eps,
                backend=kernel)
        )(sh(bufs[0]), sh(bufs[1]), sh(bufs[2]), sh(bufs[3]),
          c["counts"], hcus.zj, c["zep_i"].p, hcus.pj)
        w_rows = w1
        vals = tuple(v.reshape(n * A, p.cols) for v in (z1, e1, p1, w1))
        flats, ivecs = WL.write_rows(flats, ivecs, c["g_row"], c["order"],
                                     c["nv"], vals, c["iv_vals"], t,
                                     layout=lay)
        hcus = _put_flats(hcus, flats)
        hcus = hcus._replace(zi=ivecs[0], ei=ivecs[1], pi=ivecs[2],
                             ti=ivecs[3])
    return hcus, w_rows, c


def worklist_merged_rows(hcus: H.HCUState, jring, rows, t, p: BCPNNParams,
                         fused: bool = True, layout=None):
    """Merged worklist row phase (piecewise ring integration) on canonical
    flat planes. Returns (hcus', w_rows, common).

    ``fused`` is accepted for driver-API symmetry with the lazy phase but is
    DELIBERATELY inert here: the merged row phase always runs the
    three-phase stage/compute/writeback form. The fused single-pass form was
    built and A/B-measured for this path too, and it diverges from the
    vmapped compute at 1 ulp in Zij: `merged_row_math`'s ring-integration
    island is large enough that XLA:CPU's fusion emitter contracts the tail
    ``z*ez + dz`` into an FMA in the big vmapped compilation, and NO
    loop-embedded compilation of the same sealed island — per-entry (1, C)
    or per-HCU (A, C) blocks alike — reproduces that contraction. Since the
    head fixtures pin the vmapped semantics bit-for-bit, merged keeps the
    staged compute. Full story: docs/NUMERICS.md (the lazy island is small
    enough to compile identically in both contexts, which is why
    `worklist_lazy_rows` CAN fuse)."""
    from repro.core import merged as M
    del fused
    lay = L.as_blocked(layout)
    c = _row_worklist_common(hcus, rows, t, p)
    hcus = c["hcus"]
    n, A = c["n"], c["A"]
    flats = _ij_flats(hcus)
    ivecs = (hcus.zi, hcus.ei, hcus.pi, hcus.ti)
    bufs = WL.read_rows((flats[0], flats[1], flats[2], flats[4]),
                        c["g_row"], c["order"], c["nv"], layout=lay)
    # vmapped merged_row_math: the exact compute graph of the per-HCU path
    sh = lambda b: b.reshape(n, A, p.cols)
    z1, e1, p1, w1 = jax.vmap(
        lambda z, e, pp, tt, g, zi, ti, cnt, zj, pi, pj: M.merged_row_math(
            z, e, pp, tt, g, zi, ti, cnt, zj, pi, pj, t, p)
    )(sh(bufs[0]), sh(bufs[1]), sh(bufs[2]), sh(bufs[3]), jring,
      c["zi_g"], c["ti_g"], c["counts"], hcus.zj, c["zep_i"].p, hcus.pj)
    w_rows = w1
    vals = tuple(v.reshape(n * A, p.cols) for v in (z1, e1, p1, w1))
    flats, ivecs = WL.write_rows(flats, ivecs, c["g_row"], c["order"],
                                 c["nv"], vals, c["iv_vals"], t, layout=lay)
    hcus = _put_flats(hcus, flats)
    hcus = hcus._replace(zi=ivecs[0], ei=ivecs[1], pi=ivecs[2], ti=ivecs[3])
    return hcus, w_rows, c


def _merged_worklist_update(hcus: H.HCUState, jring, rows, t, keys,
                            p: BCPNNParams, fused: bool = True, layout=None):
    """Worklist twin of `jax.vmap(merged.hcu_tick_merged)`: merged row
    updates (piecewise ring integration; `fused` threads through but the
    merged row phase stays three-phase — see `worklist_merged_rows`), WTA,
    overflow column flush, same-tick cell patch, ring push and Zj bump — all
    row-plane traffic through the in-place flat-plane loops.
    Bitwise-identical trajectories to the vmapped path
    (tests/test_worklist.py). Returns (hcus', jring', fired)."""
    from repro.core import merged as M
    n = rows.shape[0]
    R = p.rows
    lay = L.as_blocked(layout)
    hcus, w_rows, c = worklist_merged_rows(hcus, jring, rows, t, p,
                                           fused=fused, layout=lay)
    hcus, fired = _wta(hcus, w_rows, c["counts"], t, keys, p)

    active = fired >= 0
    safe_j = jnp.maximum(fired, 0)
    overflow = active & (jring[jnp.arange(n), safe_j, 0] != M.RING_EMPTY)

    # overflow path: amortized classic column flush (fire applied, no push).
    # Kept on the per-HCU vmapped code verbatim — run through a zero-copy
    # batched view — rather than a worklist twin: XLA:CPU's
    # libm-vs-vectorized transcendental codegen is sensitive to the
    # surrounding program, so only the *same code at the same spot*
    # guarantees bitwise identity with the vmap path. This keeps the flush's
    # O(H*R) column gathers/puts on every merged tick (not just overflow
    # ticks) — a deliberate trade: cond-gating or worklist-rewriting it
    # would change its fusion context and break the 1-ulp identity, and the
    # lazy path (the perf-gated one) has no flush at all.
    hb = jax.vmap(lambda s, g, j, ov: M.column_flush_merged(
        s, g, j, t, ov, p))(L.batched_state(L.load_hcus(hcus, lay), n),
                            jring, safe_j, overflow)
    hcus = L.store_hcus(L.flat_state(hb), lay)
    jring = jax.vmap(
        lambda g, sj, ov: g.at[sj].set(
            jnp.where(ov, jnp.full((M.RING_DEPTH,), M.RING_EMPTY, jnp.int32),
                      g[sj]))
    )(jring, safe_j, overflow)

    # normal path: defer via ring; patch only this tick's touched rows
    pa_idx, n_patch = WL.compact_mask(active & ~overflow)
    zf = WL.patch_cells(hcus.zij, pa_idx, n_patch, c["rows_u"],
                        c["zi_new"], fired, R, layout=lay)
    hcus = hcus._replace(zij=zf)
    jring = jax.vmap(lambda g, j: M.push_ring(g, j, t))(
        jring, jnp.where(overflow, -1, fired))
    zj = jax.vmap(
        lambda z, sj, a: z.at[sj].add(jnp.where(a, 1.0, 0.0))
    )(hcus.zj, safe_j, active)
    return hcus._replace(zj=zj), jring, fired


# ---------------------------------------------------------------------------
# the TickBackend protocol and its two implementations
# ---------------------------------------------------------------------------

class TickBackend(Protocol):
    """A plane-update strategy pluggable into `tick`.

    Backends are hashable value objects (NamedTuples) so the jit drivers can
    treat them as static arguments. `carry_in`/`carry_out` convert between
    the canonical flat storage layout and whatever layout the backend wants
    threaded through a compiled region (jit call or scan carry); both must
    be zero-copy value-preserving views. `plane_update` consumes the
    carry-layout state and performs the row / WTA / column phases of one
    tick, returning (state', fired, h_idx, j_idx, n_dropped).

    `plane_update_split` is the same tick with the column phase DEFERRED:
    it returns (state', fired, h_idx, j_idx, n_dropped, col) where `col` is
    an hcus -> hcus closure holding the (already cond-gated) column pass, or
    None when the mode cannot split (eager / merged run everything up
    front). The sharded driver uses the split form to issue the spike
    all_to_all between WTA and columns, so the collective's latency hides
    behind the column plane traffic (`tick`'s split-route path); applying
    `col` immediately is bitwise `plane_update`."""

    def carry_in(self, state, p: BCPNNParams): ...

    def carry_out(self, state, p: BCPNNParams): ...

    def plane_update(self, state, rows, t, keys, p: BCPNNParams, cap: int,
                     cond_columns: bool): ...

    def plane_update_split(self, state, rows, t, keys, p: BCPNNParams,
                           cap: int, cond_columns: bool): ...


class DenseBackend(NamedTuple):
    """Per-HCU vmapped plane updates on the batched (H, R, C) view.

    The right regime below `hcu.DENSE_CELLS_MAX` cells per HCU, where the
    fused dense write forms beat scatters and whole-plane traffic is cheap.
    mode: "lazy" (timestamped row/column updates), "eager" (the dense golden
    reference) or "merged" (eBrainIII ring-deferred columns).
    kernel: ops backend override ("ref" | "pallas" | "pallas_interpret").
    layout: plane storage order (`layout.BlockedLayout` or None for flat).
    A blocked layout converts to/from canonical flat once per compiled
    region in `carry_in`/`carry_out` (pure data movement), so the per-tick
    dense graph stays exactly the historical batched one.
    """
    mode: str = "lazy"
    kernel: str | None = None
    layout: "L.BlockedLayout | None" = None

    def carry_in(self, state, p: BCPNNParams):
        n = state.delay_rows.shape[0]
        return state._replace(
            hcus=L.batched_state(L.load_hcus(state.hcus, self.layout), n))

    def carry_out(self, state, p: BCPNNParams):
        return state._replace(
            hcus=L.store_hcus(L.flat_state(state.hcus), self.layout))

    def plane_update(self, state, rows, t, keys, p: BCPNNParams, cap: int,
                     cond_columns: bool):
        state, fired, h_idx, j_idx, n_drop, col = self.plane_update_split(
            state, rows, t, keys, p, cap, cond_columns)
        if col is not None:
            state = state._replace(hcus=col(state.hcus))
        return state, fired, h_idx, j_idx, n_drop

    def plane_update_split(self, state, rows, t, keys, p: BCPNNParams,
                           cap: int, cond_columns: bool):
        n = state.delay_rows.shape[0]
        if self.mode == "eager":
            hcus, fired = jax.vmap(
                lambda s, r, k: reference.eager_tick(s, r, t, k, p)
            )(state.hcus, rows, keys)
            h_idx, j_idx, n_drop = N.select_fired(fired, cap)
            return (state._replace(hcus=hcus), fired, h_idx, j_idx, n_drop,
                    None)
        if self.mode == "merged":
            from repro.core import merged as M
            hcus, jring, fired = jax.vmap(
                lambda s, g, r, k: M.hcu_tick_merged(s, g, r, t, k, p)
            )(state.hcus, state.jring, rows, keys)
            h_idx, j_idx, n_drop = N.select_fired(fired, cap)
            return (state._replace(hcus=hcus, jring=jring), fired,
                    h_idx, j_idx, n_drop, None)
        hcus, fired = jax.vmap(
            lambda s, r, k: H.hcu_tick_pre(s, r, t, k, p, backend=self.kernel)
        )(state.hcus, rows, keys)
        h_idx, j_idx, n_drop = N.select_fired(fired, cap)
        col = lambda hc: column_updates_batched(hc, h_idx, j_idx, t, p,
                                                backend=self.kernel)
        if cond_columns:
            # the "power gating" of the lazy model: silent ticks skip the
            # column pass entirely
            colfn = lambda hc: jax.lax.cond(jnp.any(h_idx < n), col,
                                            lambda hc_: hc_, hc)
        else:
            colfn = col
        return state._replace(hcus=hcus), fired, h_idx, j_idx, n_drop, colfn


class WorklistBackend(NamedTuple):
    """Network-global worklist plane updates on the canonical flat planes.

    The rodent/human-scale regime: one deduplicated (cap_total,) worklist of
    (hcu, row) entries per tick; all row-plane traffic through in-place
    dynamic-slice loops (CPU) or the scalar-prefetch Pallas kernel (TPU) —
    O(touched rows) per tick, the paper's §VI.D guarantee. The scan carry IS
    the stored flat layout: no per-tick reshapes.
    mode: "lazy" or "merged"; kernel as in DenseBackend.
    fused: fuse the lazy row phase's staging and compute into one
    valid-entries-only loop (`worklist.fused_stage_compute`; the
    `ops.fused_row_update` megakernel on TPU) instead of the three-phase
    stage/compute/writeback form — default on (`hcu.use_fused_rows`),
    bitwise-identical either way.
    fused_cols: the same fusion for the lazy column phase
    (`worklist.fused_col_stage_compute`; the `ops.fused_col_update`
    megakernel on TPU) — default on (`hcu.use_fused_cols`),
    bitwise-identical either way; inert in merged mode (the merged column
    flush keeps the shared `merged_col_math` island).
    layout: plane storage order (`layout.BlockedLayout` or None for flat).
    Unlike the dense backend, the worklist loops address the blocked tiles
    DIRECTLY through the layout accessors — this is where the Row-Merge
    column-locality win lives (a fired column touches ceil(R/xr) tile
    stripes instead of R strided cache lines).
    """
    mode: str = "lazy"
    kernel: str | None = None
    fused: bool = True
    fused_cols: bool = True
    layout: "L.BlockedLayout | None" = None

    def carry_in(self, state, p: BCPNNParams):
        return state

    def carry_out(self, state, p: BCPNNParams):
        return state

    def plane_update(self, state, rows, t, keys, p: BCPNNParams, cap: int,
                     cond_columns: bool):
        state, fired, h_idx, j_idx, n_drop, col = self.plane_update_split(
            state, rows, t, keys, p, cap, cond_columns)
        if col is not None:
            state = state._replace(hcus=col(state.hcus))
        return state, fired, h_idx, j_idx, n_drop

    def plane_update_split(self, state, rows, t, keys, p: BCPNNParams,
                           cap: int, cond_columns: bool):
        n = state.delay_rows.shape[0]
        if self.mode == "merged":
            hcus, jring, fired = _merged_worklist_update(
                state.hcus, state.jring, rows, t, keys, p, fused=self.fused,
                layout=self.layout)
            h_idx, j_idx, n_drop = N.select_fired(fired, cap)
            return (state._replace(hcus=hcus, jring=jring), fired,
                    h_idx, j_idx, n_drop, None)
        hcus, w_rows, c = worklist_lazy_rows(state.hcus, rows, t, p,
                                             kernel=self.kernel,
                                             fused=self.fused,
                                             layout=self.layout)
        hcus, fired = _wta(hcus, w_rows, c["counts"], t, keys, p)
        h_idx, j_idx, n_drop = N.select_fired(fired, cap)
        col = worklist_col_dispatch(self.kernel, self.fused_cols,
                                    h_idx, j_idx, t, p, n,
                                    layout=self.layout)
        if cond_columns:
            colfn = lambda hc: jax.lax.cond(jnp.any(h_idx < n), col,
                                            lambda hc_: hc_, hc)
        else:
            colfn = col
        return state._replace(hcus=hcus), fired, h_idx, j_idx, n_drop, colfn


def select_backend(p: BCPNNParams, *, eager: bool = False,
                   merged: bool = False, worklist: bool | None = None,
                   kernel: str | None = None,
                   fused: bool | None = None,
                   fused_cols: bool | None = None,
                   layout=None) -> "TickBackend":
    """Map the historical mode flags onto a TickBackend.

    Keeps `hcu.use_worklist`'s size-guard semantics (R*C > DENSE_CELLS_MAX
    switches to the worklist engine) and the `worklist=` override; `fused=`
    likewise forces the worklist backend's single-pass row phase on/off
    (`hcu.use_fused_rows`) and `fused_cols=` its single-pass column phase
    (`hcu.use_fused_cols`) — both default on, both no-ops for the dense
    backends. The eager golden reference is dense by definition (it touches
    every cell anyway).

    ``layout`` selects the plane storage order (`layout.resolve_layout`
    spec: None/"flat" for canonical flat, "blocked"/"blocked_tpu"/a
    `BlockedLayout` for column-blocked tiles); it is normalized here so the
    backends — which are static jit arguments — only ever carry None or a
    concrete `BlockedLayout`.
    """
    layout = L.resolve_layout(layout, p)
    if eager:
        return DenseBackend(mode="eager", kernel=kernel, layout=layout)
    mode = "merged" if merged else "lazy"
    if H.use_worklist(p, worklist):
        return WorklistBackend(mode=mode, kernel=kernel,
                               fused=H.use_fused_rows(p, fused),
                               fused_cols=H.use_fused_cols(p, fused_cols),
                               layout=layout)
    return DenseBackend(mode=mode, kernel=kernel, layout=layout)


# ---------------------------------------------------------------------------
# the one tick body
# ---------------------------------------------------------------------------

def tick(state, conn, ext_rows, p: BCPNNParams, be: "TickBackend",
         cap_fire: int | None = None, *, gid_base=0, route=None,
         cond_columns: bool = True):
    """Advance the network one 1 ms tick (state in the backend's carry
    layout). THE single tick body: every driver — per-tick jit, scan chunk,
    sharded per-device — runs this exact function, which is what makes all
    trajectories bitwise-comparable.

      gid_base      — global id of local HCU 0 (sharded: dev * h_local), so
                      the RNG stream is invariant to device count;
      route         — spike routing hook route(state, dest_h, dest_r, delay,
                      valid, p, n) -> state'; defaults to the local
                      `network.enqueue_spikes`, sharded drivers pass the
                      pack + all_to_all exchange. A route exposing
                      `send`/`recv` (`distributed.SparseExchange`) is run
                      SPLIT: the collective is issued right after WTA and
                      its result consumed only after the column plane
                      update, so spike latency hides behind column traffic.
                      Neither phase reads what the other writes (the
                      exchange touches delay queues + drop counters, the
                      column pass touches the ij planes), so the split
                      trajectory is bitwise the sequential one;
      cond_columns  — gate the lazy column pass behind "anything fired?"
                      (the historical local-tick behavior; sharded ticks run
                      it unconditionally).
    Returns (state', fired) with fired[h] = MCU index or -1.
    """
    n = state.delay_rows.shape[0]
    t = state.t + 1
    cap = cap_fire or max(2, int(0.35 * n) + 1)

    # 1. consume this tick's delay bucket and merge with external input
    state, bucket = N.consume_bucket(state, t, p, n)
    rows = jnp.concatenate([bucket, ext_rows], axis=1)

    # 2. plane update (rows + WTA + columns), identical RNG in all drivers
    k_t = jax.random.fold_in(state.base_key, t)
    gids = gid_base + jnp.arange(n)
    keys = jax.vmap(lambda g: jax.random.fold_in(k_t, g))(gids)
    split = route is not None and hasattr(route, "send")
    if split:
        # split-phase route: defer the column pass so the spike collective
        # can be issued between WTA and columns (overlap window)
        state, fired, h_idx, j_idx, n_drop, col = be.plane_update_split(
            state, rows, t, keys, p, cap, cond_columns)
    else:
        state, fired, h_idx, j_idx, n_drop = be.plane_update(
            state, rows, t, keys, p, cap, cond_columns)
        col = None
    state = state._replace(drops_fire=state.drops_fire + n_drop, t=t)

    # 3. fan out spikes from the fired batch into delay queues
    safe_h = jnp.minimum(h_idx, n - 1)
    dest_h = conn.dest_hcu[safe_h, j_idx].reshape(-1)          # (K*F,)
    dest_r = conn.dest_row[safe_h, j_idx].reshape(-1)
    dly = conn.delay[safe_h, j_idx].reshape(-1)
    valid = jnp.repeat(h_idx < n, p.fanout)
    if split:
        # 3a. compact + issue the all_to_all; 2b. columns run while the
        # exchange is in flight; 3b. enqueue the delivered spikes
        state, inflight = route.send(state, dest_h, dest_r, dly, valid, p, n)
        if col is not None:
            state = state._replace(hcus=col(state.hcus))
        state = route.recv(state, inflight, p, n)
    else:
        state = (route or N.enqueue_spikes)(state, dest_h, dest_r, dly,
                                            valid, p, n)
    return state, fired


# ---------------------------------------------------------------------------
# Simulator facade
# ---------------------------------------------------------------------------

class Simulator:
    """End-to-end facade over the TickEngine: init / run / run_sharded /
    save / load in a few lines, without hand-wiring `init_network` +
    `network_run` + `make_dist_run`.

        sim = Simulator(p, key=0)
        fired = sim.run(ext)                   # staged scan runtime
        sim.save("ckpt")                       # NetworkState checkpoint
        sim.load("ckpt")                       # incl. legacy-layout shim

    The held `state` is always in the canonical flat layout; `hcus()` gives
    the batched (H, R, C) view and `flushed()` a fully-current copy for
    inspection. Drivers donate `self.state` and the Simulator rebinds it, so
    never hold your own reference across a run.
    """

    def __init__(self, p: BCPNNParams, key=0, *, n_hcu: int | None = None,
                 merged: bool = False, eager: bool = False,
                 worklist: bool | None = None, kernel: str | None = None,
                 fused: bool | None = None, fused_cols: bool | None = None,
                 cap_fire: int | None = None, chunk: int = 128,
                 layout=None):
        self.p = p
        self.n_hcu = n_hcu or p.n_hcu
        self.merged, self.eager = merged, eager
        self.worklist, self.kernel, self.fused = worklist, kernel, fused
        self.fused_cols = fused_cols
        self.cap_fire, self.chunk = cap_fire, chunk
        # normalized once: None (canonical flat) or a concrete BlockedLayout
        # ("blocked" -> the CPU tile, "blocked_tpu" -> the (8, 128) tile)
        self.layout = L.resolve_layout(layout, p)
        self._dist_cache = None
        self._key = jax.random.PRNGKey(key) if isinstance(key, int) else key
        self.conn = N.make_connectivity(p, jax.random.fold_in(self._key, 1),
                                        n_hcu)
        self.state = N.init_network(p, self._key, n_hcu=n_hcu, merged=merged,
                                    layout=self.layout)

    # -- mode plumbing -------------------------------------------------------
    def _kw(self):
        return dict(eager=self.eager, merged=self.merged,
                    worklist=self.worklist, backend=self.kernel,
                    fused=self.fused, fused_cols=self.fused_cols,
                    cap_fire=self.cap_fire, layout=self.layout)

    @property
    def backend(self) -> "TickBackend":
        return select_backend(self.p, eager=self.eager, merged=self.merged,
                              worklist=self.worklist, kernel=self.kernel,
                              fused=self.fused, fused_cols=self.fused_cols,
                              layout=self.layout)

    def reset(self, key=None) -> "Simulator":
        """Re-init the network state (same connectivity unless key given)."""
        if key is not None:
            self._key = (jax.random.PRNGKey(key) if isinstance(key, int)
                         else key)
            self.conn = N.make_connectivity(
                self.p, jax.random.fold_in(self._key, 1), self.n_hcu)
        self.state = N.init_network(self.p, self._key, n_hcu=self.n_hcu,
                                    merged=self.merged, layout=self.layout)
        self._dist_cache = None      # fresh state is host-resident again
        return self

    # -- drivers -------------------------------------------------------------
    def tick(self, ext_rows):
        """One 1 ms tick (per-tick jit driver). Returns fired (H,)."""
        self.state, fired = N.network_tick(self.state, self.conn, ext_rows,
                                           self.p, **self._kw())
        return fired

    def run(self, ext, n_ticks: int | None = None, chunk: int | None = None):
        """Scan-compiled run. `ext` is a staged (T, H, A_ext) tensor, an
        iterable of (H, A_ext) frames, or a callable ext_fn(t) (then pass
        n_ticks). Returns fired history (T, H)."""
        if callable(ext) or not hasattr(ext, "ndim"):
            ext = N.stage_external(ext, n_ticks, t0=int(self.state.t))
        if n_ticks is not None:
            ext = ext[:n_ticks]
        self.state, fired = N.network_run(self.state, self.conn, ext, self.p,
                                          chunk=chunk or self.chunk,
                                          **self._kw())
        return fired

    def run_host(self, ext_fn, n_ticks: int):
        """Per-tick host-loop driver (the dispatch-bound baseline)."""
        self.state, fired = N.run(self.state, self.conn, ext_fn, n_ticks,
                                  self.p, **self._kw())
        return fired

    def run_sharded(self, ext, mesh=None, axis: str = "hcu", rc=None):
        """Scan-compiled sharded run over an HCU mesh (defaults to all local
        devices). Shards state/conn on first use; the held state stays
        sharded afterwards. Returns fired history (T, H)."""
        from repro.core import distributed as DD
        if self.merged:
            # the sharded runtime has no jring shard specs yet; silently
            # running the lazy backend would diverge from sim.run()
            raise NotImplementedError(
                "merged mode is not supported by the sharded runtime")
        if self.layout is not None:
            # the sharded drivers carry canonical flat planes; silently
            # dropping the blocked layout would diverge from sim.run()
            raise NotImplementedError(
                "blocked plane layouts are not supported by the sharded "
                "runtime (run with layout=None/'flat')")
        if mesh is None:
            mesh = jax.make_mesh((jax.device_count(),), (axis,))
        if rc is None:
            rc = DD.default_route_config(self.p, self.n_hcu // mesh.size,
                                         mesh.size)
        # cache the compiled sharded driver and the sharding step: rebuilding
        # make_dist_run per call would retrace the whole T-tick shard_map scan
        cache_key = (mesh, axis, rc)
        if getattr(self, "_dist_cache", None) is None \
                or self._dist_cache[0] != cache_key:
            self.state, self.conn = DD.shard_network(mesh, self.state,
                                                     self.conn, axis=axis)
            fn = DD.make_dist_run(mesh, self.p, rc, axis=axis,
                                  eager=self.eager, backend=self.kernel,
                                  worklist=self.worklist, fused=self.fused,
                                  fused_cols=self.fused_cols)
            self._dist_cache = (cache_key, fn)
        self.state, fired = self._dist_cache[1](self.state, self.conn,
                                                jnp.asarray(ext))
        return fired

    # -- inspection ----------------------------------------------------------
    def drops(self) -> dict:
        """Cumulative spike-drop counters: {'in': delay-queue overflows,
        'fire': fired-batch overflows, 'route': inter-device fabric
        overflows} — the paper's Fig 7 failure currency, surfaced so health
        monitors need not reach into NetworkState."""
        return N.drop_counters(self.state)

    def hcus(self) -> H.HCUState:
        """Batched (H, R, C) view of the held state (layout-aware: blocked
        planes are unpacked to canonical order first)."""
        return N.hcu_view(self.state, layout=self.layout)

    def flushed(self) -> H.HCUState:
        """Batched HCU state with every lazy trace brought current — the
        directly inspectable/comparable form (mode-aware: merged states
        flush their rings)."""
        now = self.state.t
        hb = self.hcus()
        if self.merged:
            from repro.core import merged as M
            return jax.vmap(lambda s, g: M.flush_merged(s, g, now, self.p))(
                hb, self.state.jring)
        return jax.vmap(lambda s: H.flush(s, now, self.p))(hb)

    # -- persistence ---------------------------------------------------------
    def save(self, ckpt_dir: str, step: int | None = None) -> str:
        """Checkpoint the held NetworkState (atomic, numpy container). The
        manifest records the plane layout (`layout.layout_tag`) so a later
        load under a different layout knows to convert."""
        from repro.checkpoint import save as ckpt_save
        return ckpt_save(ckpt_dir, int(self.state.t) if step is None
                         else step, self.state,
                         extra_meta={"layout": L.layout_tag(self.layout)})

    def load(self, ckpt_dir: str, step: int | None = None) -> "Simulator":
        """Restore the latest (or given) step into this Simulator.

        One-call migration, two shims:
        * legacy layout — checkpoints written by the pre-engine runtime
          stored the batched (H, R, C)/(H, R) layout; reshaped to canonical
          flat on load (`checkpoint.restore_network`);
        * plane layout — a checkpoint saved under one plane layout restores
          under any other: the manifest's layout tag (absent == flat) picks
          a template in the SAVED layout, and the loaded planes are
          converted to this Simulator's layout (`layout.convert_hcus` —
          pure data movement, bitwise).
        """
        from repro.checkpoint import latest_step, manifest, restore_network
        if step is None:
            step = latest_step(ckpt_dir)
            if step is None:
                raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
        meta = manifest(ckpt_dir, step) or {}
        saved = L.layout_from_tag(meta.get("layout", "flat"), self.p)
        if L.layout_tag(saved) == L.layout_tag(self.layout):
            self.state = restore_network(ckpt_dir, step, self.state)
        else:
            tmpl = self.state._replace(
                hcus=L.convert_hcus(self.state.hcus, self.layout, saved))
            st = restore_network(ckpt_dir, step, tmpl)
            self.state = st._replace(
                hcus=L.convert_hcus(st.hcus, saved, self.layout))
        self._dist_cache = None      # restored state is host-resident
        return self
