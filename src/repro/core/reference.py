"""Eager dense BCPNN reference — the golden model.

This is the analogue of the paper's golden C++ model (§VII.A.2) *and* of the
GPU-style eager mapping it benchmarks against (§VIII.A): every tick, every
trace in the (R, C) matrix is decayed and every weight recomputed — no lazy
evaluation, no timestamps. Because both eager and lazy paths use the exact
exponential-integrator per gap (semigroup property), the lazy system must
match this reference bit-for-bit up to float rounding
(tests/test_lazy_vs_eager.py).

It also anchors the Fig-14-style benchmark: eager touches R*C cells/tick
where lazy touches ~(spikes * C + out_rate * R); the ratio is the paper's
"GPU achieves 5% of rated FLOPs" story re-expressed as useful-work fraction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hcu as H
from repro.core.params import BCPNNParams
from repro.core.traces import ZEP, bias, decay_zep


def eager_tick(st: H.HCUState, rows, now, key, p: BCPNNParams):
    """One dense 1 ms tick with semantics identical to the lazy pipeline."""
    # 1. j-vector decay (identical to lazy)
    zep_j = decay_zep(ZEP(st.zj, st.ej, st.pj), p.dt_ms, H.coeffs_j(p))
    st = st._replace(zj=zep_j.z, ej=zep_j.e, pj=zep_j.p)

    # 2. dense decay of ALL ij cells and the whole i-vector by dt
    zep_ij = decay_zep(ZEP(st.zij, st.eij, st.pij), p.dt_ms, H.coeffs_ij(p))
    zep_i = decay_zep(ZEP(st.zi, st.ei, st.pi), p.dt_ms, H.coeffs_i(p))

    # 3. row spike increments (duplicates aggregate, same as dedup_rows)
    rows_u, counts = H.dedup_rows(rows, p.rows)
    spike_vec = jnp.zeros((p.rows,), st.zi.dtype).at[rows_u].add(
        counts, mode="drop")                                   # (R,) multiplicity
    zi = zep_i.z + spike_vec
    zij = zep_ij.z + spike_vec[:, None] * st.zj[None, :]

    # 4. dense Bayesian weight recompute
    wij = jnp.log((zep_ij.p + p.eps**2)
                  / ((zep_i.p[:, None] + p.eps) * (st.pj[None, :] + p.eps)))

    st = st._replace(zij=zij, eij=zep_ij.e, pij=zep_ij.p, wij=wij,
                     tij=jnp.full_like(st.tij, now),
                     zi=zi, ei=zep_i.e, pi=zep_i.p,
                     ti=jnp.full_like(st.ti, now))

    # 5. periodic support + WTA (same RNG stream as lazy)
    drive = spike_vec @ wij                                    # (C,)
    h = st.h * jnp.exp(-p.dt_ms / p.tau_m) + drive
    s = h + bias(st.pj, p.eps)
    k_gate, k_win = jax.random.split(key)
    fire = jax.random.uniform(k_gate) < p.out_rate * p.dt_ms
    winner = jax.random.categorical(k_win, s / p.wta_temp)
    fired_j = jnp.where(fire, winner, -1).astype(jnp.int32)
    st = st._replace(h=h)

    # 6. column update for the fired MCU (dense state: only Z jumps; E/P/W
    #    were already brought current by the dense decay above)
    active = fired_j >= 0
    safe_j = jnp.maximum(fired_j, 0)
    onehot = (jnp.arange(p.cols) == safe_j) & active
    zij = st.zij + jnp.where(onehot[None, :], st.zi[:, None], 0.0)
    zj = st.zj + onehot.astype(st.zj.dtype)
    return st._replace(zij=zij, zj=zj), fired_j
