"""Multi-HCU BCPNN network: spike queues, routing, and the tick loop.

Maps the paper's infrastructure (§II.A.3, §IV, §VI.D-E) onto JAX:

  * delay queue  — (H, max_delay, A) ring of buckets indexed by arrival tick;
                   a spike with biological delay d lands in bucket (t+d) % D.
                   Bucket capacity A is the paper's active-queue size (36 for
                   human scale, from the Poisson tail analysis of Fig 7);
                   overflows are counted as drops, exactly the paper's
                   1-spike-per-month budget.
  * active queue — the bucket being consumed this tick (+ external input).
  * fanout       — static connectivity (dest_hcu, dest_row, delay) per MCU,
                   the analogue of the pipelined binary-tree spike NoC. In the
                   sharded runtime the tree becomes an all_to_all over fixed
                   per-device-pair buckets (see distributed.py).
  * column batching — only HCUs that actually fired pay for a column update;
                   fired HCUs are compacted into a fixed-capacity batch
                   (cap_fire) the same way spikes are queued.

Everything is a pure function of NetworkState; `eager=True` swaps the lazy
HCU pipeline for the dense golden reference with identical queue semantics
and RNG stream, so the two trajectories are directly comparable.

Tick-loop runtimes
------------------
Two drivers share the exact same single-tick body (`_tick_core`), so their
trajectories are bitwise identical under a fixed PRNG key:

  * `run`          — per-tick host loop (one jit dispatch + host sync per
                     ms). Kept as the baseline and for callers that need a
                     host-side decision between ticks.
  * `network_run`  — the production path: external input is pre-staged as a
                     dense (T, H, A_ext) tensor (`stage_external`), and the
                     loop is compiled with `jax.lax.scan` in chunks of
                     `chunk` ticks (default 128). Per chunk there is exactly
                     ONE dispatch; the NetworkState carry is donated, so
                     state planes are threaded through the scan with zero
                     host round-trips and no per-tick reallocation — the
                     runtime analogue of the paper's ping-pong buffering
                     (compute never waits on the host the way the ASIC never
                     waits on DRAM, §VI.C).

Inside the tick body, plane updates come in two size-guarded forms (the
`worklist=` argument forces either; `hcu.use_worklist` picks by default):

  * per-HCU vmap   — toy sizes: each HCU gathers/updates/scatters its own
                     (R, C) planes, with the fused dense write forms of PR 1.
  * worklist       — rodent/human scales: one deduplicated network-global
                     worklist of (hcu, row) entries per tick over the flat
                     (H*R, C) plane view (`core.worklist`, `core.layout`).
                     All plane traffic goes through in-place dynamic-slice
                     loops (CPU) or the scalar-prefetch Pallas kernel (TPU),
                     touching O(worklist) rows instead of forcing XLA's
                     copy-per-scatter on the O(H*R*C) scan carry — the
                     runtime finally matches the paper's §VI.D guarantee
                     that traffic scales with spikes, not synapses.
                     Trajectories are bitwise-identical between both forms,
                     in lazy, merged and sharded modes.

Scan-chunking contract:
  * ext staging      — ext[k] is consumed by tick t0+k+1 where t0 is
                       state.t at entry (matching `run`, which calls
                       ext_fn(state.t + 1) before each tick);
  * fired history    — returned as (T, H) int32, fired[k, h] = MCU index
                       that HCU h fired at tick t0+k+1, or -1;
  * chunking         — T need not divide by `chunk`: full chunks compile
                       one scan, the remainder compiles a second (at most
                       two compilations per (shape, mode));
  * donation         — the caller's `state` is donated; use the returned
                       state (same semantics as `network_tick`).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import hcu as H
from repro.core import layout as L
from repro.core import reference
from repro.core import worklist as WL
from repro.core.params import BCPNNParams
from repro.kernels import ops


class Connectivity(NamedTuple):
    dest_hcu: jnp.ndarray   # (H, C, F) int32
    dest_row: jnp.ndarray   # (H, C, F) int32
    delay: jnp.ndarray      # (H, C, F) int32, in [1, max_delay-1]


class NetworkState(NamedTuple):
    hcus: H.HCUState        # leading axis H on every leaf
    delay_rows: jnp.ndarray  # (H, D, A) int32; empty slots == R
    delay_count: jnp.ndarray  # (H, D) int32
    t: jnp.ndarray          # () int32 current time (ms)
    drops_in: jnp.ndarray   # () int32  — delay-queue overflow drops
    drops_fire: jnp.ndarray  # () int32 — fired-batch overflow drops
    base_key: jnp.ndarray   # PRNG key
    jring: jnp.ndarray | None = None   # (H, C, M) merged-mode spike rings


def make_connectivity(p: BCPNNParams, key, n_hcu: int | None = None) -> Connectivity:
    """Random static fanout: each MCU projects to `fanout` (HCU, row) targets
    with biological delays of mean ~`mean_delay` ms (truncated geometric)."""
    n = n_hcu or p.n_hcu
    k1, k2, k3 = jax.random.split(key, 3)
    shape = (n, p.cols, p.fanout)
    dest_hcu = jax.random.randint(k1, shape, 0, n, jnp.int32)
    dest_row = jax.random.randint(k2, shape, 0, p.rows, jnp.int32)
    lam = 1.0 / max(p.mean_delay - 1.0, 1e-3)
    geo = jnp.floor(jnp.log1p(-jax.random.uniform(k3, shape)) / -lam).astype(jnp.int32)
    delay = jnp.clip(1 + geo, 1, p.max_delay - 1)
    return Connectivity(dest_hcu, dest_row, delay)


def init_network(p: BCPNNParams, key, n_hcu: int | None = None,
                 merged: bool = False) -> NetworkState:
    n = n_hcu or p.n_hcu
    hcus = jax.vmap(lambda _: H.init_hcu_state(p))(jnp.arange(n))
    D, A = p.max_delay, p.active_queue
    jring = None
    if merged:
        from repro.core import merged as M
        jring = jnp.broadcast_to(M.init_ring(p),
                                 (n, p.cols, M.RING_DEPTH)).copy()
    return NetworkState(
        jring=jring,
        hcus=hcus,
        delay_rows=jnp.full((n, D, A), p.rows, jnp.int32),
        delay_count=jnp.zeros((n, D), jnp.int32),
        t=jnp.asarray(0, jnp.int32),
        drops_in=jnp.asarray(0, jnp.int32),
        drops_fire=jnp.asarray(0, jnp.int32),
        # private derived key: network_tick donates the state, so base_key
        # must not alias a caller-held (or sibling-network) buffer
        base_key=jax.random.fold_in(key, 0x5EED),
    )


# Below this message count the O(M^2) fused compare-reduce rank beats the
# sort-based path on op overhead; above it the sort path's O(M log M) wins.
_RANK_DENSE_MAX = 2048


def _rank_within_key(keys: jnp.ndarray) -> jnp.ndarray:
    """Rank of each element within its key group (stable: by position).

    rank[i] == #{j < i : keys[j] == keys[i]} — identical to position within
    the group under a stable sort.
    """
    M = keys.shape[0]
    if M <= _RANK_DENSE_MAX:
        eq = keys[:, None] == keys[None, :]                 # (M, M)
        earlier = jnp.arange(M)[None, :] < jnp.arange(M)[:, None]
        return jnp.sum(eq & earlier, axis=1).astype(keys.dtype)
    order = jnp.argsort(keys)                               # stable
    sorted_keys = keys[order]
    idx = jnp.arange(M)
    is_first = jnp.concatenate([jnp.array([True]),
                                sorted_keys[1:] != sorted_keys[:-1]])
    first_pos = jax.lax.cummax(jnp.where(is_first, idx, 0))
    rank_sorted = idx - first_pos
    return jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)


def consume_bucket(state: NetworkState, t, p: BCPNNParams, n: int):
    """Read this tick's delay bucket and clear it. Shared by the local and
    sharded tick bodies (keeping their trajectories identical). On small
    networks the clear is a fused iota-compare where (cheaper than the
    dynamic-update-slice scatter on XLA CPU); at scale the slice update
    touches only the one bucket."""
    D = p.max_delay
    bucket = state.delay_rows[:, t % D, :]                     # (H, A)
    if n * D * p.active_queue <= H.DENSE_CELLS_MAX:
        is_bucket = jnp.arange(D) == t % D                     # (D,)
        state = state._replace(
            delay_rows=jnp.where(is_bucket[None, :, None], p.rows,
                                 state.delay_rows),
            delay_count=jnp.where(is_bucket[None, :], 0, state.delay_count))
    else:
        state = state._replace(
            delay_rows=state.delay_rows.at[:, t % D, :].set(p.rows),
            delay_count=state.delay_count.at[:, t % D].set(0))
    return state, bucket


def enqueue_spikes(state: NetworkState, dest_h, dest_row, delay, valid,
                   p: BCPNNParams, n_hcu: int):
    """Insert a flat batch of spike messages into the delay queues.

    Fixed-capacity slot allocation: messages are ranked within their
    (dest_hcu, bucket) group; slot = current_count + rank; messages whose slot
    exceeds the bucket capacity A are dropped and counted (paper Fig 7).
    """
    D, A = p.max_delay, p.active_queue
    M = dest_h.shape[0]
    bucket = (state.t + delay) % D
    key = jnp.where(valid, dest_h * D + bucket, n_hcu * D)      # invalid rank last
    rank = _rank_within_key(key)
    base = state.delay_count[dest_h, bucket]                    # (M,)
    slot = base + rank
    ok = valid & (slot < A)
    flat_idx = jnp.where(ok, (dest_h * D + bucket) * A + slot, n_hcu * D * A)
    delay_rows = state.delay_rows.reshape(-1).at[flat_idx].set(
        dest_row, mode="drop").reshape(n_hcu, D, A)
    # bucket occupancy: add arrivals, clip at capacity
    if M * n_hcu * D <= H.DENSE_CELLS_MAX:
        # dense compare+reduce ((M, H*D) one-hot sum) instead of
        # scatter-add: integer sum is order-independent (bitwise-identical)
        # and avoids the scatter op cost on small networks. `key` is the
        # (h, bucket) flat index with invalids sent out of range.
        arrivals = jnp.sum(
            (key[:, None] == jnp.arange(n_hcu * D)[None, :]).astype(jnp.int32),
            axis=0).reshape(n_hcu, D)
    else:
        arrivals = jnp.zeros((n_hcu, D), jnp.int32).at[dest_h, bucket].add(
            valid.astype(jnp.int32), mode="drop")
    new_count = jnp.minimum(state.delay_count + arrivals, A)
    dropped = jnp.sum(state.delay_count + arrivals - new_count)
    return state._replace(delay_rows=delay_rows, delay_count=new_count,
                          drops_in=state.drops_in + dropped)


def _select_fired(fired: jnp.ndarray, cap: int):
    """Compact fired HCU indices (fired[h] >= 0) into `cap` slots."""
    n = fired.shape[0]
    is_fired = fired >= 0
    order = jnp.argsort(~is_fired)              # fired first, stable
    idx = order[:cap]
    sel_valid = is_fired[idx]
    h_idx = jnp.where(sel_valid, idx, n)
    j_idx = jnp.where(sel_valid, fired[idx], 0)
    n_dropped = jnp.sum(is_fired) - jnp.sum(sel_valid)
    return h_idx.astype(jnp.int32), j_idx.astype(jnp.int32), n_dropped


def _fired_mask(h_idx, j_idx, n: int, cols: int):
    """(H, C) mask of this tick's fired (hcu, column) cells; padding
    h_idx == n never matches arange(n)."""
    return jnp.any(
        (h_idx[:, None, None] == jnp.arange(n)[None, :, None])
        & (j_idx[:, None, None] == jnp.arange(cols)[None, None, :]),
        axis=0)


def _bump_zj(zj, h_idx, j_idx, n: int, p: BCPNNParams):
    """Postsynaptic Z increment for the compacted fired batch — the same
    two bitwise-identical branches (fused where below DENSE_CELLS_MAX,
    scatter-add above) shared by `column_updates_batched` and
    `_column_worklist`, so the worklist/vmap equivalence contract cannot
    silently diverge through an edit to one copy."""
    if n * p.rows * p.cols <= H.DENSE_CELLS_MAX:
        return jnp.where(_fired_mask(h_idx, j_idx, n, zj.shape[1]),
                         zj + 1.0, zj)
    return zj.at[h_idx, j_idx].add(1.0, mode="drop")


def column_updates_batched(hcus: H.HCUState, h_idx, j_idx, now,
                           p: BCPNNParams, backend=None) -> H.HCUState:
    """Lazy column updates for the compacted fired batch (network level).

    h_idx: (K,) HCU indices (== H for padding -> scatter-dropped);
    j_idx: (K,) fired MCU column per slot.

    Gathers exactly the K (R,)-columns that fired (plus the K i-vectors) —
    never whole HCU states — so the cost is K*R cells, matching the paper's
    column-update traffic budget.
    """
    n = hcus.zij.shape[0]
    K = h_idx.shape[0]
    R = p.rows
    safe_h = jnp.minimum(h_idx, n - 1)
    h_ix = h_idx[:, None]                     # (K,1): padding == n -> dropped
    sh_ix = safe_h[:, None]
    r_ix = jnp.arange(R)[None, :]
    j_ix = j_idx[:, None]

    gcol = lambda plane: plane[sh_ix, r_ix, j_ix]             # (K, R)
    # i-vector traces brought to `now` (values only, no writeback)
    zep_i = H.ivec_decay(hcus.zi[safe_h], hcus.ei[safe_h],
                         hcus.pi[safe_h], hcus.ti[safe_h], now, p)
    pj_sc = hcus.pj[safe_h, j_idx]                            # (K,)

    z1, e1, p1, w1, t1 = jax.vmap(
        lambda z, e, pp, t, w, zi, pi, pj: H.ops.col_update(
            z, e, pp, t, now, zi, pi, pj, H.coeffs_ij(p), p.eps,
            backend=backend, w_col=w)
    )(gcol(hcus.zij), gcol(hcus.eij), gcol(hcus.pij), gcol(hcus.tij),
      gcol(hcus.wij), zep_i.z, zep_i.p, pj_sc)

    put = lambda plane, val: plane.at[h_ix, r_ix, j_ix].set(val, mode="drop")
    hcus = hcus._replace(
        zij=put(hcus.zij, z1), eij=put(hcus.eij, e1), pij=put(hcus.pij, p1),
        wij=put(hcus.wij, w1))
    if n * R * p.cols <= H.DENSE_CELLS_MAX:
        # fused where beats scatter for the constant-valued Tij write and
        # the +1.0 Zj bump (XLA CPU scatter has a high fixed per-op cost);
        # bitwise-identical to the scatter branch.
        fired_hc = _fired_mask(h_idx, j_idx, n, hcus.zj.shape[1])
        return hcus._replace(
            tij=jnp.where(fired_hc[:, None, :], now, hcus.tij),
            zj=_bump_zj(hcus.zj, h_idx, j_idx, n, p))
    return hcus._replace(
        tij=put(hcus.tij, t1),
        zj=_bump_zj(hcus.zj, h_idx, j_idx, n, p))


def _row_worklist_common(hcus: H.HCUState, rows, t, p: BCPNNParams):
    """Shared lazy/merged worklist prologue: j-vector decay, per-HCU dedup,
    i-vector decay (identical math to `hcu.row_updates`) and worklist build.
    Returns a dict of intermediates; the i-vector write values are h-major
    flat (H*A,) arrays indexed by worklist slot."""
    n, A = rows.shape
    R = p.rows
    hcus = jax.vmap(lambda s: H._decay_jvec(s, p))(hcus)
    rows_u, counts = jax.vmap(lambda r: H.dedup_rows(r, R))(rows)
    safe = jnp.minimum(rows_u, R - 1)
    take = lambda v: jnp.take_along_axis(v, safe, axis=1)
    zi_g, ti_g = take(hcus.zi), take(hcus.ti)
    zep_i = H.ivec_decay(zi_g, take(hcus.ei), take(hcus.pi), ti_g, t, p)
    zi_new = zep_i.z + counts
    g_row, order, nv = WL.build_worklist(rows_u, R)
    return dict(
        hcus=hcus, n=n, A=A, rows_u=rows_u, counts=counts,
        zep_i=zep_i, zi_new=zi_new, zi_g=zi_g, ti_g=ti_g,
        g_row=g_row, order=order, nv=nv,
        iv_vals=(zi_new.reshape(-1), zep_i.e.reshape(-1),
                 zep_i.p.reshape(-1)))


def _flat_planes(hcus: H.HCUState):
    return tuple(L.flatten_plane(x)
                 for x in (hcus.zij, hcus.eij, hcus.pij, hcus.wij, hcus.tij))


def _unflatten_into(hcus: H.HCUState, flats, n: int) -> H.HCUState:
    z, e, pp, w, tt = (L.unflatten_plane(f, n) for f in flats)
    return hcus._replace(zij=z, eij=e, pij=pp, wij=w, tij=tt)


def _column_worklist(hcus: H.HCUState, h_idx, j_idx, now, p: BCPNNParams,
                     backend=None):
    """Worklist twin of `column_updates_batched`: same compacted fired batch,
    same vmapped per-cell compute graph (bitwise-identical values), but the
    (R, 1) column blocks are read and rewritten in place through dynamic
    slices on the flat planes instead of batched gather/scatter."""
    n = hcus.zij.shape[0]
    R = p.rows
    n_fired = jnp.sum(h_idx < n)
    safe_h = jnp.minimum(h_idx, n - 1)
    zep_i = H.ivec_decay(hcus.zi[safe_h], hcus.ei[safe_h],
                         hcus.pi[safe_h], hcus.ti[safe_h], now, p)
    pj_sc = hcus.pj[safe_h, j_idx]                            # (K,)
    flats = _flat_planes(hcus)
    zb, eb, pb, tb = WL.read_cols((flats[0], flats[1], flats[2], flats[4]),
                                  h_idx, j_idx, n_fired, R)
    # same vmap-of-col_update graph as column_updates_batched, fed from the
    # staged buffers (padding slots read zeros instead of clipped gathers;
    # their results are never written back)
    z1, e1, p1, w1, _ = jax.vmap(
        lambda z, e, pp, t, zi, pi, pj: H.ops.col_update(
            z, e, pp, t, now, zi, pi, pj, H.coeffs_ij(p), p.eps,
            backend=backend)
    )(zb, eb, pb, tb, zep_i.z, zep_i.p, pj_sc)
    flats = WL.write_cols(flats, h_idx, j_idx, n_fired, (z1, e1, p1, w1),
                          now, R)
    hcus = _unflatten_into(hcus, flats, n)
    # tij is already stamped by write_cols; only the Zj bump remains
    return hcus._replace(zj=_bump_zj(hcus.zj, h_idx, j_idx, n, p))


def lazy_batch_update(hcus: H.HCUState, rows, t, keys, p: BCPNNParams,
                      cap: int, backend: str | None = None,
                      worklist: bool | None = None,
                      cond_columns: bool = True):
    """Lazy-mode row+column updates and WTA for the local HCU batch.

    The single entry point shared by `_tick_core` and
    `distributed._local_tick`. Dispatches between the per-HCU vmap path and
    the flat-plane worklist path by `hcu.use_worklist(p, worklist)`; the two
    produce bitwise-identical trajectories (tests/test_worklist.py).
    Returns (hcus', fired, h_idx, j_idx, n_drop).
    """
    n = rows.shape[0]
    if not H.use_worklist(p, worklist):
        hcus, fired = jax.vmap(
            lambda s, r, k: H.hcu_tick_pre(s, r, t, k, p, backend=backend)
        )(hcus, rows, keys)
        h_idx, j_idx, n_drop = _select_fired(fired, cap)
        col = lambda hc: column_updates_batched(hc, h_idx, j_idx, t, p,
                                                backend=backend)
        if cond_columns:
            hcus = jax.lax.cond(jnp.any(h_idx < n), col, lambda hc: hc, hcus)
        else:
            hcus = col(hcus)
        return hcus, fired, h_idx, j_idx, n_drop

    c = _row_worklist_common(hcus, rows, t, p)
    hcus = c["hcus"]
    A = c["A"]
    kb = backend or ops.default_backend()
    if kb in ("pallas", "pallas_interpret"):
        # scalar-prefetch Pallas kernel: grid over worklist entries, planes
        # aliased in place (interpret mode on CPU)
        order = c["order"]
        h_of = order // A
        # padding entries get the H*R sentinel explicitly (order pads with
        # 0, which aliases a real row); ops routes sentinels onto the
        # kernel's junk row so they can never clobber a touched row
        W = order.shape[0]
        rows_k = jnp.where(jnp.arange(W) < c["nv"], c["g_row"][order],
                           n * p.rows)
        flats = ops.worklist_row_update(
            *_flat_planes(hcus), rows=rows_k, nv=c["nv"], now=t,
            counts=c["counts"].reshape(-1)[order],
            zj=hcus.zj[h_of], p_i=c["zep_i"].p.reshape(-1)[order],
            pj=hcus.pj[h_of], coeffs=H.coeffs_ij(p), eps=p.eps, backend=kb)
        hcus = _unflatten_into(hcus, flats, n)
        # i-vector writeback: the O(touched) scatter forms (native off-CPU)
        h_ix = jnp.arange(n)[:, None]
        put = lambda v, val: v.at[h_ix, c["rows_u"]].set(val, mode="drop")
        hcus = hcus._replace(
            zi=put(hcus.zi, c["zi_new"]), ei=put(hcus.ei, c["zep_i"].e),
            pi=put(hcus.pi, c["zep_i"].p),
            ti=put(hcus.ti, jnp.full(c["rows_u"].shape, t, hcus.ti.dtype)))
        w_g = flats[3][jnp.minimum(c["g_row"], n * p.rows - 1)]   # (W, C)
        w_rows = jnp.where((c["g_row"] < n * p.rows)[:, None], w_g, 0.0) \
            .reshape(n, A, p.cols)
    else:
        flats = _flat_planes(hcus)
        ivecs = tuple(L.flatten_vec(x)
                      for x in (hcus.zi, hcus.ei, hcus.pi, hcus.ti))
        bufs = WL.read_rows((flats[0], flats[1], flats[2], flats[4]),
                            c["g_row"], c["order"], c["nv"])
        # the per-HCU path's exact vmapped compute graph, fed from the
        # staged buffers (bitwise-identical values; padding slots read
        # zeros, their outputs are dropped / zero-count drive terms)
        sh = lambda b: b.reshape(n, A, p.cols)
        z1, e1, p1, w1, _ = jax.vmap(
            lambda z, e, pp, tt, cnt, zj, pi, pj: H.ops.row_update(
                z, e, pp, tt, t, cnt, zj, pi, pj, H.coeffs_ij(p), p.eps,
                backend=backend)
        )(sh(bufs[0]), sh(bufs[1]), sh(bufs[2]), sh(bufs[3]),
          c["counts"], hcus.zj, c["zep_i"].p, hcus.pj)
        w_rows = w1
        vals = tuple(v.reshape(n * A, p.cols) for v in (z1, e1, p1, w1))
        flats, ivecs = WL.write_rows(flats, ivecs, c["g_row"], c["order"],
                                     c["nv"], vals, c["iv_vals"], t)
        hcus = _unflatten_into(hcus, flats, n)
        zi, ei, pi, ti = (L.unflatten_vec(v, n) for v in ivecs)
        hcus = hcus._replace(zi=zi, ei=ei, pi=pi, ti=ti)

    hcus, fired = jax.vmap(
        lambda s, w, cnt, k: H.periodic_update(s, w, cnt, t, k, p)
    )(hcus, w_rows, c["counts"], keys)
    h_idx, j_idx, n_drop = _select_fired(fired, cap)
    if kb == "ref":
        col = lambda hc: _column_worklist(hc, h_idx, j_idx, t, p,
                                          backend=backend)
    else:
        col = lambda hc: column_updates_batched(hc, h_idx, j_idx, t, p,
                                                backend=backend)
    if cond_columns:
        hcus = jax.lax.cond(jnp.any(h_idx < n), col, lambda hc: hc, hcus)
    else:
        hcus = col(hcus)
    return hcus, fired, h_idx, j_idx, n_drop


def _merged_worklist_update(hcus: H.HCUState, jring, rows, t, keys,
                            p: BCPNNParams):
    """Worklist twin of `jax.vmap(merged.hcu_tick_merged)`: merged row
    updates (piecewise ring integration), WTA, overflow column flush,
    same-tick cell patch, ring push and Zj bump — all plane traffic through
    the in-place flat-plane loops. Bitwise-identical trajectories to the
    vmapped path (tests/test_worklist.py). Returns (hcus', jring', fired)."""
    from repro.core import merged as M
    n, A = rows.shape
    R = p.rows
    c = _row_worklist_common(hcus, rows, t, p)
    hcus = c["hcus"]

    flats = _flat_planes(hcus)
    ivecs = tuple(L.flatten_vec(x)
                  for x in (hcus.zi, hcus.ei, hcus.pi, hcus.ti))
    bufs = WL.read_rows((flats[0], flats[1], flats[2], flats[4]),
                        c["g_row"], c["order"], c["nv"])
    # vmapped merged_row_math: the exact compute graph of the per-HCU path
    sh = lambda b: b.reshape(n, A, p.cols)
    z1, e1, p1, w1 = jax.vmap(
        lambda z, e, pp, tt, g, zi, ti, cnt, zj, pi, pj: M.merged_row_math(
            z, e, pp, tt, g, zi, ti, cnt, zj, pi, pj, t, p)
    )(sh(bufs[0]), sh(bufs[1]), sh(bufs[2]), sh(bufs[3]), jring,
      c["zi_g"], c["ti_g"], c["counts"], hcus.zj, c["zep_i"].p, hcus.pj)
    w_rows = w1
    vals = tuple(v.reshape(n * A, p.cols) for v in (z1, e1, p1, w1))
    flats, ivecs = WL.write_rows(flats, ivecs, c["g_row"], c["order"],
                                 c["nv"], vals, c["iv_vals"], t)
    hcus = _unflatten_into(hcus, flats, n)
    zi, ei, pi, ti = (L.unflatten_vec(v, n) for v in ivecs)
    hcus = hcus._replace(zi=zi, ei=ei, pi=pi, ti=ti)

    hcus, fired = jax.vmap(
        lambda s, w, cnt, k: H.periodic_update(s, w, cnt, t, k, p)
    )(hcus, w_rows, c["counts"], keys)

    active = fired >= 0
    safe_j = jnp.maximum(fired, 0)
    overflow = active & (jring[jnp.arange(n), safe_j, 0] != M.RING_EMPTY)

    # overflow path: amortized classic column flush (fire applied, no push).
    # Kept on the per-HCU vmapped code verbatim rather than a worklist twin:
    # XLA:CPU's libm-vs-vectorized transcendental codegen is sensitive to
    # the surrounding program, so only the *same code at the same spot*
    # guarantees bitwise identity with the vmap path. This keeps the flush's
    # O(H*R) column gathers/puts on every merged tick (not just overflow
    # ticks) — a deliberate trade: cond-gating or worklist-rewriting it
    # would change its fusion context and break the 1-ulp identity, and the
    # lazy path (the perf-gated one) has no flush at all.
    hcus = jax.vmap(lambda s, g, j, ov: M.column_flush_merged(
        s, g, j, t, ov, p))(hcus, jring, safe_j, overflow)
    jring = jax.vmap(
        lambda g, sj, ov: g.at[sj].set(
            jnp.where(ov, jnp.full((M.RING_DEPTH,), M.RING_EMPTY, jnp.int32),
                      g[sj]))
    )(jring, safe_j, overflow)

    # normal path: defer via ring; patch only this tick's touched rows
    pa_idx, n_patch = WL.compact_mask(active & ~overflow)
    flats = _flat_planes(hcus)
    flats = (WL.patch_cells(flats[0], pa_idx, n_patch, c["rows_u"],
                            c["zi_new"], fired, R),) + flats[1:]
    hcus = _unflatten_into(hcus, flats, n)
    jring = jax.vmap(lambda g, j: M.push_ring(g, j, t))(
        jring, jnp.where(overflow, -1, fired))
    zj = jax.vmap(
        lambda z, sj, a: z.at[sj].add(jnp.where(a, 1.0, 0.0))
    )(hcus.zj, safe_j, active)
    return hcus._replace(zj=zj), jring, fired


def _tick_core(state: NetworkState, conn: Connectivity, ext_rows: jnp.ndarray,
               p: BCPNNParams, eager: bool, merged: bool,
               backend: str | None, cap_fire: int | None,
               worklist: bool | None = None):
    """Single-tick body shared by `network_tick` (per-tick jit) and
    `network_run` (lax.scan) — one implementation, bitwise-identical
    trajectories (and, at worklist scales, bitwise-identical between the
    per-HCU vmap forms and the flat-plane worklist forms)."""
    n = state.delay_rows.shape[0]
    t = state.t + 1
    cap = cap_fire or max(2, int(0.35 * n) + 1)

    # 1. consume this tick's delay bucket and merge with external input
    state, bucket = consume_bucket(state, t, p, n)
    rows = jnp.concatenate([bucket, ext_rows], axis=1)

    # 2. per-HCU tick (row updates + periodic/WTA), identical RNG all paths.
    #    The lazy path also pays its column updates here (compacted fired
    #    batch under lax.cond — the "power gating" of the lazy model; merged
    #    mode has no column pass at all, eBrainIII).
    k_t = jax.random.fold_in(state.base_key, t)
    keys = jax.vmap(lambda h: jax.random.fold_in(k_t, h))(jnp.arange(n))
    if eager:
        hcus, fired = jax.vmap(
            lambda s, r, k: reference.eager_tick(s, r, t, k, p)
        )(state.hcus, rows, keys)
        h_idx, j_idx, n_drop = _select_fired(fired, cap)
    elif merged:
        from repro.core import merged as M
        if H.use_worklist(p, worklist):
            hcus, jring, fired = _merged_worklist_update(
                state.hcus, state.jring, rows, t, keys, p)
        else:
            hcus, jring, fired = jax.vmap(
                lambda s, g, r, k: M.hcu_tick_merged(s, g, r, t, k, p)
            )(state.hcus, state.jring, rows, keys)
        state = state._replace(jring=jring)
        h_idx, j_idx, n_drop = _select_fired(fired, cap)
    else:
        hcus, fired, h_idx, j_idx, n_drop = lazy_batch_update(
            state.hcus, rows, t, keys, p, cap, backend=backend,
            worklist=worklist, cond_columns=True)
    state = state._replace(hcus=hcus, drops_fire=state.drops_fire + n_drop,
                           t=t)

    # 4. fan out spikes from the fired batch into delay queues
    safe_h = jnp.minimum(h_idx, n - 1)
    dest_h = conn.dest_hcu[safe_h, j_idx].reshape(-1)          # (K*F,)
    dest_r = conn.dest_row[safe_h, j_idx].reshape(-1)
    dly = conn.delay[safe_h, j_idx].reshape(-1)
    valid = jnp.repeat(h_idx < n, p.fanout)
    state = enqueue_spikes(state, dest_h, dest_r, dly, valid, p, n)
    return state, fired


@functools.partial(jax.jit, static_argnames=("p", "eager", "backend",
                                             "cap_fire", "merged",
                                             "worklist"),
                   donate_argnums=(0,))
def network_tick(state: NetworkState, conn: Connectivity, ext_rows: jnp.ndarray,
                 p: BCPNNParams, *, eager: bool = False, merged: bool = False,
                 backend: str | None = None, cap_fire: int | None = None,
                 worklist: bool | None = None):
    """Advance the whole network by one 1 ms tick.

    ext_rows: (H, A_ext) external input spikes (row index, padding == p.rows)
    Returns (state', fired (H,)) with fired[h] = MCU index or -1.
    merged=True runs the eBrainIII merged-column-update mode (core/merged.py;
    state must be built with init_network(..., merged=True)).
    worklist=True/False forces the flat-plane worklist runtime on/off
    (default: auto by size, `hcu.use_worklist`); trajectories are identical
    either way.
    """
    return _tick_core(state, conn, ext_rows, p, eager, merged, backend,
                      cap_fire, worklist)


@functools.partial(jax.jit, static_argnames=("p", "eager", "backend",
                                             "cap_fire", "merged",
                                             "worklist"),
                   donate_argnums=(0,))
def _run_chunk(state: NetworkState, conn: Connectivity, ext: jnp.ndarray,
               p: BCPNNParams, *, eager: bool, merged: bool,
               backend: str | None, cap_fire: int | None,
               worklist: bool | None):
    """One compiled scan over ext (T_chunk, H, A_ext): a single dispatch
    advances the network T_chunk ticks, threading the donated state."""
    def body(s, e):
        return _tick_core(s, conn, e, p, eager, merged, backend, cap_fire,
                          worklist)
    return jax.lax.scan(body, state, ext)


def network_run(state: NetworkState, conn: Connectivity, ext: jnp.ndarray,
                p: BCPNNParams, *, chunk: int = 128, eager: bool = False,
                merged: bool = False, backend: str | None = None,
                cap_fire: int | None = None, worklist: bool | None = None):
    """Scan-compiled multi-tick driver (see module docstring contract).

    ext: (T, H, A_ext) pre-staged external spikes — use `stage_external`.
    Returns (state', fired_hist (T, H) int32). Bitwise-equivalent to `run`
    with the same inputs, ~dispatch-free: one compiled step per `chunk`
    ticks instead of one per tick.
    """
    ext = jnp.asarray(ext)
    T = ext.shape[0]
    n = state.delay_rows.shape[0]
    if T == 0:
        return state, jnp.zeros((0, n), jnp.int32)
    hist = []
    i = 0
    while i < T:
        step = min(chunk, T - i)
        state, fired = _run_chunk(state, conn, ext[i:i + step], p,
                                  eager=eager, merged=merged, backend=backend,
                                  cap_fire=cap_fire, worklist=worklist)
        hist.append(fired)
        i += step
    return state, (hist[0] if len(hist) == 1 else jnp.concatenate(hist))


def stage_external(ext, n_ticks: int | None = None, t0: int = 0) -> jnp.ndarray:
    """Stage external input as the dense (T, H, A_ext) tensor `network_run`
    consumes. `ext` is either an iterable of (H, A_ext) arrays or a callable
    ext_fn(t) (the `run` protocol); t0 is state.t at entry, so ext_fn is
    sampled at t0+1 .. t0+n_ticks exactly like the host loop."""
    if callable(ext):
        assert n_ticks is not None, "n_ticks required with a callable"
        ext = [ext(t0 + 1 + k) for k in range(n_ticks)]
    else:
        ext = list(ext)
    return jnp.stack([jnp.asarray(e) for e in ext])


def run(state: NetworkState, conn: Connectivity, ext_fn, n_ticks: int,
        p: BCPNNParams, **kw):
    """Per-tick host-loop driver: ext_fn(t) -> (H, A_ext) external rows.

    One jit dispatch + `int(state.t)` host sync per tick — kept as the
    dispatch-bound baseline (benchmarks/tick_loop.py) and for callers that
    need host-side control between ticks. Production paths should stage
    input and use `network_run`.
    """
    fired_hist = []
    for _ in range(n_ticks):
        ext = ext_fn(int(state.t) + 1)
        state, fired = network_tick(state, conn, ext, p, **kw)
        fired_hist.append(fired)
    return state, jnp.stack(fired_hist)
