"""Multi-HCU BCPNN network: state, spike queues, routing, and tick drivers.

Maps the paper's infrastructure (§II.A.3, §IV, §VI.D-E) onto JAX:

  * delay queue  — (H, max_delay, A) ring of buckets indexed by arrival tick;
                   a spike with biological delay d lands in bucket (t+d) % D.
                   Bucket capacity A is the paper's active-queue size (36 for
                   human scale, from the Poisson tail analysis of Fig 7);
                   overflows are counted as drops, exactly the paper's
                   1-spike-per-month budget.
  * active queue — the bucket being consumed this tick (+ external input).
  * fanout       — static connectivity (dest_hcu, dest_row, delay) per MCU,
                   the analogue of the pipelined binary-tree spike NoC. In the
                   sharded runtime the tree becomes an all_to_all over fixed
                   per-device-pair buckets (see distributed.py).
  * column batching — only HCUs that actually fired pay for a column update;
                   fired HCUs are compacted into a fixed-capacity batch
                   (cap_fire) the same way spikes are queued.

Canonical state layout (PR 3)
-----------------------------
`NetworkState.hcus` stores the FLAT layout (`repro.core.layout`): ij planes
(H*R, C), i-vectors (H*R,), j-vectors (H, C). This is the layout the
worklist tick engine consumes natively and the layout checkpoints persist;
`hcu_view(state)` exposes the batched (H, R, C) view for per-HCU vmapped
code (`flush`, inspection, the dense engine backend). Old (H, R, C)-layout
checkpoints load through `repro.checkpoint.restore_network`'s migration
shim.

Tick pipeline
-------------
The tick body itself lives in `repro.core.engine`: one `tick` skeleton
(consume bucket -> plane update -> fan out) parameterized by a `TickBackend`
(DenseBackend per-HCU vmap vs WorklistBackend flat-plane worklist,
`engine.select_backend`). This module keeps the network *infrastructure* —
queues, spike routing, compaction — and the execution drivers:

  * `network_tick` — one jitted tick (host-loop building block).
  * `run`          — per-tick host loop (one jit dispatch + host sync per
                     ms). Kept as the baseline and for callers that need a
                     host-side decision between ticks.
  * `network_run`  — the production path: external input is pre-staged as a
                     dense (T, H, A_ext) tensor (`stage_external`), and the
                     loop is compiled with `jax.lax.scan` in chunks of
                     `chunk` ticks (default 128). Per chunk there is exactly
                     ONE dispatch; the NetworkState carry is donated and, at
                     worklist scales, IS the stored flat layout — no
                     per-tick reshapes, plane traffic O(touched rows).

All drivers share the exact same single-tick body, so their trajectories
are bitwise identical under a fixed PRNG key — in lazy, eager and merged
modes, on both the dense and worklist backends (tests/test_network_run.py,
tests/test_worklist.py, tests/test_engine_fixtures.py).

Scan-chunking contract:
  * ext staging      — ext[k] is consumed by tick t0+k+1 where t0 is
                       state.t at entry (matching `run`, which calls
                       ext_fn(state.t + 1) before each tick);
  * fired history    — returned as (T, H) int32, fired[k, h] = MCU index
                       that HCU h fired at tick t0+k+1, or -1;
  * chunking         — T need not divide by `chunk`: full chunks compile
                       one scan, the remainder compiles a second (at most
                       two compilations per (shape, mode));
  * donation         — the caller's `state` is donated; use the returned
                       state (same semantics as `network_tick`).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import hcu as H
from repro.core import layout as L
from repro.core.params import BCPNNParams


class Connectivity(NamedTuple):
    dest_hcu: jnp.ndarray   # (H, C, F) int32
    dest_row: jnp.ndarray   # (H, C, F) int32
    delay: jnp.ndarray      # (H, C, F) int32, in [1, max_delay-1]


class NetworkState(NamedTuple):
    hcus: H.HCUState        # CANONICAL FLAT layout (see module docstring)
    delay_rows: jnp.ndarray  # (H, D, A) int32; empty slots == R
    delay_count: jnp.ndarray  # (H, D) int32
    t: jnp.ndarray          # () int32 current time (ms)
    drops_in: jnp.ndarray   # () int32  — delay-queue overflow drops
    drops_fire: jnp.ndarray  # () int32 — fired-batch overflow drops
    base_key: jnp.ndarray   # PRNG key
    jring: jnp.ndarray | None = None   # (H, C, M) merged-mode spike rings
    # () int32 — inter-device route-capacity overflow drops (sharded fabric
    # only; local drivers never touch it). LAST field: pre-PR 7 checkpoints
    # are one trailing leaf short, which `checkpoint.restore_network` shims.
    drops_route: jnp.ndarray | None = None


def drop_counters(state: NetworkState) -> dict:
    """Cumulative spike-drop counters as a plain dict — the Fig 7 failure
    currency ({'in': delay-queue, 'fire': fired-batch, 'route': inter-device
    fabric overflows}). Tolerates pre-`drops_route` states (counts as 0)."""
    route = state.drops_route
    return {"in": int(state.drops_in), "fire": int(state.drops_fire),
            "route": 0 if route is None else int(route)}


def hcu_view(state: NetworkState, layout=None) -> H.HCUState:
    """Batched (H, R, C)/(H, R) view of `state.hcus` — the shape
    `jax.vmap`-over-HCUs consumers want, e.g.
    `jax.vmap(lambda s: flush(s, state.t, p))(hcu_view(state))`.
    Zero-copy on the canonical flat layout; under a blocked `layout` the ij
    planes are first unpacked to canonical order (`layout.load_hcus`, pure
    data movement)."""
    return L.batched_state(L.load_hcus(state.hcus, layout),
                           state.delay_rows.shape[0])


def make_connectivity(p: BCPNNParams, key, n_hcu: int | None = None) -> Connectivity:
    """Random static fanout: each MCU projects to `fanout` (HCU, row) targets
    with biological delays of mean ~`mean_delay` ms (truncated geometric)."""
    n = n_hcu or p.n_hcu
    k1, k2, k3 = jax.random.split(key, 3)
    shape = (n, p.cols, p.fanout)
    dest_hcu = jax.random.randint(k1, shape, 0, n, jnp.int32)
    dest_row = jax.random.randint(k2, shape, 0, p.rows, jnp.int32)
    lam = 1.0 / max(p.mean_delay - 1.0, 1e-3)
    geo = jnp.floor(jnp.log1p(-jax.random.uniform(k3, shape)) / -lam).astype(jnp.int32)
    delay = jnp.clip(1 + geo, 1, p.max_delay - 1)
    return Connectivity(dest_hcu, dest_row, delay)


def init_network(p: BCPNNParams, key, n_hcu: int | None = None,
                 merged: bool = False, layout=None) -> NetworkState:
    n = n_hcu or p.n_hcu
    # canonical flat layout, re-tiled iff a blocked layout is requested
    # (pure data movement — a blocked-layout network holds bitwise the same
    # logical values as a flat one)
    hcus = L.store_hcus(H.init_hcu_batch(p, n), layout)
    D, A = p.max_delay, p.active_queue
    jring = None
    if merged:
        from repro.core import merged as M
        jring = jnp.broadcast_to(M.init_ring(p),
                                 (n, p.cols, M.RING_DEPTH)).copy()
    return NetworkState(
        jring=jring,
        hcus=hcus,
        delay_rows=jnp.full((n, D, A), p.rows, jnp.int32),
        delay_count=jnp.zeros((n, D), jnp.int32),
        t=jnp.asarray(0, jnp.int32),
        drops_in=jnp.asarray(0, jnp.int32),
        drops_fire=jnp.asarray(0, jnp.int32),
        drops_route=jnp.asarray(0, jnp.int32),
        # private derived key: network_tick donates the state, so base_key
        # must not alias a caller-held (or sibling-network) buffer
        base_key=jax.random.fold_in(key, 0x5EED),
    )


# Below this message count the O(M^2) fused compare-reduce rank beats the
# sort-based path on op overhead; above it the sort path's O(M log M) wins.
_RANK_DENSE_MAX = 2048


def _rank_within_key(keys: jnp.ndarray) -> jnp.ndarray:
    """Rank of each element within its key group (stable: by position).

    rank[i] == #{j < i : keys[j] == keys[i]} — identical to position within
    the group under a stable sort.
    """
    M = keys.shape[0]
    if M <= _RANK_DENSE_MAX:
        eq = keys[:, None] == keys[None, :]                 # (M, M)
        earlier = jnp.arange(M)[None, :] < jnp.arange(M)[:, None]
        return jnp.sum(eq & earlier, axis=1).astype(keys.dtype)
    order = jnp.argsort(keys)                               # stable
    sorted_keys = keys[order]
    idx = jnp.arange(M)
    is_first = jnp.concatenate([jnp.array([True]),
                                sorted_keys[1:] != sorted_keys[:-1]])
    first_pos = jax.lax.cummax(jnp.where(is_first, idx, 0))
    rank_sorted = idx - first_pos
    return jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)


def consume_bucket(state: NetworkState, t, p: BCPNNParams, n: int):
    """Read this tick's delay bucket and clear it. Shared by the local and
    sharded tick bodies (keeping their trajectories identical). On small
    networks the clear is a fused iota-compare where (cheaper than the
    dynamic-update-slice scatter on XLA CPU); at scale the slice update
    touches only the one bucket."""
    D = p.max_delay
    bucket = state.delay_rows[:, t % D, :]                     # (H, A)
    if n * D * p.active_queue <= H.DENSE_CELLS_MAX:
        is_bucket = jnp.arange(D) == t % D                     # (D,)
        state = state._replace(
            delay_rows=jnp.where(is_bucket[None, :, None], p.rows,
                                 state.delay_rows),
            delay_count=jnp.where(is_bucket[None, :], 0, state.delay_count))
    else:
        state = state._replace(
            delay_rows=state.delay_rows.at[:, t % D, :].set(p.rows),
            delay_count=state.delay_count.at[:, t % D].set(0))
    return state, bucket


def enqueue_spikes(state: NetworkState, dest_h, dest_row, delay, valid,
                   p: BCPNNParams, n_hcu: int):
    """Insert a flat batch of spike messages into the delay queues.

    Fixed-capacity slot allocation: messages are ranked within their
    (dest_hcu, bucket) group; slot = current_count + rank; messages whose slot
    exceeds the bucket capacity A are dropped and counted (paper Fig 7).
    """
    D, A = p.max_delay, p.active_queue
    M = dest_h.shape[0]
    bucket = (state.t + delay) % D
    key = jnp.where(valid, dest_h * D + bucket, n_hcu * D)      # invalid rank last
    rank = _rank_within_key(key)
    base = state.delay_count[dest_h, bucket]                    # (M,)
    slot = base + rank
    ok = valid & (slot < A)
    flat_idx = jnp.where(ok, (dest_h * D + bucket) * A + slot, n_hcu * D * A)
    delay_rows = state.delay_rows.reshape(-1).at[flat_idx].set(
        dest_row, mode="drop").reshape(n_hcu, D, A)
    # bucket occupancy: add arrivals, clip at capacity
    if M * n_hcu * D <= H.DENSE_CELLS_MAX:
        # dense compare+reduce ((M, H*D) one-hot sum) instead of
        # scatter-add: integer sum is order-independent (bitwise-identical)
        # and avoids the scatter op cost on small networks. `key` is the
        # (h, bucket) flat index with invalids sent out of range.
        arrivals = jnp.sum(
            (key[:, None] == jnp.arange(n_hcu * D)[None, :]).astype(jnp.int32),
            axis=0).reshape(n_hcu, D)
    else:
        arrivals = jnp.zeros((n_hcu, D), jnp.int32).at[dest_h, bucket].add(
            valid.astype(jnp.int32), mode="drop")
    new_count = jnp.minimum(state.delay_count + arrivals, A)
    dropped = jnp.sum(state.delay_count + arrivals - new_count)
    return state._replace(delay_rows=delay_rows, delay_count=new_count,
                          drops_in=state.drops_in + dropped)


def select_fired(fired: jnp.ndarray, cap: int):
    """Compact fired HCU indices (fired[h] >= 0) into `cap` slots."""
    n = fired.shape[0]
    is_fired = fired >= 0
    order = jnp.argsort(~is_fired)              # fired first, stable
    idx = order[:cap]
    sel_valid = is_fired[idx]
    h_idx = jnp.where(sel_valid, idx, n)
    j_idx = jnp.where(sel_valid, fired[idx], 0)
    n_dropped = jnp.sum(is_fired) - jnp.sum(sel_valid)
    return h_idx.astype(jnp.int32), j_idx.astype(jnp.int32), n_dropped


# ---------------------------------------------------------------------------
# session batching (serving): a leading (S,) lane dim over NetworkState
# ---------------------------------------------------------------------------

def stack_sessions(state: NetworkState, n_sessions: int) -> NetworkState:
    """Replicate one NetworkState into `n_sessions` independent session
    lanes: every leaf gains a leading (S,) batch dim.

    Each lane then evolves under its own per-session external stream — the
    state layout the continuous-batching recall server
    (`repro.launch.serve_bcpnn`) carries. Lanes must be advanced with
    `jax.lax.map` (NOT vmap): lax.map runs one lane at a time with exactly
    the single-session `_run_chunk` graph and shapes, so lane trajectories
    stay bitwise identical to independent `Simulator.run` calls; vmap would
    fuse across lanes, and XLA:CPU fused codegen is 1-ulp context-sensitive
    (docs/NUMERICS.md).
    """
    def rep(a):
        a = jnp.asarray(a)
        return jnp.repeat(a[None], n_sessions, axis=0)
    return jax.tree.map(rep, state)


@functools.partial(jax.jit, donate_argnums=(0,))
def write_sessions(stacked: NetworkState, template: NetworkState,
                   lanes: jnp.ndarray) -> NetworkState:
    """Scatter a fresh `template` into the session lanes named by `lanes`
    ((K,) int32; out-of-range entries are dropped, so pad with S to write
    fewer than K lanes with one compiled shape). The stacked state is
    donated: slot recycling writes freed lanes in place — admission never
    copies the other lanes or recompiles."""
    def put(st, tp):
        tp = jnp.asarray(tp)
        rep = jnp.broadcast_to(tp[None], (lanes.shape[0],) + tp.shape)
        return st.at[lanes].set(rep, mode="drop")
    return jax.tree.map(put, stacked, template)


def take_session(stacked: NetworkState, lane: int) -> NetworkState:
    """One session lane back as a plain single-session NetworkState
    (inspection / the bitwise-vs-Simulator serving tests)."""
    return jax.tree.map(lambda a: a[lane], stacked)


# ---------------------------------------------------------------------------
# execution drivers (thin wrappers over engine.tick)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("p", "eager", "backend",
                                             "cap_fire", "merged",
                                             "worklist", "fused",
                                             "fused_cols", "layout"),
                   donate_argnums=(0,))
def network_tick(state: NetworkState, conn: Connectivity, ext_rows: jnp.ndarray,
                 p: BCPNNParams, *, eager: bool = False, merged: bool = False,
                 backend: str | None = None, cap_fire: int | None = None,
                 worklist: bool | None = None, fused: bool | None = None,
                 fused_cols: bool | None = None, layout=None):
    """Advance the whole network by one 1 ms tick.

    ext_rows: (H, A_ext) external input spikes (row index, padding == p.rows)
    Returns (state', fired (H,)) with fired[h] = MCU index or -1.
    merged=True runs the eBrainIII merged-column-update mode (core/merged.py;
    state must be built with init_network(..., merged=True)).
    worklist=True/False forces the worklist engine backend on/off (default:
    auto by size, `hcu.use_worklist`); fused=True/False likewise forces the
    worklist backend's single-pass fused row phase (default: on,
    `hcu.use_fused_rows`) and fused_cols=True/False its single-pass fused
    column phase (default: on, `hcu.use_fused_cols`); trajectories are
    identical every way.
    layout selects the plane storage order (None/"flat" canonical flat,
    "blocked"/"blocked_tpu"/a `layout.BlockedLayout` for column-blocked
    tiles; `state.hcus` must be stored in that layout) — trajectories are
    identical under every layout (storage order, not math).
    """
    from repro.core import engine as E
    be = E.select_backend(p, eager=eager, merged=merged, worklist=worklist,
                          kernel=backend, fused=fused, fused_cols=fused_cols,
                          layout=layout)
    state, fired = E.tick(be.carry_in(state, p), conn, ext_rows, p, be,
                          cap_fire)
    return be.carry_out(state, p), fired


@functools.partial(jax.jit, static_argnames=("p", "eager", "backend",
                                             "cap_fire", "merged",
                                             "worklist", "fused",
                                             "fused_cols", "layout"),
                   donate_argnums=(0,))
def _run_chunk(state: NetworkState, conn: Connectivity, ext: jnp.ndarray,
               p: BCPNNParams, *, eager: bool, merged: bool,
               backend: str | None, cap_fire: int | None,
               worklist: bool | None, fused: bool | None,
               fused_cols: bool | None, layout=None):
    """One compiled scan over ext (T_chunk, H, A_ext): a single dispatch
    advances the network T_chunk ticks, threading the donated state. The
    backend picks the carry layout ONCE per chunk (`carry_in`/`carry_out` at
    the scan boundary): the worklist backend's carry is the stored flat
    layout itself, so the tick body has zero per-tick reshapes."""
    from repro.core import engine as E
    be = E.select_backend(p, eager=eager, merged=merged, worklist=worklist,
                          kernel=backend, fused=fused, fused_cols=fused_cols,
                          layout=layout)

    def body(s, e):
        return E.tick(s, conn, e, p, be, cap_fire)

    state, fired = jax.lax.scan(body, be.carry_in(state, p), ext)
    return be.carry_out(state, p), fired


def network_run(state: NetworkState, conn: Connectivity, ext: jnp.ndarray,
                p: BCPNNParams, *, chunk: int = 128, eager: bool = False,
                merged: bool = False, backend: str | None = None,
                cap_fire: int | None = None, worklist: bool | None = None,
                fused: bool | None = None, fused_cols: bool | None = None,
                layout=None):
    """Scan-compiled multi-tick driver (see module docstring contract).

    ext: (T, H, A_ext) pre-staged external spikes — use `stage_external`.
    Returns (state', fired_hist (T, H) int32). Bitwise-equivalent to `run`
    with the same inputs, ~dispatch-free: one compiled step per `chunk`
    ticks instead of one per tick.
    """
    ext = jnp.asarray(ext)
    T = ext.shape[0]
    n = state.delay_rows.shape[0]
    if T == 0:
        return state, jnp.zeros((0, n), jnp.int32)
    hist = []
    i = 0
    while i < T:
        step = min(chunk, T - i)
        state, fired = _run_chunk(state, conn, ext[i:i + step], p,
                                  eager=eager, merged=merged, backend=backend,
                                  cap_fire=cap_fire, worklist=worklist,
                                  fused=fused, fused_cols=fused_cols,
                                  layout=layout)
        hist.append(fired)
        i += step
    return state, (hist[0] if len(hist) == 1 else jnp.concatenate(hist))


def stage_external(ext, n_ticks: int | None = None, t0: int = 0) -> jnp.ndarray:
    """Stage external input as the dense (T, H, A_ext) tensor `network_run`
    consumes. `ext` is either an iterable of (H, A_ext) arrays or a callable
    ext_fn(t) (the `run` protocol); t0 is state.t at entry, so ext_fn is
    sampled at t0+1 .. t0+n_ticks exactly like the host loop."""
    if callable(ext):
        assert n_ticks is not None, "n_ticks required with a callable"
        ext = [ext(t0 + 1 + k) for k in range(n_ticks)]
    else:
        ext = list(ext)
    return jnp.stack([jnp.asarray(e) for e in ext])


def run(state: NetworkState, conn: Connectivity, ext_fn, n_ticks: int,
        p: BCPNNParams, **kw):
    """Per-tick host-loop driver: ext_fn(t) -> (H, A_ext) external rows.

    One jit dispatch + `int(state.t)` host sync per tick — kept as the
    dispatch-bound baseline (benchmarks/tick_loop.py) and for callers that
    need host-side control between ticks. Production paths should stage
    input and use `network_run`.
    """
    fired_hist = []
    for _ in range(n_ticks):
        ext = ext_fn(int(state.t) + 1)
        state, fired = network_tick(state, conn, ext, p, **kw)
        fired_hist.append(fired)
    return state, jnp.stack(fired_hist)
