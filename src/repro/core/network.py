"""Multi-HCU BCPNN network: spike queues, routing, and the tick loop.

Maps the paper's infrastructure (§II.A.3, §IV, §VI.D-E) onto JAX:

  * delay queue  — (H, max_delay, A) ring of buckets indexed by arrival tick;
                   a spike with biological delay d lands in bucket (t+d) % D.
                   Bucket capacity A is the paper's active-queue size (36 for
                   human scale, from the Poisson tail analysis of Fig 7);
                   overflows are counted as drops, exactly the paper's
                   1-spike-per-month budget.
  * active queue — the bucket being consumed this tick (+ external input).
  * fanout       — static connectivity (dest_hcu, dest_row, delay) per MCU,
                   the analogue of the pipelined binary-tree spike NoC. In the
                   sharded runtime the tree becomes an all_to_all over fixed
                   per-device-pair buckets (see distributed.py).
  * column batching — only HCUs that actually fired pay for a column update;
                   fired HCUs are compacted into a fixed-capacity batch
                   (cap_fire) the same way spikes are queued.

Everything is a pure function of NetworkState; `eager=True` swaps the lazy
HCU pipeline for the dense golden reference with identical queue semantics
and RNG stream, so the two trajectories are directly comparable.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import hcu as H
from repro.core import reference
from repro.core.params import BCPNNParams
from repro.core.traces import ZEP, decay_zep


class Connectivity(NamedTuple):
    dest_hcu: jnp.ndarray   # (H, C, F) int32
    dest_row: jnp.ndarray   # (H, C, F) int32
    delay: jnp.ndarray      # (H, C, F) int32, in [1, max_delay-1]


class NetworkState(NamedTuple):
    hcus: H.HCUState        # leading axis H on every leaf
    delay_rows: jnp.ndarray  # (H, D, A) int32; empty slots == R
    delay_count: jnp.ndarray  # (H, D) int32
    t: jnp.ndarray          # () int32 current time (ms)
    drops_in: jnp.ndarray   # () int32  — delay-queue overflow drops
    drops_fire: jnp.ndarray  # () int32 — fired-batch overflow drops
    base_key: jnp.ndarray   # PRNG key
    jring: jnp.ndarray | None = None   # (H, C, M) merged-mode spike rings


def make_connectivity(p: BCPNNParams, key, n_hcu: int | None = None) -> Connectivity:
    """Random static fanout: each MCU projects to `fanout` (HCU, row) targets
    with biological delays of mean ~`mean_delay` ms (truncated geometric)."""
    n = n_hcu or p.n_hcu
    k1, k2, k3 = jax.random.split(key, 3)
    shape = (n, p.cols, p.fanout)
    dest_hcu = jax.random.randint(k1, shape, 0, n, jnp.int32)
    dest_row = jax.random.randint(k2, shape, 0, p.rows, jnp.int32)
    lam = 1.0 / max(p.mean_delay - 1.0, 1e-3)
    geo = jnp.floor(jnp.log1p(-jax.random.uniform(k3, shape)) / -lam).astype(jnp.int32)
    delay = jnp.clip(1 + geo, 1, p.max_delay - 1)
    return Connectivity(dest_hcu, dest_row, delay)


def init_network(p: BCPNNParams, key, n_hcu: int | None = None,
                 merged: bool = False) -> NetworkState:
    n = n_hcu or p.n_hcu
    hcus = jax.vmap(lambda _: H.init_hcu_state(p))(jnp.arange(n))
    D, A = p.max_delay, p.active_queue
    jring = None
    if merged:
        from repro.core import merged as M
        jring = jnp.broadcast_to(M.init_ring(p),
                                 (n, p.cols, M.RING_DEPTH)).copy()
    return NetworkState(
        jring=jring,
        hcus=hcus,
        delay_rows=jnp.full((n, D, A), p.rows, jnp.int32),
        delay_count=jnp.zeros((n, D), jnp.int32),
        t=jnp.asarray(0, jnp.int32),
        drops_in=jnp.asarray(0, jnp.int32),
        drops_fire=jnp.asarray(0, jnp.int32),
        # private derived key: network_tick donates the state, so base_key
        # must not alias a caller-held (or sibling-network) buffer
        base_key=jax.random.fold_in(key, 0x5EED),
    )


def _rank_within_key(keys: jnp.ndarray, order: jnp.ndarray) -> jnp.ndarray:
    """Given sort order of `keys`, rank of each element within its key group."""
    sorted_keys = keys[order]
    idx = jnp.arange(keys.shape[0])
    is_first = jnp.concatenate([jnp.array([True]), sorted_keys[1:] != sorted_keys[:-1]])
    first_pos = jnp.where(is_first, idx, 0)
    first_pos = jax.lax.associative_scan(jnp.maximum, first_pos)
    rank_sorted = idx - first_pos
    rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)
    return rank


def enqueue_spikes(state: NetworkState, dest_h, dest_row, delay, valid,
                   p: BCPNNParams, n_hcu: int):
    """Insert a flat batch of spike messages into the delay queues.

    Fixed-capacity slot allocation: messages are ranked within their
    (dest_hcu, bucket) group; slot = current_count + rank; messages whose slot
    exceeds the bucket capacity A are dropped and counted (paper Fig 7).
    """
    D, A = p.max_delay, p.active_queue
    M = dest_h.shape[0]
    bucket = (state.t + delay) % D
    key = jnp.where(valid, dest_h * D + bucket, n_hcu * D)      # invalid sort last
    order = jnp.argsort(key)
    rank = _rank_within_key(key, order)
    base = state.delay_count[dest_h, bucket]                    # (M,)
    slot = base + rank
    ok = valid & (slot < A)
    flat_idx = jnp.where(ok, (dest_h * D + bucket) * A + slot, n_hcu * D * A)
    delay_rows = state.delay_rows.reshape(-1).at[flat_idx].set(
        dest_row, mode="drop").reshape(n_hcu, D, A)
    # bucket occupancy: add arrivals, clip at capacity
    arrivals = jnp.zeros((n_hcu, D), jnp.int32).at[dest_h, bucket].add(
        valid.astype(jnp.int32), mode="drop")
    new_count = jnp.minimum(state.delay_count + arrivals, A)
    dropped = jnp.sum(state.delay_count + arrivals - new_count)
    return state._replace(delay_rows=delay_rows, delay_count=new_count,
                          drops_in=state.drops_in + dropped)


def _select_fired(fired: jnp.ndarray, cap: int):
    """Compact fired HCU indices (fired[h] >= 0) into `cap` slots."""
    n = fired.shape[0]
    is_fired = fired >= 0
    order = jnp.argsort(~is_fired)              # fired first, stable
    idx = order[:cap]
    sel_valid = is_fired[idx]
    h_idx = jnp.where(sel_valid, idx, n)
    j_idx = jnp.where(sel_valid, fired[idx], 0)
    n_dropped = jnp.sum(is_fired) - jnp.sum(sel_valid)
    return h_idx.astype(jnp.int32), j_idx.astype(jnp.int32), n_dropped


def column_updates_batched(hcus: H.HCUState, h_idx, j_idx, now,
                           p: BCPNNParams, backend=None) -> H.HCUState:
    """Lazy column updates for the compacted fired batch (network level).

    h_idx: (K,) HCU indices (== H for padding -> scatter-dropped);
    j_idx: (K,) fired MCU column per slot.

    Gathers exactly the K (R,)-columns that fired (plus the K i-vectors) —
    never whole HCU states — so the cost is K*R cells, matching the paper's
    column-update traffic budget.
    """
    n = hcus.zij.shape[0]
    K = h_idx.shape[0]
    R = p.rows
    safe_h = jnp.minimum(h_idx, n - 1)
    h_ix = h_idx[:, None]                     # (K,1): padding == n -> dropped
    sh_ix = safe_h[:, None]
    r_ix = jnp.arange(R)[None, :]
    j_ix = j_idx[:, None]

    gcol = lambda plane: plane[sh_ix, r_ix, j_ix]             # (K, R)
    # i-vector traces brought to `now` (values only, no writeback)
    d_i = (now - hcus.ti[safe_h]).astype(hcus.zi.dtype)       # (K, R)
    zep_i = decay_zep(ZEP(hcus.zi[safe_h], hcus.ei[safe_h],
                          hcus.pi[safe_h]), d_i, H.coeffs_i(p))
    pj_sc = hcus.pj[safe_h, j_idx]                            # (K,)

    z1, e1, p1, w1, t1 = jax.vmap(
        lambda z, e, pp, t, zi, pi, pj: H.ops.col_update(
            z, e, pp, t, now, zi, pi, pj, H.coeffs_ij(p), p.eps,
            backend=backend)
    )(gcol(hcus.zij), gcol(hcus.eij), gcol(hcus.pij), gcol(hcus.tij),
      zep_i.z, zep_i.p, pj_sc)

    put = lambda plane, val: plane.at[h_ix, r_ix, j_ix].set(val, mode="drop")
    hcus = hcus._replace(
        zij=put(hcus.zij, z1), eij=put(hcus.eij, e1), pij=put(hcus.pij, p1),
        wij=put(hcus.wij, w1), tij=put(hcus.tij, t1))
    zj = hcus.zj.at[h_idx, j_idx].add(1.0, mode="drop")
    return hcus._replace(zj=zj)


@functools.partial(jax.jit, static_argnames=("p", "eager", "backend",
                                             "cap_fire", "merged"),
                   donate_argnums=(0,))
def network_tick(state: NetworkState, conn: Connectivity, ext_rows: jnp.ndarray,
                 p: BCPNNParams, *, eager: bool = False, merged: bool = False,
                 backend: str | None = None, cap_fire: int | None = None):
    """Advance the whole network by one 1 ms tick.

    ext_rows: (H, A_ext) external input spikes (row index, padding == p.rows)
    Returns (state', fired (H,)) with fired[h] = MCU index or -1.
    merged=True runs the eBrainIII merged-column-update mode (core/merged.py;
    state must be built with init_network(..., merged=True)).
    """
    n = state.delay_rows.shape[0]
    D = p.max_delay
    t = state.t + 1
    cap = cap_fire or max(2, int(0.35 * n) + 1)

    # 1. consume this tick's delay bucket and merge with external input
    bucket = state.delay_rows[:, t % D, :]                     # (H, A)
    rows = jnp.concatenate([bucket, ext_rows], axis=1)
    state = state._replace(
        delay_rows=state.delay_rows.at[:, t % D, :].set(p.rows),
        delay_count=state.delay_count.at[:, t % D].set(0))

    # 2. per-HCU tick (row updates + periodic/WTA), identical RNG all paths
    k_t = jax.random.fold_in(state.base_key, t)
    keys = jax.vmap(lambda h: jax.random.fold_in(k_t, h))(jnp.arange(n))
    if eager:
        hcus, fired = jax.vmap(
            lambda s, r, k: reference.eager_tick(s, r, t, k, p)
        )(state.hcus, rows, keys)
    elif merged:
        from repro.core import merged as M
        hcus, jring, fired = jax.vmap(
            lambda s, g, r, k: M.hcu_tick_merged(s, g, r, t, k, p)
        )(state.hcus, state.jring, rows, keys)
        state = state._replace(jring=jring)
    else:
        hcus, fired = jax.vmap(
            lambda s, r, k: H.hcu_tick_pre(s, r, t, k, p, backend=backend)
        )(state.hcus, rows, keys)

    # 3. compact fired HCUs; lazy path pays its column updates here.
    #    lax.cond skips the whole column pass on silent ticks (~90% of ticks
    #    at out_rate=0.1) — the "power gating" of the lazy model. Merged
    #    mode has no column pass at all (eBrainIII).
    h_idx, j_idx, n_drop = _select_fired(fired, cap)
    if not eager and not merged:
        hcus = jax.lax.cond(
            jnp.any(h_idx < n),
            lambda hc: column_updates_batched(hc, h_idx, j_idx, t, p,
                                              backend=backend),
            lambda hc: hc,
            hcus)
    state = state._replace(hcus=hcus, drops_fire=state.drops_fire + n_drop,
                           t=t)

    # 4. fan out spikes from the fired batch into delay queues
    safe_h = jnp.minimum(h_idx, n - 1)
    dest_h = conn.dest_hcu[safe_h, j_idx].reshape(-1)          # (K*F,)
    dest_r = conn.dest_row[safe_h, j_idx].reshape(-1)
    dly = conn.delay[safe_h, j_idx].reshape(-1)
    valid = jnp.repeat(h_idx < n, p.fanout)
    state = enqueue_spikes(state, dest_h, dest_r, dly, valid, p, n)
    return state, fired


def run(state: NetworkState, conn: Connectivity, ext_fn, n_ticks: int,
        p: BCPNNParams, **kw):
    """Host-loop driver: ext_fn(t) -> (H, A_ext) external spike rows."""
    fired_hist = []
    for _ in range(n_ticks):
        ext = ext_fn(int(state.t) + 1)
        state, fired = network_tick(state, conn, ext, p, **kw)
        fired_hist.append(fired)
    return state, jnp.stack(fired_hist)
