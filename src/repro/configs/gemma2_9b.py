"""gemma2-9b [dense] — local+global alternating, logit softcap
[arXiv:2408.00118; hf].

42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000. head_dim=256,
sliding window 4096 on odd (local) layers, attn softcap 50, final softcap 30,
GeGLU, tied embeddings, query scale 1/sqrt(256).
"""
from repro.configs import shrink
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="gemma2-9b", family="dense", n_layers=42, d_model=3584,
    n_heads=16, n_kv=8, d_ff=14336, vocab=256000, head_dim=256,
    local_global_period=2, sliding_window=4096,
    attn_softcap=50.0, final_softcap=30.0, act="gelu",
    tie_embeddings=True, rope_theta=10_000.0,
)

SMOKE = shrink(CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv=2,
               head_dim=16, d_ff=128, vocab=512, sliding_window=8)
