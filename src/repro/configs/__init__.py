"""Architecture registry: --arch <id> resolves here.

Each module defines CONFIG (the exact published dims) and SMOKE (a reduced
same-family config for CPU smoke tests). BCPNN scale presets live in
bcpnn_human / bcpnn_rodent.
"""
from __future__ import annotations

import dataclasses
import importlib

ARCH_IDS = [
    "xlstm-125m",
    "internlm2-1.8b",
    "stablelm-3b",
    "qwen2-1.5b",
    "gemma2-9b",
    "qwen3-moe-235b-a22b",
    "llama4-maverick-400b-a17b",
    "llama-3.2-vision-11b",
    "zamba2-7b",
    "whisper-large-v3",
]

BCPNN_IDS = ["bcpnn-human", "bcpnn-rodent"]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS + BCPNN_IDS}


def get_config(arch_id: str):
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def get_smoke_config(arch_id: str):
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.SMOKE


def shrink(cfg, **over):
    return dataclasses.replace(cfg, **over)
