"""whisper-large-v3 [audio] — enc-dec, conv frontend (stub)
[arXiv:2212.04356; unverified].

32L d_model=1280 20H (kv=20) d_ff=5120 vocab=51866. Encoder-decoder: 32
bidirectional encoder layers over stub conv-frontend frame embeddings
(B, 1500, 1280) + 32 decoder layers with cross-attention. Decoder uses
learned positions. The real model caps decoding at 448 positions; the
assigned 32k decode cells exercise the backbone beyond that cap (noted in
DESIGN.md).
"""
from repro.configs import shrink
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="whisper-large-v3", family="audio", n_layers=32, d_model=1280,
    n_heads=20, n_kv=20, d_ff=5120, vocab=51866,
    enc_dec=True, n_enc_layers=32, n_enc_frames=1500, vision_dim=1280,
    rotary_pct=0.0,   # whisper uses absolute positions, not RoPE
)

SMOKE = shrink(CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=128,
               vocab=512, n_enc_layers=2, n_enc_frames=16, vision_dim=64)
