"""llama-3.2-vision-11b [vlm] — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256; every 5th layer is
a gated cross-attention layer over vision patch embeddings. The vision tower
is a STUB: input_specs() provides precomputed patch embeddings
(B, 1601, 1280) projected into d_model.
"""
from repro.configs import shrink
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="llama-3.2-vision-11b", family="vlm", n_layers=40, d_model=4096,
    n_heads=32, n_kv=8, d_ff=14336, vocab=128256, head_dim=128,
    cross_attn_period=5, n_patches=1601, vision_dim=1280,
    rope_theta=500_000.0,
)

SMOKE = shrink(CONFIG, n_layers=5, d_model=64, n_heads=4, n_kv=2,
               head_dim=16, d_ff=128, vocab=512, n_patches=16, vision_dim=32)
