"""Rodent-scale BCPNN (paper SVII.C): 32K HCUs, R=1200, C=70.

~2 MB per HCU -> 64 GB total: fits a pod with wide margin; this is the
primary runnable BCPNN dry-run config (the paper similarly demonstrates
rodent scale end-to-end, 12 W / real time).
"""
from repro.core.params import rodent_scale

CONFIG = rodent_scale()
DRYRUN_N_HCU = 32_768                     # pow2 for even sharding (paper: 32K)
SMOKE = rodent_scale(n_hcu=2)
