"""Human-scale BCPNN (paper SII.A): 2M HCUs, R=10000, C=100.

Full human scale needs 50 TB of synaptic state — beyond one 512-chip pod
(paper: 62.5K BCUs). The dry-run config uses the number of HCUs that
saturates a pod at ~70% HBM (v5e 16 GiB/chip), with the full-scale numbers
reported analytically in benchmarks/table1_requirements.py, mirroring the
paper (which measured rodent scale and extrapolated).
"""
from repro.core.params import human_scale

CONFIG = human_scale()                    # full 2M-HCU spec (analytic)
# 25 MB/HCU: 65536 HCUs ~ 1.6 TB -> ~6.4 GB/chip on 256 chips (fits HBM);
# the FULL 2M-HCU human scale needs ~31 such pods - reported analytically.
DRYRUN_N_HCU = 65_536
SMOKE = human_scale(n_hcu=2)
