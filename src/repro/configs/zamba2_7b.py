"""zamba2-7b [hybrid] — Mamba2 + shared attn blocks [arXiv:2411.15242;
unverified].

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64.
Backbone: Mamba2 blocks; ONE weight-shared attention+MLP block invoked every
6 mamba layers (13 invocations + 3 trailing mamba layers). The real model
adds per-invocation LoRA deltas on the shared block; we share weights
exactly and note the simplification in DESIGN.md.
"""
from repro.configs import shrink
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="zamba2-7b", family="hybrid", n_layers=81, d_model=3584,
    n_heads=32, n_kv=32, d_ff=14336, vocab=32000,
    ssm_kind="mamba2", ssm_state=64, ssm_expand=2, attn_period=6,
)

SMOKE = shrink(CONFIG, n_layers=8, d_model=64, n_heads=4, n_kv=4, d_ff=128,
               vocab=512, ssm_state=16, attn_period=3)
