"""xlstm-125m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

12L d_model=768 4H (GQA kv=4) d_ff=0 vocab=50304. xLSTM[7:1]: every 8th
block is sLSTM, the rest mLSTM (d_ff=0: blocks carry their own projections —
mLSTM pre-up-projection x2, sLSTM post-FFN 4/3).
"""
from repro.configs import shrink
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="xlstm-125m", family="ssm", n_layers=12, d_model=768,
    n_heads=4, n_kv=4, d_ff=0, vocab=50304,
    ssm_kind="xlstm", ssm_expand=2, slstm_period=8,
)

SMOKE = shrink(CONFIG, n_layers=9, d_model=64, n_heads=4, n_kv=4, vocab=512)
