"""qwen3-moe-235b-a22b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf].

94L d_model=4096 64H (GQA kv=4) d_ff=1536 (per expert) vocab=151936,
MoE 128e top-8, head_dim=128, every layer MoE.
"""
from repro.configs import shrink
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen3-moe-235b-a22b", family="moe", n_layers=94, d_model=4096,
    n_heads=64, n_kv=4, d_ff=1536, vocab=151936, head_dim=128,
    n_experts=128, top_k=8, expert_d_ff=1536, moe_period=1,
    rope_theta=1_000_000.0,
)

SMOKE = shrink(CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=2,
               head_dim=16, d_ff=32, expert_d_ff=32, n_experts=8, top_k=2,
               vocab=512)
