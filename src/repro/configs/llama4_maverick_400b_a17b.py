"""llama4-maverick-400b-a17b [moe] — MoE top-1 + shared expert, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1,
interleaved dense/MoE layers (moe_period=2), one shared expert.
Early fusion: multimodal tokens share the decoder (text-only here; the
modality frontend is out of the assigned backbone scope).
"""
from repro.configs import shrink
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="llama4-maverick-400b-a17b", family="moe", n_layers=48,
    d_model=5120, n_heads=40, n_kv=8, d_ff=8192, vocab=202048, head_dim=128,
    n_experts=128, top_k=1, expert_d_ff=8192, n_shared_experts=1,
    moe_period=2, rope_theta=500_000.0,
)

SMOKE = shrink(CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv=2,
               head_dim=16, d_ff=64, expert_d_ff=64, n_experts=8, top_k=1,
               vocab=512)
