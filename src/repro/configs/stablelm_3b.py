"""stablelm-3b [dense] — [hf:stabilityai/stablelm-2-1_6b; unverified].

32L d_model=2560 32H (GQA kv=32 == MHA) d_ff=6912 vocab=50304.
StableLM-2 family uses partial rotary (25%).
"""
from repro.configs import shrink
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="stablelm-3b", family="dense", n_layers=32, d_model=2560,
    n_heads=32, n_kv=32, d_ff=6912, vocab=50304, rotary_pct=0.25,
)

SMOKE = shrink(CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=128,
               vocab=512)
