"""Batched serving driver: prefill + decode loop with continuous batching.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --batch 4 --prompt-len 32 --max-new 32

Implements the serving-side runtime: a request queue, batched prefill,
step-synchronous decode with per-slot completion, and slot recycling
(continuous batching) — the serving analogue of the BCPNN spike queues
(fixed capacity, drop/queue accounting).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models.transformer import Model, build_stack_spec
from repro.train.serve_step import make_decode_step, make_prefill, sample


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    """Step-synchronous continuous batching over a fixed slot count."""

    def __init__(self, model: Model, params, batch_slots: int, max_len: int,
                 temperature: float = 0.0):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.prefill = jax.jit(make_prefill(model))
        self.decode = jax.jit(make_decode_step(model, temperature))
        # ragged (mixed prompt lengths per wave) needs the pad mask to reach
        # every mixer in the stack; only the cached-attention kinds honour it
        kinds = {k for pat, _ in build_stack_spec(model.cfg) for k in pat}
        self.ragged = kinds <= {"attn", "attn_local", "attn_moe"}
        self.queue: list[Request] = []
        self.completed: list[Request] = []
        self.steps = 0

    def submit(self, req: Request):
        self.queue.append(req)

    def _next_wave(self):
        if self.ragged:
            return [self.queue.pop(0) for _ in range(min(self.slots,
                                                         len(self.queue)))]
        # non-attention stacks: group a wave of equal prompt lengths,
        # skipping over mismatched requests without reordering them
        wave, rest = [], []
        plen = len(self.queue[0].prompt)
        for r in self.queue:
            if len(wave) < self.slots and len(r.prompt) == plen:
                wave.append(r)
            else:
                rest.append(r)
        self.queue = rest
        return wave

    def run(self):
        """Drain the queue in FIFO waves of up to `slots` requests.

        Attention-only stacks serve mixed prompt lengths in one wave
        (left-padded, pad slots masked out of the KV cache); other stacks
        fall back to grouping each wave by equal prompt length.
        """
        while self.queue:
            self._run_wave(self._next_wave())
        return self.completed

    def _run_wave(self, wave):
        B = len(wave)
        plen = max(len(r.prompt) for r in wave)
        pad_np = np.array([plen - len(r.prompt) for r in wave], np.int32)
        if pad_np.any() and not self.ragged:
            raise ValueError("mixed prompt lengths need an attention-only "
                             "stack (recurrent mixers cannot mask left-pad)")
        pad = jnp.asarray(pad_np) if pad_np.any() else None
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(wave):
            toks[i, -len(r.prompt):] = r.prompt       # left-pad
        caches = self.model.init_cache(B, self.max_len)
        batch = {"tokens": jnp.asarray(toks)}
        logits, caches = self.prefill(self.params, batch, caches, pad)
        key = jax.random.PRNGKey(0)
        tok = sample(logits, key)
        for i, r in enumerate(wave):
            r.out.append(int(tok[i, 0]))
        max_new = max(r.max_new for r in wave)
        for step in range(max_new - 1):
            key = jax.random.fold_in(key, step)
            tok, logits, caches = self.decode(
                self.params, tok, jnp.asarray(plen + step, jnp.int32),
                caches, key, None, None, pad)
            self.steps += 1
            for i, r in enumerate(wave):
                if not r.done and len(r.out) < r.max_new:
                    r.out.append(int(tok[i, 0]))
                if len(r.out) >= r.max_new:
                    r.done = True
            if all(r.done for r in wave):
                break
        for r in wave:
            r.done = True
            self.completed.append(r)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--n-requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, args.batch,
                        args.prompt_len + args.max_new + 8)
    rng = np.random.default_rng(0)
    for rid in range(args.n_requests):
        eng.submit(Request(rid, rng.integers(0, cfg.vocab, args.prompt_len),
                           args.max_new))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    ntok = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {ntok} tokens in {dt:.2f}s "
          f"({ntok/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
