"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds-per-step at TPU v5e-class
constants:

  compute    = HLO_FLOPs            / (chips * 197e12 FLOP/s bf16)
  memory     = HLO_bytes_accessed   / (chips * 819e9  B/s HBM)
  collective = collective_bytes     / (chips * 2 * 50e9 B/s ICI links)

HLO_FLOPs / bytes come from compiled.cost_analysis(). Collective bytes are
NOT in cost_analysis: we parse the optimized HLO and sum operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (cross-replica traffic; all-reduce counted ~2x operand
size for the reduce+broadcast phases of a ring).

Notes on interpretation (see EXPERIMENTS.md):
  * cost_analysis on the SPMD module reports PER-PARTITION flops/bytes in
    recent jax/XLA; we detect & normalize to per-chip via sanity comparison
    against the analytic MODEL_FLOPS.
  * MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference fwd), N = (active)
    params, D = tokens processed — the "useful work" yardstick.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip (v5e-class)
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link
ICI_LINKS = 2                # usable links per chip for a 2D-torus transfer

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\S+))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str, loop_factor: float = 1.0) -> dict:
    """Sum result-shape bytes per collective kind from optimized HLO.

    XLA's cost/HLO view counts a while-loop (lax.scan) body ONCE, so a
    collective inside the scanned layer stack executes `repeats` times but
    appears once in the text. We therefore classify each collective as
    inside/outside a while-body (via the HLO call graph) and scale the
    inside ones by `loop_factor` (the stack's weighted trip count — see
    scan_factor()). Validated against unrolled compiles in EXPERIMENTS.md.
    """
    out = {k: 0.0 for k in ("all-reduce", "all-gather", "reduce-scatter",
                            "all-to-all", "collective-permute")}
    count = dict.fromkeys(out, 0)
    in_loop = _while_body_computations(hlo_text) if loop_factor != 1.0 else set()
    for comp_name, body in _computations(hlo_text):
        factor = loop_factor if comp_name in in_loop else 1.0
        for m in _COLL_RE.finditer(body):
            shape_str, kind = m.group(1), m.group(2)
            b = _shape_bytes(shape_str)
            b = 2 * b if kind == "all-reduce" else b   # ring: ~2x payload
            out[kind] += b * factor
            count[kind] += 1
    out["total"] = sum(v for k, v in out.items() if k != "counts")
    out["counts"] = count
    return out


_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")
_WHILE_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")


def _computations(hlo_text: str):
    """Split optimized HLO text into (computation_name, body_text) pairs.

    Line-based: computation headers are lines ending in '{' that contain
    '->' (param lists contain nested parens, so regex-free splitting)."""
    comps = []
    cur_name, cur_lines = None, []
    for ln in hlo_text.splitlines():
        s = ln.rstrip()
        if s.endswith("{") and "->" in s and \
                (s.startswith("%") or s.startswith("ENTRY")):
            if cur_name is not None:
                comps.append((cur_name, "\n".join(cur_lines)))
            tok = s.split()[1] if s.startswith("ENTRY") else s.split()[0]
            cur_name = tok.lstrip("%")
            cur_lines = [s]
        else:
            cur_lines.append(ln)
    if cur_name is not None:
        comps.append((cur_name, "\n".join(cur_lines)))
    return comps


def _while_body_computations(hlo_text: str) -> set:
    """Names of computations reachable from any while-loop body."""
    comps = dict(_computations(hlo_text))
    calls = {name: set(_CALL_RE.findall(body))
             for name, body in comps.items()}
    roots = set()
    for body in comps.values():
        roots.update(_WHILE_BODY_RE.findall(body))
    seen = set()
    stack = list(roots)
    while stack:
        n = stack.pop()
        if n in seen:
            continue
        seen.add(n)
        stack.extend(calls.get(n, ()))
    return seen


def scan_factor(cfg, extra_repeats: int = 0) -> float:
    """Weighted trip count of the scanned layer stack.

    F = sum_seg(repeats * blocks) / sum_seg(blocks): multiplying the
    once-counted scan bodies by F reconstructs total block executions.
    extra_repeats adds non-stack scans (e.g. the whisper encoder).
    """
    from repro.models.transformer import build_stack_spec
    segs = build_stack_spec(cfg)
    blocks = sum(len(pat) for pat, _ in segs)
    execs = sum(len(pat) * rep for pat, rep in segs)
    if extra_repeats:
        blocks += 1
        execs += extra_repeats
    return execs / max(blocks, 1)


def outside_loop_costs(cfg, shape_kind: str, batch: int, seq: int,
                       chips: int, tp: int = 16):
    """Analytic per-chip flops/bytes of the NON-scanned part of a step
    (embedding + LM head + loss + optimizer), used to keep the scan
    correction from inflating out-of-loop work.

    train : head fwd+bwd ~ 6*B*S*D*V flops; optimizer ~ 12N flops,
            ~28N bytes f32 traffic (p,mu,nu r/w + grads r)
    serve : head fwd 2*tokens*D*V; no optimizer
    Per-chip: matmuls divide by all chips (fully sharded); optimizer traffic
    divides by the sharding of each buffer (params/grads: TP; moments: ZeRO
    over all chips).
    """
    D, V = cfg.d_model, cfg.vocab
    N = cfg.param_count()
    if shape_kind == "train":
        tokens = batch * seq
        flops = 6.0 * tokens * D * V / chips + 12.0 * N / chips
        byts = (12.0 * N / tp           # params+grads r/w, TP-sharded f32
                + 16.0 * N / chips)     # mu/nu r/w, ZeRO over all chips
        flops += 2.0 * tokens * D / chips        # embed gather
    else:
        # prefill emits logits ONLY for the last position; decode for the
        # single new token — the head is B tokens either way
        flops = 2.0 * batch * D * V / chips
        byts = 4.0 * V * D / tp                   # head weights read
    return flops, byts


def corrected_costs(cfg, shape_kind: str, raw_flops: float, raw_bytes: float,
                    batch: int, seq: int, chips: int, factor: float,
                    tp: int = 16):
    """Scan-corrected per-chip (flops, bytes):
         corrected = outside + (raw - outside) * factor
    clamped so a mis-estimated outside part can't push the in-loop share
    negative. Validated against unrolled compiles (EXPERIMENTS.md §Roofline).
    """
    of, ob = outside_loop_costs(cfg, shape_kind, batch, seq, chips, tp)
    of = min(of, raw_flops)
    ob = min(ob, raw_bytes)
    return (of + (raw_flops - of) * factor,
            ob + (raw_bytes - ob) * factor)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float            # per chip
    hlo_bytes: float            # per chip
    coll_bytes: float           # per chip
    model_flops: float          # useful-work flops per step (global)
    coll_detail: dict | None = None

    @property
    def t_compute(self):
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self):
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self):
        return self.coll_bytes / (ICI_LINKS * ICI_BW)

    @property
    def bottleneck(self):
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def t_step(self):
        # perfectly-overlapped lower bound: max of the three terms
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_fraction(self):
        """MODEL_FLOPS / (global HLO flops) — remat/redundancy waste."""
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu_at_roofline(self):
        """Model-flop utilization if the step ran exactly at t_step."""
        return self.model_flops / (self.t_step * self.chips * PEAK_FLOPS) \
            if self.t_step else 0.0

    def row(self):
        return (f"{self.arch:28s} {self.shape:12s} {self.mesh:10s} "
                f"{self.t_compute*1e3:9.3f} {self.t_memory*1e3:9.3f} "
                f"{self.t_collective*1e3:9.3f}  {self.bottleneck:10s} "
                f"{self.useful_fraction:7.3f} {self.mfu_at_roofline:6.3f}")

    HEADER = (f"{'arch':28s} {'shape':12s} {'mesh':10s} "
              f"{'t_comp_ms':>9s} {'t_mem_ms':>9s} {'t_coll_ms':>9s}  "
              f"{'bottleneck':10s} {'useful':>7s} {'MFU@rl':>6s}")


def model_flops(cfg, shape_name: str, n_tokens: int, train: bool) -> float:
    """6*N*D (train) / 2*N*D (inference) with MoE active params."""
    n = cfg.active_param_count()
    mult = 6.0 if train else 2.0
    return mult * n * n_tokens


def analyze(compiled, lowered_text: str, *, arch: str, shape: str,
            mesh_name: str, chips: int, model_fl: float,
            per_partition: bool = True) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    coll = collective_bytes(lowered_text)
    if not per_partition:
        flops /= chips
        byts /= chips
    return Roofline(arch=arch, shape=shape, mesh=mesh_name, chips=chips,
                    hlo_flops=flops, hlo_bytes=byts,
                    coll_bytes=coll["total"] / chips, model_flops=model_fl,
                    coll_detail=coll)
