"""Assigned input shapes and ShapeDtypeStruct stand-ins for the dry-run.

Four cells per LM architecture (40 total):
  train_4k     seq 4096,   global batch 256   -> train_step
  prefill_32k  seq 32768,  global batch 32    -> serve prefill
  decode_32k   seq 32768,  global batch 128   -> serve decode (1 new token)
  long_500k    seq 524288, global batch 1     -> long-context decode;
               sub-quadratic archs only (xlstm, zamba2) — full-attention
               archs skip with a note (DESIGN.md §Arch-applicability).

No real allocation ever happens here: everything is jax.ShapeDtypeStruct /
jax.eval_shape.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.base import ArchConfig
from repro.models.transformer import Model

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32_768, batch=32),
    "decode_32k": dict(kind="decode", seq=32_768, batch=128),
    "long_500k": dict(kind="decode", seq=524_288, batch=1, long=True),
}

# sub-quadratic archs that run the long_500k cell
LONG_OK = {"xlstm-125m", "zamba2-7b"}


def applicable(arch_id: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch_id in LONG_OK
    return True


def _modality_specs(cfg: ArchConfig, batch: int):
    out = {}
    if cfg.family == "vlm":
        out["patch_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_patches, cfg.vision_dim), jnp.float32)
    if cfg.enc_dec:
        out["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_enc_frames, cfg.vision_dim), jnp.float32)
    return out


def input_specs(cfg: ArchConfig, shape_name: str):
    """ShapeDtypeStruct pytrees for one (arch x shape) cell.

    Returns a dict describing what the corresponding step function consumes:
      train  : {batch}
      prefill: {batch, caches}
      decode : {token, pos, caches[, memory, mem_pos]}
    """
    sh = SHAPES[shape_name]
    B, S = sh["batch"], sh["seq"]
    model = Model(cfg)
    if sh["kind"] == "train":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        batch.update(_modality_specs(cfg, B))
        return {"batch": batch}
    if sh["kind"] == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        batch.update(_modality_specs(cfg, B))
        caches = jax.eval_shape(lambda: model.init_cache(B, S))
        return {"batch": batch, "caches": caches}
    # decode: one new token against a seq_len-deep cache
    caches = jax.eval_shape(lambda: model.init_cache(B, S))
    out = {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
           "pos": jax.ShapeDtypeStruct((), jnp.int32),
           "caches": caches}
    if cfg.family == "vlm":
        out["memory"] = jax.ShapeDtypeStruct(
            (B, cfg.n_patches, cfg.d_model), cfg.cdtype)
        out["mem_pos"] = jax.ShapeDtypeStruct((cfg.n_patches,), jnp.int32)
    if cfg.enc_dec:
        out["memory"] = jax.ShapeDtypeStruct(
            (B, cfg.n_enc_frames, cfg.d_model), cfg.cdtype)
        out["mem_pos"] = jax.ShapeDtypeStruct((cfg.n_enc_frames,), jnp.int32)
    return out


def params_specs_abstract(cfg: ArchConfig):
    """Abstract parameter shapes (no allocation)."""
    model = Model(cfg)
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))
