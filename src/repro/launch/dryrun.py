import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first initialization). Everything below is ordinary code.

"""Multi-pod dry-run: lower + compile every (architecture x input shape x
mesh) cell and extract memory / cost / collective data for the roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only] \
      --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --bcpnn

Each cell writes results/dryrun/<arch>__<shape>__<mesh>.json with
memory_analysis, cost_analysis, collective-bytes breakdown and the roofline
terms; EXPERIMENTS.md tables are generated from these files.
"""
import argparse
import json
import time
import traceback

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.launch import roofline as RL
from repro.launch import shardings as SH
from repro.launch.mesh import make_bcpnn_mesh, make_production_mesh
from repro.launch.shapes import (SHAPES, applicable, input_specs,
                                 params_specs_abstract)
from repro.models.sharding import DEFAULT_RULES, use_rules
from repro.models.transformer import Model
from repro.train.optimizer import AdamW
from repro.train.train_step import make_train_step

from jax.sharding import NamedSharding, PartitionSpec as P


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _mem_summary(compiled):
    try:
        ma = compiled.memory_analysis()
        return {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(
                getattr(ma, "generated_code_size_in_bytes", 0)),
            "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
        }
    except Exception as e:  # backend without memory analysis
        return {"error": str(e)}


def lower_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
               zero_opt: bool = True, donate: bool = True,
               seq_shard_long: bool = True, remat: bool | None = None,
               scan: bool | None = None, cfg_override=None,
               fsdp_bytes: int | None = None, attn_impl: str | None = None,
               seqp: bool | None = None, moe_cap: bool | None = None):
    """Lower + compile one cell; returns (compiled, lowered_text, record)."""
    import dataclasses
    cfg = cfg_override or get_config(arch_id)
    if remat is not None:
        cfg = dataclasses.replace(cfg, remat=remat)
    if scan is not None:
        cfg = dataclasses.replace(cfg, scan_layers=scan)
    if attn_impl is not None:
        cfg = dataclasses.replace(cfg, attn_impl=attn_impl)
    if seqp is not None:
        cfg = dataclasses.replace(cfg, seq_parallel_residual=seqp)
    if moe_cap is not None:
        cfg = dataclasses.replace(cfg, moe_shard_cap=moe_cap)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = mesh.size
    model = Model(cfg)
    sh = SHAPES[shape_name]
    specs_in = input_specs(cfg, shape_name)
    p_abs = params_specs_abstract(cfg)
    p_specs = SH.param_specs(p_abs, cfg, mesh,
                             fsdp_threshold_bytes=fsdp_bytes)

    t0 = time.time()
    with mesh, use_rules(DEFAULT_RULES, mesh):
        if sh["kind"] == "train":
            opt = AdamW()
            o_abs = jax.eval_shape(opt.init, p_abs)
            o_specs = SH.opt_specs(p_specs, zero=zero_opt, mesh=mesh,
                                   params=p_abs)
            b_specs = SH.batch_specs(specs_in["batch"], mesh)
            step = make_train_step(model, opt)
            jf = jax.jit(
                step,
                in_shardings=(_named(mesh, p_specs), _named(mesh, o_specs),
                              _named(mesh, b_specs)),
                out_shardings=(_named(mesh, p_specs), _named(mesh, o_specs),
                               None),
                donate_argnums=(0, 1) if donate else ())
            lowered = jf.lower(p_abs, o_abs, specs_in["batch"])
            n_tok = sh["batch"] * sh["seq"]
            mfl = RL.model_flops(cfg, shape_name, n_tok, train=True)
        elif sh["kind"] == "prefill":
            c_specs = SH.cache_specs(specs_in["caches"], cfg, mesh)
            b_specs = SH.batch_specs(specs_in["batch"], mesh)
            jf = jax.jit(
                model.prefill,
                in_shardings=(_named(mesh, p_specs), _named(mesh, b_specs),
                              _named(mesh, c_specs)),
                donate_argnums=(2,) if donate else ())
            lowered = jf.lower(p_abs, specs_in["batch"], specs_in["caches"])
            n_tok = sh["batch"] * sh["seq"]
            mfl = RL.model_flops(cfg, shape_name, n_tok, train=False)
        else:  # decode
            seq_shard = seq_shard_long and sh.get("long", False)
            c_specs = SH.cache_specs(specs_in["caches"], cfg, mesh,
                                     seq_shard=seq_shard)
            args = [p_abs, specs_in["token"], specs_in["pos"],
                    specs_in["caches"]]
            in_sh = [_named(mesh, p_specs),
                     NamedSharding(mesh, P()), NamedSharding(mesh, P()),
                     _named(mesh, c_specs)]
            if "memory" in specs_in:
                mem_spec = SH.batch_specs({"m": specs_in["memory"]}, mesh)["m"]
                args += [specs_in["memory"], specs_in["mem_pos"]]
                in_sh += [NamedSharding(mesh, mem_spec),
                          NamedSharding(mesh, P())]
            jf = jax.jit(model.decode_step,
                         in_shardings=tuple(in_sh),
                         donate_argnums=(3,) if donate else ())
            lowered = jf.lower(*args)
            n_tok = sh["batch"]
            mfl = RL.model_flops(cfg, shape_name, n_tok, train=False)

        compiled = lowered.compile()
        text = compiled.as_text()     # post-SPMD: explicit collective ops

    # scan correction: XLA cost analysis counts while bodies once
    factor = RL.scan_factor(
        cfg, extra_repeats=(cfg.n_enc_layers if cfg.enc_dec
                            and sh["kind"] != "decode" else 0))
    if not cfg.scan_layers:
        factor = 1.0
    tp = mesh.shape.get("model", 1)
    rec = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_name,
        "chips": chips, "compile_s": round(time.time() - t0, 1),
        "memory": _mem_summary(compiled),
        "collectives_raw": RL.collective_bytes(text),
        "collectives": RL.collective_bytes(text, loop_factor=factor),
        "scan_factor": factor,
        "model_flops": mfl,
    }
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        rec["cost"] = {k: float(v) for k, v in ca.items()
                       if isinstance(v, (int, float)) and
                       k in ("flops", "bytes accessed", "transcendentals",
                             "optimal_seconds")}
        cf, cb = RL.corrected_costs(
            cfg, sh["kind"], rec["cost"].get("flops", 0.0),
            rec["cost"].get("bytes accessed", 0.0),
            sh["batch"], sh["seq"], chips, factor, tp)
        rec["cost_corrected"] = {"flops": cf, "bytes accessed": cb}
    except Exception as e:
        rec["cost"] = {"error": str(e)}
    return compiled, text, rec


def lower_bcpnn(scale: str = "rodent", *, multi_pod: bool,
                eager: bool = False, donate: bool = True,
                poisson_route: bool = True, pack: bool = True):
    """Lower + compile the BCPNN distributed tick on the production mesh."""
    import importlib
    import jax.numpy as jnp
    from repro.core import distributed as DD
    from repro.core import hcu as H
    from repro.core import network as N

    mod = importlib.import_module(f"repro.configs.bcpnn_{scale}")
    p = mod.CONFIG
    n_hcu = mod.DRYRUN_N_HCU
    mesh = make_bcpnn_mesh(512 if multi_pod else 256, multi_pod=multi_pod)
    mesh_name = ("pod2x256" if multi_pod else "pod256") + f"-{scale}"
    ndev = mesh.size
    h_local = n_hcu // ndev
    rc = DD.default_route_config(p, h_local,
                                 n_dev=ndev if poisson_route else None)
    rc = rc._replace(pack=pack)
    axis = ("pod", "hcu") if multi_pod else ("hcu",)
    tick = DD.make_dist_tick(mesh, p, rc, axis=axis, eager=eager,
                             donate=donate)

    # abstract state/conn/ext (ShapeDtypeStruct only — no allocation)
    def make_abstract():
        st = jax.eval_shape(lambda k: N.init_network(p, k, n_hcu=n_hcu),
                            jax.random.PRNGKey(0))
        cn = jax.eval_shape(
            lambda k: N.make_connectivity(p, k, n_hcu=n_hcu),
            jax.random.PRNGKey(1))
        ext = jax.ShapeDtypeStruct((n_hcu, 8), jnp.int32)
        return st, cn, ext

    st, cn, ext = make_abstract()
    t0 = time.time()
    with mesh:
        lowered = tick.lower(st, cn, ext)
        compiled = lowered.compile()
        text = compiled.as_text()     # post-SPMD: explicit collective ops
    # synaptic traffic per tick (lazy model): rows touched * row bytes * 2
    cells = (p.in_rate * p.cols + p.out_rate * p.rows) * n_hcu
    lazy_bytes = cells * 20 * 2
    rec = {
        "arch": f"bcpnn-{scale}", "shape": "tick_1ms", "mesh": mesh_name,
        "chips": ndev, "n_hcu": n_hcu, "h_local": h_local,
        "compile_s": round(time.time() - t0, 1),
        "memory": _mem_summary(compiled),
        "collectives": RL.collective_bytes(text),
        "model_flops": cells * 60.0,            # FLOPS_PER_CELL
        "lazy_bytes_per_tick": lazy_bytes,
    }
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        rec["cost"] = {k: float(v) for k, v in ca.items()
                       if isinstance(v, (int, float)) and
                       k in ("flops", "bytes accessed", "transcendentals")}
    except Exception as e:
        rec["cost"] = {"error": str(e)}
    return compiled, text, rec


def run_cell(arch_id, shape_name, multi_pod, out_dir, skip_existing=True,
             **kw):
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    tag = f"{arch_id}__{shape_name}__{mesh_name}"
    path = os.path.join(out_dir, tag + ".json")
    if skip_existing and os.path.exists(path):
        print(f"[skip] {tag}")
        return None
    if not applicable(arch_id, shape_name):
        rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
               "skipped": "full-attention arch: long_500k inapplicable "
                          "(DESIGN.md §Arch-applicability)"}
        os.makedirs(out_dir, exist_ok=True)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[n/a ] {tag}")
        return rec
    print(f"[lower] {tag} ...", flush=True)
    try:
        compiled, text, rec = lower_cell(arch_id, shape_name,
                                         multi_pod=multi_pod, **kw)
        os.makedirs(out_dir, exist_ok=True)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[ok  ] {tag} compile={rec['compile_s']}s "
              f"flops/chip={rec['cost'].get('flops', 0):.3e} "
              f"coll={rec['collectives']['total']:.3e}B", flush=True)
        del compiled, text
        return rec
    except Exception as e:
        traceback.print_exc()
        rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
               "error": f"{type(e).__name__}: {e}"}
        os.makedirs(out_dir, exist_ok=True)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[FAIL] {tag}: {e}", flush=True)
        return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--bcpnn", action="store_true")
    ap.add_argument("--eager-bcpnn", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-skip", action="store_true")
    args = ap.parse_args()

    assert jax.device_count() == 512, "dry-run needs the 512-device env"
    os.makedirs(args.out, exist_ok=True)

    if args.bcpnn:
        for scale in ("rodent", "human"):
            for mp in (False, True):
                tag = f"bcpnn-{scale}__tick__{'pod2x256' if mp else 'pod256'}"
                path = os.path.join(args.out, tag + ".json")
                if not args.no_skip and os.path.exists(path):
                    print(f"[skip] {tag}")
                    continue
                print(f"[lower] {tag}", flush=True)
                try:
                    _, _, rec = lower_bcpnn(scale, multi_pod=mp,
                                            eager=args.eager_bcpnn)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    print(f"[ok  ] {tag} compile={rec['compile_s']}s", flush=True)
                except Exception as e:
                    traceback.print_exc()
                    with open(path, "w") as f:
                        json.dump({"error": str(e)}, f)
        return

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    pods = []
    if not args.multi_pod:
        pods.append(False)
    if not args.single_pod:
        pods.append(True)
    for mp in pods:
        for a in archs:
            for s in shapes:
                run_cell(a, s, mp, args.out, skip_existing=not args.no_skip)


if __name__ == "__main__":
    main()
