"""BCPNN-as-a-service: slot-recycling continuous-batching recall server.

  PYTHONPATH=src python -m repro.launch.serve_bcpnn --requests 32

Many concurrent cue->attractor-recall sessions batched onto ONE shared
compiled multi-tick graph — the BCPNN analogue of the LM `ServingEngine`
(`repro.launch.serve`), and the "millions of users" direction of the
ROADMAP north star. The pieces:

  RecallRequest   one client session: a partial cue (pattern row per HCU +
                  driven-HCU mask) and a tick budget; carries its own
                  lifecycle telemetry (queue/admit/finish timestamps, fired
                  trajectory, per-session drop counters).
  RequestQueue    fixed-capacity FIFO admission queue — the serving analogue
                  of the paper's spike queues (fixed slots, overflow is a
                  counted rejection, priced by Fig 7 / EQ1 through
                  `repro.runtime.resilience.ServingHealthMonitor`).
  BCPNNRecallServer
                  `slots` session lanes as a leading (S,) batch dim over
                  `NetworkState` (`repro.core.network.stack_sessions`). Each
                  engine step advances every lane `step_ticks` ticks through
                  one jitted `jax.lax.map` over the per-lane scan
                  (`_serve_step`). A session completes when its recall
                  CONVERGES (every HCU has fired and no winner changed over
                  a full step) or its tick budget expires; its lane is freed
                  and the next queued cue is admitted by an in-place donated
                  scatter (`write_sessions`) — no recompilation, no copy of
                  the other lanes.

Sharing model: the `Connectivity` fanout tables and the params are closure
constants of the jitted step — ONE copy shared read-only across all lanes.
The per-lane NetworkState is fully private (the tick writes the synaptic ij
planes during recall, and the volatile j-vectors/delay queues are per-slot
by construction), so lane trajectories are exactly independent runs.

Bitwise contract (the serving analogue of the head-fixture discipline):
each lane's trajectory is BITWISE identical to an independent
single-session `Simulator.run` from the same template state, because
`jax.lax.map` executes one lane at a time with exactly the single-session
`network._run_chunk` graph and shapes — same code, same shapes, same
per-tick RNG (`fold_in(base_key, t)` with per-lane `t`). vmap would fuse
across lanes and break this (XLA:CPU 1-ulp context sensitivity,
docs/NUMERICS.md). Pinned by tests/test_serve_bcpnn.py.

Free lanes keep ticking on silence until recycled (like the LM engine's pad
slots); their drops are not attributed to any session and their state is
reset from the template on the next admission.
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as E
from repro.core import network as N
from repro.runtime.resilience import ServingHealthMonitor


# ---------------------------------------------------------------------------
# the shared compiled step
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("p", "be", "cap_fire"),
                   donate_argnums=(0,))
def _serve_step(stacked, conn, ext, p, be, cap_fire):
    """Advance every session lane by ext.shape[1] ticks in one dispatch.

    stacked: NetworkState with a leading (S,) lane dim; ext: (S, T, H, W)
    per-lane staged external input. Returns (stacked', fired (S, T, H)).
    Per lane the graph is EXACTLY the single-session `network._run_chunk`
    scan — see the module docstring's bitwise contract. The stacked state is
    donated (in-place lane updates); `conn`/params are shared read-only.
    """
    def session_body(args):
        state, e = args

        def body(s, ee):
            return E.tick(s, conn, ee, p, be, cap_fire)

        st, fired = jax.lax.scan(body, be.carry_in(state, p), e)
        return be.carry_out(st, p), fired

    return jax.lax.map(session_body, (stacked, ext))


def _step_winners(fired_step: np.ndarray) -> np.ndarray:
    """Last WTA winner per HCU over one (T, H) step window (-1 = silent)."""
    T, H = fired_step.shape
    w = np.full((H,), -1, np.int64)
    for f in fired_step:
        upd = f >= 0
        w[upd] = f[upd]
    return w


# ---------------------------------------------------------------------------
# requests and the admission queue
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RecallRequest:
    """One client session: cue in, attractor out, telemetry throughout."""
    rid: int
    cue_rows: np.ndarray            # (H,) int32 — pattern row per HCU
    cue_mask: np.ndarray            # (H,) bool  — which HCUs the cue drives
    budget_ticks: int = 48          # max biological ms before expiry
    # lifecycle (filled in by the server)
    status: str = "new"             # new|queued|rejected|active|done|expired
    submit_s: float | None = None
    admit_s: float | None = None
    finish_s: float | None = None
    ticks: int = 0                  # biological ms actually served
    winners: np.ndarray | None = None   # (H,) final winner per HCU
    fired: np.ndarray | None = None     # (ticks, H) fired trajectory
    drops: dict | None = None           # per-session {'in','fire','route'}

    @property
    def service_ms(self) -> float | None:
        """Wall milliseconds from admission to completion."""
        if self.admit_s is None or self.finish_s is None:
            return None
        return (self.finish_s - self.admit_s) * 1e3

    @property
    def sojourn_ms(self) -> float | None:
        """Wall milliseconds from submission to completion (incl. queueing)."""
        if self.submit_s is None or self.finish_s is None:
            return None
        return (self.finish_s - self.submit_s) * 1e3


class RequestQueue:
    """Fixed-capacity FIFO admission queue with drop accounting.

    The serving analogue of the delay-bucket spike queues: a fixed number of
    waiting slots, overflow is a counted REJECTION (never silent loss), and
    admission order is strictly FIFO. Invariants (pinned by
    tests/test_serve_queue.py): admitted + rejected + waiting == submitted;
    rejections happen exactly when the queue is at capacity at offer time.
    """

    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self._q: collections.deque = collections.deque()
        self.submitted = 0
        self.admitted = 0
        self.rejected = 0

    def __len__(self) -> int:
        return len(self._q)

    @property
    def free(self) -> int:
        return self.capacity - len(self._q)

    def offer(self, req: RecallRequest) -> bool:
        """Submit a request; False (and req.status == 'rejected') if full."""
        self.submitted += 1
        if len(self._q) >= self.capacity:
            self.rejected += 1
            req.status = "rejected"
            return False
        req.status = "queued"
        self._q.append(req)
        return True

    def take(self, k: int) -> list:
        """Admit up to k requests, FIFO."""
        out = []
        while self._q and len(out) < k:
            out.append(self._q.popleft())
            self.admitted += 1
        return out

    def counters(self) -> dict:
        return {"submitted": self.submitted, "admitted": self.admitted,
                "rejected": self.rejected, "waiting": len(self._q),
                "capacity": self.capacity}


# ---------------------------------------------------------------------------
# the server
# ---------------------------------------------------------------------------

class BCPNNRecallServer:
    """Continuous-batching recall serving over `slots` session lanes.

        sim = Simulator(p, key=0, cap_fire=p.n_hcu)
        train_assoc(sim, patterns, ...)              # or any warmed state
        srv = BCPNNRecallServer(sim, slots=8, queue_capacity=64)
        srv.submit(RecallRequest(0, cue_rows, cue_mask))
        done = srv.run()                             # drain to completion

    The server snapshots `sim.state` as its session TEMPLATE at construction
    (a true copy — the Simulator stays usable) and takes the backend/mode
    configuration from the facade, so whatever engine mode the Simulator
    runs (dense/worklist, lazy/eager, layouts) is what every lane runs.
    """

    def __init__(self, sim, *, slots: int = 4, queue_capacity: int = 64,
                 step_ticks: int = 12, ext_width: int = 4,
                 monitor: ServingHealthMonitor | None = None,
                 req_rate: float = 0.0, clock=time.perf_counter):
        if sim.merged:
            raise NotImplementedError(
                "serving: merged mode's jring carry is untested under "
                "session stacking")
        self.p = sim.p
        self.n_hcu = sim.n_hcu
        self.slots = int(slots)
        self.step_ticks = int(step_ticks)
        self.ext_width = int(ext_width)
        self.conn = sim.conn
        self.be = sim.backend
        self.cap_fire = sim.cap_fire
        self.clock = clock
        # true copy: drivers donate sim.state, and on CPU jnp.asarray may
        # alias a buffer a later donation would invalidate
        self.template = jax.tree.map(lambda a: jnp.asarray(np.array(a)),
                                     sim.state)
        self.stacked = N.stack_sessions(self.template, self.slots)
        self._base_drops = N.drop_counters(self.template)
        self.queue = RequestQueue(queue_capacity)
        self.active: list[RecallRequest | None] = [None] * self.slots
        self._winners = np.full((self.slots, self.n_hcu), -1, np.int64)
        self._traj: list[list[np.ndarray]] = [[] for _ in range(self.slots)]
        self._drops_done = {"in": 0, "fire": 0, "route": 0}
        self.completed: list[RecallRequest] = []
        self.steps = 0
        self.monitor = monitor if monitor is not None else \
            ServingHealthMonitor(self.p, n_hcu=self.n_hcu * self.slots,
                                 queue_capacity=int(queue_capacity),
                                 req_rate=req_rate)
        self.monitor.begin(self._cum_drops(None, None, None))

    # -- client API ----------------------------------------------------------
    def submit(self, req: RecallRequest) -> bool:
        req.submit_s = self.clock()
        return self.queue.offer(req)

    @property
    def busy(self) -> bool:
        return len(self.queue) > 0 or any(r is not None for r in self.active)

    def run(self, requests=None) -> list[RecallRequest]:
        """Submit `requests` (if given) and step until idle. Offers that
        find the queue full are rejected — pace submissions against
        `queue.free` for lossless closed-loop driving."""
        for r in requests or ():
            self.submit(r)
        while self.busy:
            self.step()
        return self.completed

    # -- engine step ---------------------------------------------------------
    def step(self) -> list[RecallRequest]:
        """Admit, advance every lane `step_ticks` ticks, retire finished
        sessions. Returns the sessions completed by this step."""
        now = self.clock()
        free = [i for i, r in enumerate(self.active) if r is None]
        newly = self.queue.take(len(free))
        if newly:
            # fixed-shape admission scatter: unused entries padded out of
            # range (mode="drop") so one compiled shape serves any fill
            lanes = np.full((len(free),), self.slots, np.int32)
            for i, req in enumerate(newly):
                lane = free[i]
                lanes[i] = lane
                self.active[lane] = req
                req.status = "active"
                req.admit_s = now
                self._winners[lane] = -1
                self._traj[lane] = []
            self.stacked = N.write_sessions(self.stacked, self.template,
                                            jnp.asarray(lanes))
        if not any(r is not None for r in self.active):
            return []

        ext = np.full((self.slots, self.step_ticks, self.n_hcu,
                       self.ext_width), self.p.rows, np.int32)
        for lane, req in enumerate(self.active):
            if req is None:
                continue
            frame = np.full((self.n_hcu, self.ext_width), self.p.rows,
                            np.int32)
            mask = np.asarray(req.cue_mask, bool)
            frame[mask, 0] = np.asarray(req.cue_rows, np.int32)[mask]
            ext[lane] = frame[None]
        self.monitor.chunk_start(self.step_ticks)
        self.stacked, fired = _serve_step(self.stacked, self.conn,
                                          jnp.asarray(ext), self.p, self.be,
                                          self.cap_fire)
        fired = np.asarray(fired)
        self.steps += 1

        d_in = np.asarray(self.stacked.drops_in)
        d_fire = np.asarray(self.stacked.drops_fire)
        d_route = (np.asarray(self.stacked.drops_route)
                   if self.stacked.drops_route is not None
                   else np.zeros((self.slots,), np.int64))
        now = self.clock()
        done_now = []
        for lane, req in enumerate(self.active):
            if req is None:
                continue
            f = fired[lane]
            self._traj[lane].append(f)
            step_w = _step_winners(f)
            upd = step_w >= 0
            changed = bool((step_w[upd] != self._winners[lane][upd]).any())
            self._winners[lane][upd] = step_w[upd]
            req.ticks += self.step_ticks
            # converged: every HCU has expressed a winner and a full step
            # passed without any winner flipping (a stable attractor);
            # unreachable on the very first step (winners start at -1)
            converged = (not changed) and bool((self._winners[lane] >= 0).all())
            if converged or req.ticks >= req.budget_ticks:
                req.status = "done" if converged else "expired"
                req.finish_s = now
                req.winners = self._winners[lane].copy()
                req.fired = np.concatenate(self._traj[lane], axis=0)
                req.drops = {
                    "in": int(d_in[lane]) - self._base_drops["in"],
                    "fire": int(d_fire[lane]) - self._base_drops["fire"],
                    "route": int(d_route[lane]) - self._base_drops["route"],
                }
                for k, v in req.drops.items():
                    self._drops_done[k] += v
                self.active[lane] = None
                self._traj[lane] = []
                self.completed.append(req)
                done_now.append(req)
        self.monitor.chunk_end(self.step_ticks,
                               self._cum_drops(d_in, d_fire, d_route))
        return done_now

    # -- accounting ----------------------------------------------------------
    def _cum_drops(self, d_in, d_fire, d_route) -> dict:
        """Cumulative session-attributed drops + request rejections, the
        dict the HealthMonitor prices per class. Free lanes (ticking on
        silence between sessions) are unattributed by design."""
        cum = dict(self._drops_done)
        if d_in is not None:
            for lane, req in enumerate(self.active):
                if req is None:
                    continue
                cum["in"] += int(d_in[lane]) - self._base_drops["in"]
                cum["fire"] += int(d_fire[lane]) - self._base_drops["fire"]
                cum["route"] += int(d_route[lane]) - self._base_drops["route"]
        cum["reject"] = self.queue.rejected
        return cum

    def stats(self, slo_ms: float | None = None) -> dict:
        """Structured serving report: queue counters, completion mix,
        latency percentiles, and the per-class drop-budget health verdict
        (schema in docs/SERVING.md)."""
        done = [r for r in self.completed if r.finish_s is not None]
        service = np.sort([r.service_ms for r in done]) if done else np.array([])
        sojourn = np.sort([r.sojourn_ms for r in done]) if done else np.array([])

        def pct(a, q):
            return float(np.percentile(a, q)) if a.size else None

        out = {
            "slots": self.slots,
            "step_ticks": self.step_ticks,
            "steps": self.steps,
            "queue": self.queue.counters(),
            "completed": len(done),
            "done": sum(r.status == "done" for r in done),
            "expired": sum(r.status == "expired" for r in done),
            "p50_service_ms": pct(service, 50),
            "p95_service_ms": pct(service, 95),
            "p50_sojourn_ms": pct(sojourn, 50),
            "p95_sojourn_ms": pct(sojourn, 95),
            "health": self.monitor.report(),
        }
        if slo_ms is not None:
            out["slo_ms"] = float(slo_ms)
            p95 = out["p95_sojourn_ms"]
            out["slo_met"] = bool(p95 is not None and p95 <= slo_ms)
        return out


# ---------------------------------------------------------------------------
# demo CLI (toy scale; the measured benchmark is benchmarks/serve_bcpnn.py)
# ---------------------------------------------------------------------------

def main() -> None:
    from repro.core import Simulator, test_scale

    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--queue", type=int, default=8)
    ap.add_argument("--step-ticks", type=int, default=8)
    ap.add_argument("--budget", type=int, default=32)
    args = ap.parse_args()

    p = test_scale(n_hcu=8, rows=64, cols=8)
    sim = Simulator(p, key=0, cap_fire=p.n_hcu)
    srv = BCPNNRecallServer(sim, slots=args.slots, queue_capacity=args.queue,
                            step_ticks=args.step_ticks)
    rng = np.random.default_rng(0)
    pending = [RecallRequest(rid, rng.integers(0, p.rows, p.n_hcu),
                             rng.random(p.n_hcu) < 0.6,
                             budget_ticks=args.budget)
               for rid in range(args.requests)]
    t0 = time.perf_counter()
    while pending or srv.busy:
        while pending and srv.queue.free > 0:
            srv.submit(pending.pop(0))
        srv.step()
    dt = time.perf_counter() - t0
    s = srv.stats()
    print(f"served {s['completed']} sessions ({s['done']} converged, "
          f"{s['expired']} expired) in {dt:.2f}s "
          f"({s['completed']/dt:.1f} qps), p95 service "
          f"{s['p95_service_ms']:.0f} ms, health={s['health']['status']}")


if __name__ == "__main__":
    main()
