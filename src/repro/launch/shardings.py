"""Parameter / batch / cache PartitionSpecs for the production meshes.

Rules (baseline — the §Perf hillclimb iterates on these):
  params : TP over "model" — attention qkv/o projections, MLP in/out, vocab;
           EP over "model" for MoE expert stacks; tiny/odd tensors replicate.
           DP axes never shard params (pure replication) — optimizer state
           can additionally be ZeRO-sharded over "data" (opt_specs(zero=True)).
  batch  : tokens over ("pod","data").
  cache  : decode KV caches shard batch over ("pod","data") and kv-heads over
           "model" when divisible; long-context (batch=1) shards the SEQUENCE
           dim over ("pod","data") instead.

Every candidate axis is divisibility-checked against the mesh and dropped to
replication when it doesn't divide — specs are always valid for the mesh.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.base import ArchConfig

# logical mesh axis groups
DP = ("pod", "data")
TP = ("model",)


def _axis_size(mesh: Mesh, axes) -> int:
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def _present(mesh: Mesh, axes):
    axes = tuple(a for a in (axes if isinstance(axes, tuple) else (axes,))
                 if a in mesh.axis_names)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def _checked(mesh: Mesh, dim: int, axes):
    """Axes if they divide `dim`, else None (replicate)."""
    a = _present(mesh, axes)
    if a is None or dim % _axis_size(mesh, axes) != 0:
        return None
    return a


def param_spec(path: str, leaf, cfg: ArchConfig, mesh: Mesh) -> P:
    """Spec for one parameter, keyed by its tree path (joined key names)."""
    nd = leaf.ndim
    name = path.split("/")[-1]

    def at(pos, dim_axes):  # spec with mesh axes at dim `pos` (may be None)
        spec = [None] * nd
        spec[pos] = _checked(mesh, leaf.shape[pos], dim_axes)
        return P(*spec)

    if name == "embed":
        return at(0, TP)                       # vocab-sharded embedding
    if name in ("lm_head", "pos_embed"):
        return at(nd - 1, TP)
    if "ffn" in path and name in ("wi", "wg", "wo") and nd >= 3 \
            and cfg.n_experts and "shared" not in path:
        return at(nd - 3, TP)                  # EP: expert dim over model
    if name in ("wq", "wk", "wv", "wi", "wg", "up", "in_proj", "w",
                "router", "vision_proj", "frame_proj"):
        return at(nd - 1, TP)                  # column-parallel
    if name in ("wo", "down", "out_proj"):
        return at(nd - 2, TP)                  # row-parallel
    if name in ("bq", "bk", "bv", "norm_w", "b"):
        return at(nd - 1, TP)
    return P()                                 # norms, gates, scalars, conv


def _path_str(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
    return "/".join(parts)


def param_specs(params, cfg: ArchConfig, mesh: Mesh, *,
                fsdp_threshold_bytes: int | None = None):
    """Pytree of PartitionSpec congruent with params.

    With fsdp_threshold_bytes set, parameters larger than the threshold are
    ADDITIONALLY sharded over the "data" axes on their largest unsharded dim
    (FSDP / ZeRO-3): GSPMD all-gathers them at use and reduce-scatters
    gradients. This is what makes the 235B/400B MoE archs fit HBM
    (EXPERIMENTS.md §Perf).
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for kp, leaf in flat:
        s = param_spec(_path_str(kp), leaf, cfg, mesh)
        if fsdp_threshold_bytes is not None and leaf.ndim >= 1:
            size = leaf.size if hasattr(leaf, "size") else 0
            if size * 4 >= fsdp_threshold_bytes:
                entries = list(s) + [None] * (leaf.ndim - len(s))
                for i in sorted(range(leaf.ndim),
                                key=lambda i: -leaf.shape[i]):
                    if entries[i] is None:
                        a = _checked(mesh, leaf.shape[i], DP)
                        if a is not None:
                            entries[i] = a
                            break
                s = P(*entries)
        specs.append(s)
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_specs(params_specs, zero: bool = False, mesh: Mesh | None = None,
              params=None):
    """Optimizer-state specs: mirror params; with zero=True, additionally
    shard replicated moments over "data" on their largest divisible dim
    (ZeRO-2-style)."""
    from repro.train.optimizer import AdamWState

    def zero_extend(spec: P, leaf):
        if not zero or mesh is None or leaf.ndim == 0:
            return spec
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        used = {a for e in entries if e is not None
                for a in (e if isinstance(e, tuple) else (e,))}
        if "data" in used:          # already FSDP-sharded over data
            return P(*entries)
        for i in sorted(range(leaf.ndim), key=lambda i: -leaf.shape[i]):
            if entries[i] is None:
                a = _checked(mesh, leaf.shape[i], ("data",))
                if a is not None:
                    entries[i] = a
                    break
        return P(*entries)

    mu = (jax.tree.map(zero_extend, params_specs, params)
          if zero else params_specs)
    return AdamWState(step=P(), mu=mu, nu=mu)


def batch_specs(batch_tree, mesh: Mesh):
    """Shard the leading (batch) dim over DP when divisible."""
    def one(leaf):
        return P(_checked(mesh, leaf.shape[0], DP),
                 *([None] * (leaf.ndim - 1)))
    return jax.tree.map(one, batch_tree)


def cache_specs(cache_tree, cfg: ArchConfig, mesh: Mesh, *,
                seq_shard: bool = False):
    """Decode-state shardings.

    KV caches (leaf paths '.k'/'.v', shape (layers, B, S, Kv, hd)):
      batch over DP (or, with seq_shard for batch==1 long-context, the
      SEQUENCE dim over DP), kv-heads over TP, falling back to head_dim when
      the kv count doesn't divide the model axis.
    Recurrent states (mamba (L,B,H,P,N) / mlstm (L,B,H,dk,dv)...):
      batch over DP, heads over TP.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)

    def one(path, leaf):
        nd = leaf.ndim
        spec = [None] * nd
        name = ""
        for kk in reversed(path):
            if hasattr(kk, "name"):
                name = str(kk.name)
                break
            if hasattr(kk, "key"):
                name = str(kk.key)
                break
        if name in ("k", "v") and nd == 5:          # stacked KV cache
            if seq_shard and leaf.shape[1] == 1:
                spec[2] = _checked(mesh, leaf.shape[2], DP)    # sequence
            else:
                spec[1] = _checked(mesh, leaf.shape[1], DP)    # batch
            spec[3] = _checked(mesh, leaf.shape[3], TP)        # kv heads
            if spec[3] is None:
                spec[4] = _checked(mesh, leaf.shape[4], TP)    # head_dim
        elif nd >= 3:                                # recurrent states
            spec[1] = _checked(mesh, leaf.shape[1], DP)        # batch
            spec[2] = _checked(mesh, leaf.shape[2], TP)        # heads
        return P(*spec)

    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in flat])


def shard_params(params, cfg: ArchConfig, mesh: Mesh):
    specs = param_specs(params, cfg, mesh)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        params, specs), specs
