"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required for the dry-run's
xla_force_host_platform_device_count dance.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types on the mesh
    from jax.sharding import AxisType
except ImportError:  # jax 0.4.x: make_mesh has no axis_types kwarg
    AxisType = None


def _make_mesh(shape, axes, devices=None):
    """jax.make_mesh across versions: 0.4.x lacks the axis_types kwarg."""
    if AxisType is None:
        return jax.make_mesh(shape, axes, devices=devices)
    return jax.make_mesh(shape, axes, devices=devices,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devs = jax.devices()[:n]        # single-pod uses the first 256 of 512
    return _make_mesh(shape, axes, devices=devs)


def make_bcpnn_mesh(n_devices: int | None = None, *, multi_pod: bool = False):
    """BCPNN shards whole HCUs (embarrassingly parallel, paper §II.B): a flat
    'hcu' axis over every chip; multi-pod adds an explicit 'pod' axis so the
    spike all_to_all hierarchy (intra/inter pod) is visible to the compiler."""
    n = n_devices or len(jax.devices())
    devs = jax.devices()[:n]
    if multi_pod:
        return _make_mesh((2, n // 2), ("pod", "hcu"), devices=devs)
    return _make_mesh((n,), ("hcu",), devices=devs)


def elastic_device_count(n_hcu: int, n_available: int) -> int:
    """Degraded-mode mesh size: the largest device count <= the survivors
    that divides the hypercolumn count (`make_dist_run` shards whole HCUs,
    h_local = H // ndev — H % ndev must be 0). Always >= 1: a single
    survivor can host the entire network."""
    n = max(min(int(n_available), int(n_hcu)), 1)
    while n_hcu % n:
        n -= 1
    return n


def make_elastic_mesh(n_hcu: int, devices=None, axis: str = "hcu"):
    """1-D HCU mesh over (a whole-HCU-divisible prefix of) the surviving
    devices — the mesh `ElasticRunner` re-lowers onto after a device loss."""
    devs = list(devices) if devices is not None else jax.devices()
    n = elastic_device_count(n_hcu, len(devs))
    return _make_mesh((n,), (axis,), devices=devs[:n])


def force_host_device_count_flags(n: int, base: str | None = None) -> str:
    """XLA_FLAGS value forcing `n` host-platform (CPU) devices.

    Must be in the environment BEFORE jax initializes, so this is for
    building a CHILD process env (the weak-scaling sweep, the multi-device
    tests), never for mutating the current process. `base` defaults to the
    caller's current XLA_FLAGS so benchmark pins (e.g. the legacy CPU
    runtime, `benchmarks.run.pin_legacy_cpu_runtime`) survive into the
    child; any existing forced-count flag is replaced."""
    import os
    if base is None:
        base = os.environ.get("XLA_FLAGS", "")
    flags = [f for f in base.split()
             if not f.startswith("--xla_force_host_platform_device_count")]
    flags.append(f"--xla_force_host_platform_device_count={int(n)}")
    return " ".join(flags)


def make_host_mesh(shape=None, axes=("data", "model")):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if shape is None:
        shape = (n, 1)
    return _make_mesh(shape, axes)
