"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --steps 200 --batch 8 --seq 128

Runs the full production loop on whatever devices exist: sharded params,
jit train_step with in/out shardings, synthetic Markov LM data, async
checkpointing, straggler monitoring, restart-from-checkpoint. On the real
pod the same script runs with --no-smoke (full config) and the production
mesh; on CPU it is exercised by examples/train_lm.py and tests.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import AsyncCheckpointer, restore_latest
from repro.configs import get_config, get_smoke_config
from repro.data import MarkovLM
from repro.launch import shardings as SH
from repro.launch.mesh import make_host_mesh
from repro.models.sharding import DEFAULT_RULES, use_rules
from repro.models.transformer import Model
from repro.runtime import StragglerMonitor
from repro.train import AdamW, make_train_step


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def train(arch: str, steps: int, batch: int, seq: int, smoke: bool = True,
          ckpt_dir: str | None = None, lr: float = 3e-3, log_every: int = 10,
          mesh=None, seed: int = 0):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    model = Model(cfg)
    opt = AdamW(lr=lr, warmup_steps=20)
    mesh = mesh or make_host_mesh()
    data = MarkovLM(vocab=cfg.vocab, seed=seed)

    key = jax.random.PRNGKey(seed)
    with mesh, use_rules(DEFAULT_RULES, mesh):
        params = model.init(key)
        p_specs = SH.param_specs(params, cfg, mesh)
        params = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            params, p_specs)
        opt_state = opt.init(params)
        o_specs = SH.opt_specs(p_specs)

        step_fn = jax.jit(
            make_train_step(model, opt),
            in_shardings=(_named(mesh, p_specs), _named(mesh, o_specs), None),
            out_shardings=(_named(mesh, p_specs), _named(mesh, o_specs), None),
            donate_argnums=(0, 1))

        start = 0
        ckpt = None
        if ckpt_dir:
            ckpt = AsyncCheckpointer(ckpt_dir)
            restored, s = restore_latest(ckpt_dir, (params, opt_state))
            if restored is not None:
                params, opt_state = restored
                start = s
                print(f"[restore] resumed from step {s}")

        mon = StragglerMonitor(deadline_s=30.0)
        losses = []
        for step in range(start, steps):
            b = data.batch(step, batch, seq)
            mon.start()
            params, opt_state, metrics = step_fn(params, opt_state, b)
            mon.finish()
            losses.append(float(metrics["loss"]))
            if step % log_every == 0 or step == steps - 1:
                print(f"step {step:5d} loss {losses[-1]:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e}", flush=True)
            if ckpt and (step + 1) % 50 == 0:
                ckpt.save_async(step + 1, (params, opt_state))
        if ckpt:
            ckpt.save_async(steps, (params, opt_state))
            ckpt.wait()
        print(f"[straggler] {mon.summary()}")
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()
    _, losses = train(args.arch, args.steps, args.batch, args.seq,
                      smoke=args.smoke, ckpt_dir=args.ckpt, lr=args.lr)
    n = max(len(losses) // 10, 1)
    print(f"loss first10={np.mean(losses[:n]):.4f} "
          f"last10={np.mean(losses[-n:]):.4f}")


if __name__ == "__main__":
    main()
