from repro.data.synthetic import (MarkovLM, lm_batch_spec, make_patterns,
                                  pattern_drive, poisson_external_drive)

__all__ = ["MarkovLM", "lm_batch_spec", "make_patterns", "pattern_drive",
           "poisson_external_drive"]
