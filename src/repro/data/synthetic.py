"""Deterministic synthetic data pipelines (no external datasets offline).

LM: a hidden-Markov token stream — tokens are predictable from context, so a
model trained on it shows real loss decrease (used by the end-to-end example
and tests). BCPNN: Poisson spike streams and pattern generators for the
associative-memory demo (paper's function: cortical attractor memory).

Both pipelines are host-sharded: each process generates only its slice of
the global batch, keyed by (seed, step, shard), so 1000-node ingestion needs
no coordination.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


# -------------------------------- LM stream ---------------------------------

@dataclasses.dataclass
class MarkovLM:
    """Order-1 Markov chain over `vocab` with low-entropy transitions."""
    vocab: int
    seed: int = 0
    branch: int = 4          # out-degree per state: log2(branch) bits/token

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.next_tokens = rng.integers(0, self.vocab,
                                        (self.vocab, self.branch))

    def batch(self, step: int, batch: int, seq: int, shard: int = 0,
              n_shards: int = 1):
        """Returns {tokens, labels} for this host's slice of the batch."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + shard)
        b_local = batch // n_shards
        toks = np.empty((b_local, seq + 1), np.int64)
        toks[:, 0] = rng.integers(0, self.vocab, b_local)
        choices = rng.integers(0, self.branch, (b_local, seq))
        for t in range(seq):
            toks[:, t + 1] = self.next_tokens[toks[:, t], choices[:, t]]
        return {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
                "labels": jnp.asarray(toks[:, 1:], jnp.int32)}


def lm_batch_spec(batch: int, seq: int):
    return {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
            "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}


# ------------------------------ BCPNN streams -------------------------------

def poisson_external_drive(p, n_ticks: int, seed: int = 0, width: int = 8,
                           lam: float | None = None):
    """Yields (H, width) external spike-row arrays, Poisson(lam) per HCU."""
    lam = lam if lam is not None else min(p.in_rate, width / 2)
    rng = np.random.default_rng(seed)
    for _ in range(n_ticks):
        out = np.full((p.n_hcu, width), p.rows, np.int32)
        for h in range(p.n_hcu):
            n = min(width, rng.poisson(lam))
            out[h, :n] = rng.integers(0, p.rows, n)
        yield jnp.asarray(out)


def pattern_drive(p, patterns: np.ndarray, schedule, width: int = 8,
                  noise: float = 0.0, seed: int = 0):
    """Drive the network with stored patterns (associative-memory training).

    patterns: (n_patterns, n_hcu) winning-row index per HCU per pattern.
    schedule: iterable of pattern ids (or -1 for silence) per tick.
    Each active tick, every HCU receives a spike on its pattern row (plus
    optional noise rows).
    """
    rng = np.random.default_rng(seed)
    for pid in schedule:
        out = np.full((p.n_hcu, width), p.rows, np.int32)
        if pid >= 0:
            out[:, 0] = patterns[pid]
            if noise > 0:
                for h in range(p.n_hcu):
                    if rng.random() < noise:
                        out[h, 1] = rng.integers(0, p.rows)
        yield jnp.asarray(out)


def make_patterns(p, n_patterns: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, p.rows, (n_patterns, p.n_hcu))
