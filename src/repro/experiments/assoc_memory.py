"""Associative-memory train/cue/recall protocol (paper §I-II), reusable.

The protocol from `examples/bcpnn_assoc_memory.py`, factored into functions
so it can be driven both as a demo and as a measurement harness:

  train_assoc       present P patterns repeatedly; record each pattern's
                    attractor (winning MCU per HCU)
  recall_accuracy   cue with partial patterns from the trained state and
                    count undriven HCUs that complete to their attractor —
                    with an optional `corrupt` hook applied to the state
                    before each recall (the DRAM-retention fault experiment
                    in `benchmarks/resilience.py` plugs
                    `repro.runtime.resilience.inject_retention_faults`
                    in here)

Chance level is 1/C (C = MCUs per HCU); a working associative memory scores
far above it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BCPNNParams


def assoc_params() -> BCPNNParams:
    """The small associative-memory network the example and the resilience
    benchmark share (12 HCUs, 8 MCUs each, slow P traces)."""
    return BCPNNParams(n_hcu=12, rows=64, cols=8, fanout=12, active_queue=16,
                       max_delay=4, mean_delay=1.5, out_rate=1.0,
                       wta_temp=0.25, tau_p=400.0)


def drive_frame(p: BCPNNParams, pattern_rows, active_mask,
                width: int = 4) -> jnp.ndarray:
    """One (H, width) external-input frame: pattern row in slot 0 for active
    HCUs, padding (row index == p.rows) everywhere else."""
    ext = np.full((p.n_hcu, width), p.rows, np.int32)
    for h in range(p.n_hcu):
        if active_mask[h]:
            ext[h, 0] = pattern_rows[h]
    return jnp.asarray(ext)


def winners_from_fired(fired) -> np.ndarray:
    """Last WTA winner per HCU from a (T, H) fired history (-1 where the
    HCU never fired)."""
    fired = np.asarray(fired)
    winners = np.full((fired.shape[1],), -1, np.int64)
    for f in fired:
        upd = f >= 0
        winners[upd] = f[upd]
    return winners


def _present(sim, frame, n_ticks: int) -> np.ndarray:
    """Run one presentation through the staged scan driver (bitwise the same
    trajectory as per-tick `sim.tick` calls — the engine contract)."""
    ext = jnp.broadcast_to(frame, (n_ticks,) + frame.shape)
    return winners_from_fired(sim.run(ext))


def train_assoc(sim, patterns, *, reps: int = 30, present_ms: int = 6,
                gap_ms: int = 2) -> np.ndarray:
    """Present every pattern `reps` times (with `gap_ms` of silence between
    sweeps so Z traces decay); returns the (P, H) attractor — each pattern's
    winning MCU per HCU on the final presentation. Leaves `sim.state` as the
    trained state."""
    p = sim.p
    n_patterns = len(patterns)
    all_on = np.ones(p.n_hcu, bool)
    silence = drive_frame(p, patterns[0], np.zeros(p.n_hcu, bool))
    attractor = np.zeros((n_patterns, p.n_hcu), np.int64)
    for rep in range(reps):
        for pid in range(n_patterns):
            winners = _present(sim, drive_frame(p, patterns[pid], all_on),
                               present_ms)
            if rep == reps - 1:
                attractor[pid] = winners
        _present(sim, silence, gap_ms)
    return attractor


def sram_loss(state, p: BCPNNParams):
    """Reset the volatile j-side state (zj/ej/pj vectors and the support
    membrane h) to its init values, keeping the synaptic ij planes and lazy
    i-vectors — the state after a power cycle in the paper's memory split:
    j-vectors live in (volatile) SRAM, the big planes in 3D DRAM.

    Recall from an `sram_loss` state is carried by the DRAM planes ALONE:
    without the reset, the trained pj bias can dominate the WTA support and
    recall survives arbitrary plane corruption — measuring nothing. The
    retention-fault experiment (`benchmarks/resilience.py`) always applies
    this before corrupting the planes."""
    h = state.hcus
    return state._replace(hcus=h._replace(
        zj=jnp.zeros_like(h.zj), ej=jnp.zeros_like(h.ej),
        pj=jnp.full_like(h.pj, p.p_init), h=jnp.zeros_like(h.h)))


def recall_accuracy(sim, trained_state, patterns, attractor, *,
                    cue_fraction: float = 0.6, recall_ms: int = 12,
                    rng=None, corrupt=None) -> tuple[int, int]:
    """Partial-cue pattern completion score: (correct, total) over the
    undriven HCUs of every pattern.

    Each recall starts from a fresh copy of `trained_state` (drivers donate
    their input buffers). `corrupt(state) -> state`, if given, is applied to
    that copy before the cue — the fault-injection hook.
    """
    p = sim.p
    rng = rng if rng is not None else np.random.default_rng(0)
    correct = total = 0
    for pid in range(len(patterns)):
        cue_mask = rng.random(p.n_hcu) < cue_fraction
        frame = drive_frame(p, patterns[pid], cue_mask)
        state = jax.tree.map(jnp.copy, trained_state)
        if corrupt is not None:
            state = corrupt(state)
        sim.state = state
        winners = _present(sim, frame, recall_ms)
        probe = ~cue_mask & (winners >= 0) & (attractor[pid] >= 0)
        correct += int((winners[probe] == attractor[pid][probe]).sum())
        total += int(probe.sum())
    return correct, total
