"""Reusable experiment harnesses over the Simulator facade.

`repro.experiments.assoc_memory` factors the associative-memory
train/cue/recall protocol out of `examples/bcpnn_assoc_memory.py` so the
resilience benchmark (`benchmarks/resilience.py`) can re-run recall under
injected DRAM-retention faults without duplicating the protocol.
"""
from repro.experiments.assoc_memory import (assoc_params, drive_frame,
                                            recall_accuracy, sram_loss,
                                            train_assoc, winners_from_fired)

__all__ = ["assoc_params", "drive_frame", "recall_accuracy", "sram_loss",
           "train_assoc", "winners_from_fired"]
