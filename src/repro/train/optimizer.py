"""AdamW in pure JAX (optax is not available in this environment).

Optimizer state is a pytree congruent with params, so the launch layer can
shard it with the same (or ZeRO-extended) PartitionSpecs as the parameters.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100

    def init(self, params) -> AdamWState:
        z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(z, params),
                          nu=jax.tree.map(z, params))

    def schedule(self, step):
        warm = jnp.minimum(step / max(self.warmup_steps, 1), 1.0)
        return self.lr * warm

    def update(self, grads, state: AdamWState, params):
        # global-norm clip
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
        step = state.step + 1
        lr = self.schedule(step)
        c1 = 1.0 - self.b1 ** step.astype(jnp.float32)
        c2 = 1.0 - self.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * g * g
            u = (m / c1) / (jnp.sqrt(v / c2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state.mu)
        flat_v = jax.tree.leaves(state.nu)
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
        new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
        new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
        return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm,
                                                       "lr": lr}
