from repro.train.optimizer import AdamW, AdamWState
from repro.train.train_step import (cross_entropy, make_eval_step,
                                    make_loss_fn, make_train_step)
from repro.train.serve_step import generate, make_decode_step, make_prefill

__all__ = ["AdamW", "AdamWState", "cross_entropy", "make_eval_step",
           "make_loss_fn", "make_train_step", "generate", "make_decode_step",
           "make_prefill"]
