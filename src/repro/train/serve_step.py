"""Serving steps: batched prefill + incremental decode with sampling.

serve_step (decode) is what the decode_32k / long_500k dry-run cells lower:
one new token against a seq_len-deep cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.transformer import Model


def sample(logits, key, temperature: float = 0.0):
    """logits (B,1,V) -> (B,1) token ids. temperature==0 => greedy."""
    if temperature == 0.0:
        return jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    g = jax.random.categorical(key, logits[:, -1, :] / temperature)
    return g[:, None].astype(jnp.int32)


def make_prefill(model: Model):
    def prefill(params, batch, caches, pad=None):
        return model.prefill(params, batch, caches, pad=pad)
    return prefill


def make_decode_step(model: Model, temperature: float = 0.0):
    def decode_step(params, token, pos, caches, key, memory=None,
                    mem_pos=None, pad=None):
        logits, caches = model.decode_step(params, token, pos, caches,
                                           memory, mem_pos, pad=pad)
        nxt = sample(logits, key, temperature)
        return nxt, logits, caches
    return decode_step


def generate(model: Model, params, batch, max_new: int, max_len: int,
             temperature: float = 0.0, key=None):
    """Host-loop generation driver (examples/serving)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    B, S = batch["tokens"].shape
    caches = model.init_cache(B, max_len)
    memory, mem_pos = model._encode_memory(params, batch)
    prefill = jax.jit(make_prefill(model))
    step = jax.jit(make_decode_step(model, temperature))
    logits, caches = prefill(params, batch, caches)
    tok = sample(logits, key, temperature)
    out = [tok]
    for i in range(max_new - 1):
        key = jax.random.fold_in(key, i)
        tok, logits, caches = step(params, tok, jnp.asarray(S + i, jnp.int32),
                                   caches, key, memory, mem_pos)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
