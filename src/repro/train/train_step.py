"""Training step factory: loss, grads, AdamW update, metrics.

The returned step is a single jit-able function of (params, opt_state,
batch); the launch layer binds it to a mesh with in/out shardings (DP over
pod+data, TP/EP over model, ZeRO-style optimizer-state sharding).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.transformer import Model
from repro.train.optimizer import AdamW

AUX_LOSS_WEIGHT = 0.01
Z_LOSS_WEIGHT = 1e-4


def cross_entropy(logits, labels, z_loss: float = Z_LOSS_WEIGHT):
    """Token-mean CE with z-loss; logits (B,S,V) any dtype, labels (B,S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(lse - ll)
    zl = jnp.mean(jnp.square(lse))
    return ce + z_loss * zl, ce


def make_loss_fn(model: Model):
    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch)
        total, ce = cross_entropy(logits, batch["labels"])
        total = total + AUX_LOSS_WEIGHT * aux
        return total, {"loss": ce, "aux": aux}
    return loss_fn


def make_train_step(model: Model, opt: AdamW):
    loss_fn = make_loss_fn(model)

    def train_step(params, opt_state, batch):
        (total, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt_state, opt_metrics = opt.update(grads, opt_state, params)
        metrics = dict(metrics, total=total, **opt_metrics)
        return params, opt_state, metrics

    return train_step


def make_eval_step(model: Model):
    loss_fn = make_loss_fn(model)

    def eval_step(params, batch):
        _, metrics = loss_fn(params, batch)
        return metrics

    return eval_step
