"""Sharded, async, atomic checkpointing (pure numpy container format).

Layout:
  <dir>/step_<N>/manifest.json      tree structure + leaf metadata
  <dir>/step_<N>/leaf_<i>.npy       one file per pytree leaf
  <dir>/LATEST                      atomic pointer (written last)

Properties needed at 1000-node scale:
  * atomic: a step directory is staged under .tmp_ and renamed only when
    complete, and LATEST is updated only after the rename — a crash mid-save
    never corrupts the restorable state;
  * async: `save_async` snapshots to host memory synchronously (cheap) and
    writes in a background thread so the train loop is not blocked;
  * restartable: `restore_latest` + a params/opt template rebuilds arbitrary
    pytrees (NamedTuples, dicts, lists) and re-places them onto the current
    mesh — device count may differ from save time (elastic restart), since
    leaves are saved as full logical arrays.
  * bounded: keep_last prunes old steps.

For multi-host deployments each host would write only the addressable shards
of each leaf (leaf_<i>.shard_<k>.npy); the single-process container exercises
the full-array path, and runtime/elastic.py covers the re-sharding logic.
"""
from __future__ import annotations

import json
import logging
import os
import shutil
import threading
import time
import zlib

import jax
import numpy as np

log = logging.getLogger("repro.checkpoint")


class CheckpointCorruption(RuntimeError):
    """A step directory whose leaf bytes no longer match the checksums its
    manifest recorded at save time — torn write, bit rot, tampering. Raised
    by `restore`; `restore_latest` recovers by falling back to the newest
    intact step (see its docstring)."""


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _leaf_checksum(arr: np.ndarray) -> str:
    """crc32 over the raw leaf bytes (dtype/shape are covered separately by
    the npy header + template shape check)."""
    return f"{zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF:08x}"


def _step_id(name: str) -> int | None:
    """step_<N> -> N; None for anything else (tmp dirs, stray files)."""
    if not name.startswith("step_"):
        return None
    try:
        return int(name[len("step_"):])
    except ValueError:
        return None


def _sweep_stale_tmp(ckpt_dir: str):
    """Remove `.tmp_step_*` staging dirs orphaned by a crash mid-save.

    Safe: a tmp dir only exists between `save` staging and its atomic
    rename, and saves within one process are serialized (AsyncCheckpointer
    joins the previous write before starting the next) — so any tmp dir
    found at the START of a save is a leftover from a died writer.
    """
    for d in os.listdir(ckpt_dir):
        if d.startswith(".tmp_step_"):
            shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def save(ckpt_dir: str, step: int, tree, keep_last: int = 3,
         extra_meta: dict | None = None) -> str:
    """``extra_meta`` (optional, JSON-serializable) is merged into the
    manifest — e.g. the Simulator records the plane-layout tag so a restore
    under a different layout knows to convert. Reserved manifest keys
    (step/n_leaves/checksums/treedef/time) win over extra_meta."""
    os.makedirs(ckpt_dir, exist_ok=True)
    _sweep_stale_tmp(ckpt_dir)
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}_{os.getpid()}")
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(tree)
    checksums = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        np.save(os.path.join(tmp, f"leaf_{i}.npy"), arr)
        checksums.append(_leaf_checksum(arr))
    meta = dict(extra_meta or {})
    meta.update({"step": step, "n_leaves": len(leaves),
                 "checksums": checksums, "treedef": str(treedef),
                 "time": time.time()})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(ckpt_dir, ".LATEST_tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(ckpt_dir, ".LATEST_tmp"),
               os.path.join(ckpt_dir, "LATEST"))
    _prune(ckpt_dir, keep_last)
    return final


def _prune(ckpt_dir: str, keep_last: int):
    steps = sorted(s for s in map(_step_id, os.listdir(ckpt_dir))
                   if s is not None)
    for s in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


class AsyncCheckpointer:
    """Snapshot-to-host synchronously; write to disk in the background.

    A failed background save is never silently lost: the exception is
    captured and re-raised on the next `wait()` or `save_async()` — the
    caller's crash-recovery contract must not quietly degrade to an older
    checkpoint because a write died out of band."""

    def __init__(self, ckpt_dir: str, keep_last: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep_last = keep_last
        self._thread: threading.Thread | None = None
        self._exc: BaseException | None = None

    def _write(self, step: int, host_tree):
        try:
            save(self.ckpt_dir, step, host_tree, keep_last=self.keep_last)
        except BaseException as e:  # noqa: BLE001 — must cross the thread
            self._exc = e

    def save_async(self, step: int, tree):
        self.wait()
        # np.array, not np.asarray: on CPU jax the latter can alias the
        # device buffer, and a donating run launched before the background
        # write finishes would corrupt the checkpoint in flight
        host_tree = jax.tree.map(lambda x: np.array(x), tree)  # snapshot
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise exc


def _is_complete(ckpt_dir: str, step: int) -> bool:
    """A step dir is restorable iff its manifest parses and every leaf file
    it promises exists (a crash between staging and rename can't produce a
    partial step dir, but a corrupt LATEST can point at a pruned one)."""
    d = os.path.join(ckpt_dir, f"step_{step}")
    try:
        with open(os.path.join(d, "manifest.json")) as f:
            meta = json.load(f)
        return all(os.path.exists(os.path.join(d, f"leaf_{i}.npy"))
                   for i in range(int(meta["n_leaves"])))
    except (OSError, ValueError, KeyError):
        return False


def latest_step(ckpt_dir: str) -> int | None:
    """Newest complete step, or None. LATEST is only a hint: if it is
    missing, corrupt, or points at an incomplete/pruned step, fall back to
    scanning for the newest complete step directory."""
    if not os.path.isdir(ckpt_dir):
        return None
    p = os.path.join(ckpt_dir, "LATEST")
    try:
        with open(p) as f:
            s = int(f.read().strip())
        if _is_complete(ckpt_dir, s):
            return s
    except (OSError, ValueError):
        pass
    steps = sorted((s for s in map(_step_id, os.listdir(ckpt_dir))
                    if s is not None), reverse=True)
    for s in steps:
        if _is_complete(ckpt_dir, s):
            return s
    return None


def _manifest(ckpt_dir: str, step: int) -> dict | None:
    try:
        with open(os.path.join(ckpt_dir, f"step_{step}",
                               "manifest.json")) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def manifest(ckpt_dir: str, step: int) -> dict | None:
    """Public manifest reader: the step's metadata dict (including any
    extra_meta recorded at save time, e.g. the plane-layout tag), or None
    if the step has no parseable manifest."""
    return _manifest(ckpt_dir, step)


def restore(ckpt_dir: str, step: int, template, migrate=None):
    """Restore into the structure of `template` (values are placeholders).

    If the manifest carries per-leaf checksums (every save since they were
    introduced), the loaded bytes are verified against them and a mismatch
    raises `CheckpointCorruption` — a checksum-less (older) manifest loads
    unverified. ``migrate`` (optional) is applied as
    migrate(loaded_leaf, template_leaf) -> leaf before the shape check — the
    hook layout-migration shims (e.g. `migrate_flat_planes`) plug into.
    """
    d = os.path.join(ckpt_dir, f"step_{step}")
    leaves, treedef = _flatten(template)
    meta = _manifest(ckpt_dir, step)
    n_have = (meta or {}).get("n_leaves")
    if n_have is not None and int(n_have) != len(leaves):
        raise ValueError(
            f"step {step}: checkpoint has {n_have} leaves, template wants "
            f"{len(leaves)} (older-format checkpoint? see restore_network)")
    out = [np.load(os.path.join(d, f"leaf_{i}.npy"))
           for i in range(len(leaves))]
    sums = (meta or {}).get("checksums")
    if sums is not None:
        bad = [i for i, a in enumerate(out)
               if i < len(sums) and _leaf_checksum(a) != sums[i]]
        if bad:
            raise CheckpointCorruption(
                f"step {step}: leaf checksum mismatch at {bad} "
                f"(torn write or bit rot under {d})")
    if migrate is not None:
        out = [migrate(a, t) for a, t in zip(out, leaves)]
    for i, (a, t) in enumerate(zip(out, leaves)):
        want = getattr(t, "shape", None)
        if want is not None and tuple(a.shape) != tuple(want):
            raise ValueError(f"leaf {i}: checkpoint shape {a.shape} != "
                             f"template {want}")
    return jax.tree.unflatten(treedef, out)


def migrate_flat_planes(leaf, template_leaf):
    """Layout shim: batched (H, R, ...) leaves -> canonical flat (H*R, ...).

    Pre-engine BCPNN checkpoints stored `NetworkState.hcus` in the batched
    layout — ij planes (H, R, C), i-vectors (H, R). The canonical layout
    merges the two leading axes (a pure row-major reshape, bitwise the same
    values). A leaf is migrated iff it has exactly one more leading axis
    than the template wants and folding its first two axes yields the
    template shape; everything else (and every already-flat leaf) passes
    through untouched, so the shim is safe to apply unconditionally.
    """
    want = getattr(template_leaf, "shape", None)
    if want is None:
        return leaf
    want = tuple(want)
    have = tuple(leaf.shape)
    if have != want and len(have) == len(want) + 1 and len(have) >= 2 \
            and (have[0] * have[1],) + have[2:] == want:
        return leaf.reshape(want)
    return leaf


def restore_network(ckpt_dir: str, step: int, template):
    """One-call NetworkState restore with the legacy migration shims:

    * layout — loads both canonical-flat and pre-engine (H, R, C)-layout
      checkpoints into a canonical-flat template (`migrate_flat_planes`);
    * counters — pre-`drops_route` checkpoints are exactly one trailing
      leaf short (the field was appended last); the missing route-drop
      counter is re-initialized to 0, since historical route drops were
      folded into `drops_fire`.
    """
    meta = _manifest(ckpt_dir, step)
    tmpl_route = getattr(template, "drops_route", None)
    if meta is not None and tmpl_route is not None and \
            int(meta.get("n_leaves", -1)) == \
            len(jax.tree.leaves(template)) - 1:
        old = restore(ckpt_dir, step, template._replace(drops_route=None),
                      migrate=migrate_flat_planes)
        return old._replace(
            drops_route=np.zeros_like(np.asarray(tmpl_route)))
    return restore(ckpt_dir, step, template, migrate=migrate_flat_planes)


def restore_latest(ckpt_dir: str, template, *, prune_corrupt: bool = True):
    """Restore the newest VERIFIED checkpoint, or (None, None).

    A step whose checksums fail verification is pruned (deleted) and the
    scan falls back to the next-newest complete step — so a torn or
    bit-rotted save costs one checkpoint interval, never the run. Pass
    ``prune_corrupt=False`` to re-raise `CheckpointCorruption` instead
    (forensics mode: the corrupt dir is left in place)."""
    while True:
        s = latest_step(ckpt_dir)
        if s is None:
            return None, None
        try:
            return restore(ckpt_dir, s, template), s
        except CheckpointCorruption as e:
            if not prune_corrupt:
                raise
            log.warning("pruning corrupt checkpoint step_%d: %s", s, e)
            # not ignore_errors: if the dir can't be removed, latest_step
            # would hand it straight back — better to surface the OSError
            shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"))
