from repro.checkpoint.checkpointer import (AsyncCheckpointer, latest_step,
                                           restore, restore_latest, save)

__all__ = ["AsyncCheckpointer", "latest_step", "restore", "restore_latest",
           "save"]
