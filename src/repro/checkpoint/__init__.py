from repro.checkpoint.checkpointer import (AsyncCheckpointer,
                                           CheckpointCorruption, latest_step,
                                           manifest, migrate_flat_planes,
                                           restore, restore_latest,
                                           restore_network, save)

__all__ = ["AsyncCheckpointer", "CheckpointCorruption", "latest_step",
           "manifest", "migrate_flat_planes", "restore", "restore_latest",
           "restore_network", "save"]
