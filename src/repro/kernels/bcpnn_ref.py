"""Pure-jnp oracle for the fused BCPNN lazy cell update.

This is the reference ("golden model" in the paper's §VII.A.2 sense) for the
Pallas kernel in `bcpnn_update.py`. One call performs, per synaptic cell:

  1. integrated lazy decay of the (Zij, Eij, Pij) cascade across the gap
     ``now - Tij`` (closed form, see repro.core.traces),
  2. the Hebbian spike increment  Zij += dz,
  3. the Bayesian weight recompute  Wij = log(Pij / (Pi * Pj)),
  4. timestamp update Tij = now.

Two access patterns, mirroring the paper's row/column updates:
  - row update:    block (S, C); dz is rank-1:  counts (S,1) * zj (1,C)
  - column update: block (S, L); dz is full-rank (pre-gathered Zi(t) values)
Both are expressed through ``cell_update_ref`` with broadcastable args.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.traces import DecayCoeffs, ZEP, decay_zep, bayesian_weight


def cell_update_ref(zij, eij, pij, tij, now, dz, p_pre, p_post,
                    coeffs: DecayCoeffs, eps: float):
    """Fused lazy decay + Hebbian increment + Bayesian weight.

    Args:
      zij, eij, pij: trace planes, shape (..., L) f32.
      tij: int32 timestamps, same shape.
      now: scalar int32/float current time (ms).
      dz:  Z increment applied after decay (broadcastable).
      p_pre:  presynaptic P trace at `now` (broadcastable)  -> weight denominator.
      p_post: postsynaptic P trace at `now` (broadcastable) -> weight denominator.
      coeffs: decay coefficients for the ij product trace (tau_z').
      eps: probability regularizer.

    Returns:
      (zij', eij', pij', wij', tij') with tij' = now everywhere.
    """
    dt = (now - tij).astype(zij.dtype)
    z1, e1, p1 = decay_zep(ZEP(zij, eij, pij), dt, coeffs)
    z1 = z1 + dz
    w1 = bayesian_weight(p1, p_pre, p_post, eps)
    t1 = jnp.broadcast_to(jnp.asarray(now, tij.dtype), tij.shape)
    return z1, e1, p1, w1, t1


def row_update_ref(zij, eij, pij, tij, now, counts, zj, p_i, p_j,
                   coeffs: DecayCoeffs, eps: float):
    """Row update: blocks (S, C), rank-1 increment counts[:,None]*zj[None,:].

    counts: (S,) spike multiplicities for the S gathered rows.
    zj:     (C,) postsynaptic Z traces at `now`.
    p_i:    (S,) presynaptic P traces at `now` (post-increment of i-vector).
    p_j:    (C,) postsynaptic P traces at `now`.
    """
    dz = counts[:, None] * zj[None, :]
    return cell_update_ref(zij, eij, pij, tij, now, dz,
                           p_i[:, None], p_j[None, :], coeffs, eps)


def col_update_ref(zij, eij, pij, tij, now, zi_t, p_i, p_j_scalar,
                   coeffs: DecayCoeffs, eps: float):
    """Column update: the (R,) column is viewed as (R/L, L) lanes.

    zi_t: (R/L, L) presynaptic Z traces at `now` (the Hebbian increment).
    p_i:  (R/L, L) presynaptic P traces at `now`.
    p_j_scalar: postsynaptic P trace of the fired MCU.
    """
    return cell_update_ref(zij, eij, pij, tij, now, zi_t,
                           p_i, p_j_scalar, coeffs, eps)
