"""Backend-dispatching jit wrappers around the BCPNN update kernels.

Backends:
  "ref"               pure-jnp oracle (fast on CPU; default off-TPU)
  "pallas"            compiled Pallas kernel (TPU target)
  "pallas_interpret"  Pallas interpret mode (kernel-body semantics on CPU —
                      used by tests to validate the kernel against the oracle)

Selected via REPRO_KERNEL_BACKEND or the explicit ``backend=`` argument.
The wrappers own all shape plumbing (padding to (8,128) tiles, column
reshape), so callers deal only in logical (S, C) / (R,) shapes.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core.traces import DecayCoeffs
from repro.kernels import bcpnn_ref, bcpnn_update


def default_backend() -> str:
    env = os.environ.get("REPRO_KERNEL_BACKEND")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _pad2(x, s_to: int, c_to: int, fill=0):
    S, C = x.shape
    if S == s_to and C == c_to:
        return x
    return jnp.pad(x, ((0, s_to - S), (0, c_to - C)), constant_values=fill)


def _pad1(x, n_to: int, fill=0):
    n = x.shape[0]
    if n == n_to:
        return x
    return jnp.pad(x, (0, n_to - n), constant_values=fill)


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def row_update(zij, eij, pij, tij, now, counts, zj, p_i, p_j,
               coeffs: DecayCoeffs, eps: float, backend: str | None = None,
               wij=None):
    """Fused lazy row update on an (S, C) block of gathered rows.

    Returns (zij', eij', pij', wij', tij'), logical shapes preserved.
    ``wij`` (optional) is the current weight plane block: it is never read,
    but passing it lets the Pallas path alias all five planes in place
    (callers on the hot path should always pass it).
    """
    backend = backend or default_backend()
    if backend == "ref":
        return bcpnn_ref.row_update_ref(zij, eij, pij, tij, now, counts, zj,
                                        p_i, p_j, coeffs, eps)
    S, C = zij.shape
    if wij is None:
        wij = jnp.zeros_like(zij)
    bs = min(bcpnn_update.DEFAULT_BLOCK_S, _round_up(S, 8))
    Sp, Cp = _round_up(S, bs), _round_up(C, bcpnn_update.DEFAULT_BLOCK_L)
    interp = backend == "pallas_interpret"
    out = bcpnn_update.row_update_kernel_call(
        _pad2(zij, Sp, Cp), _pad2(eij, Sp, Cp), _pad2(pij, Sp, Cp),
        _pad2(wij, Sp, Cp), _pad2(tij, Sp, Cp, fill=0), now,
        _pad1(counts, Sp), _pad1(zj, Cp), _pad1(p_i, Sp), _pad1(p_j, Cp),
        k=coeffs, eps=eps, bs=bs, interpret=interp)
    return tuple(o[:S, :C] for o in out)


def worklist_row_update(zij, eij, pij, wij, tij, rows, nv, now, counts, zj,
                        p_i, pj, coeffs: DecayCoeffs, eps: float,
                        backend: str | None = None):
    """Worklist row update over the canonical flat (H*R, C) planes (Pallas
    backends only; the "ref" worklist path lives in `repro.core.worklist` as
    in-place dynamic-slice loops — this wrapper is the TPU/interpret
    dispatch). Since PR 3 the flat planes are `NetworkState.hcus`'s STORED
    layout (`core.layout.flat_state`), so the engine passes them here
    directly — no flatten/unflatten around the call.

    rows (W,): compacted-valid-first flat row indices (entries >= nv are
    ignored whatever they hold); counts/p_i (W,); zj/pj (W, C) per-entry
    operands. Planes are padded to HR+>=1 junk rows (8-multiple) and a lane
    multiple of C; every entry at or past nv is rerouted onto the junk
    region so a padding grid step can never revisit (and, in interpret
    mode, clobber) a row a valid entry updated. The alignment padding is the
    one remaining per-call copy: storing the planes pre-aligned (+ junk row)
    would make this zero-copy thanks to input_output_aliases — partly
    realized in PR 8 by the degenerate (Tc == 1) `core.layout.BlockedLayout`:
    its stored tiles reshape to a plane already aligned in lanes and
    8-multiple rows (`BlockedLayout.flat_view`; the engine remaps the
    row-index stream via `BlockedLayout.pad_row_index`), leaving only this
    wrapper's >=1 junk-row tail as a per-call pad.
    """
    backend = backend or default_backend()
    HR, C = zij.shape
    W = rows.shape[0]
    HRp = _round_up(HR + 1, 8)       # always >= 1 junk row for padding
    Cp = _round_up(C, bcpnn_update.DEFAULT_BLOCK_L)
    interp = backend == "pallas_interpret"
    rows_eff = jnp.where(jnp.arange(W) < jnp.asarray(nv, jnp.int32),
                         jnp.clip(rows, 0, HRp - 1), HRp - 1)
    out = bcpnn_update.worklist_update_kernel_call(
        _pad2(zij, HRp, Cp), _pad2(eij, HRp, Cp), _pad2(pij, HRp, Cp),
        _pad2(wij, HRp, Cp), _pad2(tij, HRp, Cp, fill=0),
        rows_eff, nv, now, counts,
        _pad2(zj, W, Cp), p_i, _pad2(pj, W, Cp),
        k=coeffs, eps=eps, interpret=interp)
    return tuple(o[:HR, :C] for o in out)


def fused_row_update(zij, eij, pij, wij, tij, zi, ei, pi, ti, rows, now,
                     counts, zj, p_i, pj, zi_new, ei_new, pi_new,
                     coeffs: DecayCoeffs, eps: float,
                     backend: str | None = None):
    """Fused worklist row phase over the canonical flat planes — Pallas
    megakernel dispatch (the "ref" fused path is
    `worklist.fused_stage_compute` + `worklist.write_rows`;
    this wrapper is the TPU/interpret half of `engine.worklist_lazy_rows`'
    fused branch).

    One kernel launch completes the whole row phase: the five (H*R, C) ij
    planes AND the four (H*R,) i-vectors are rewritten in place (aliased),
    and the per-entry recomputed weight rows come back as a (W, C) buffer
    for the WTA drive — replacing the old three-op tail (worklist kernel +
    four i-vector scatters + a Wij re-gather).

    rows (W,): SLOT-ordered flat row indices, one per worklist slot, with
    the H*R sentinel on padding/duplicate slots (no compaction: the grid is
    W steps either way, and slot order is what makes the weight-row output
    land h-major for free). counts/p_i/zi_new/ei_new/pi_new (W,);
    zj/pj (W, C) per-entry operands. Sentinel entries are rerouted onto the
    junk row region (>= H*R) added by the alignment padding, so a padding
    grid step can never clobber a touched row.
    Returns ((zij', eij', pij', wij', tij'), (zi', ei', pi', ti'), w_rows).
    """
    backend = backend or default_backend()
    HR, C = zij.shape
    W = rows.shape[0]
    HRp = _round_up(HR + 1, 8)       # always >= 1 junk row for padding
    Cp = _round_up(C, bcpnn_update.DEFAULT_BLOCK_L)
    interp = backend == "pallas_interpret"
    rows_eff = jnp.where(rows < HR, jnp.clip(rows, 0, HRp - 1), HRp - 1)
    iv2 = lambda v, fill=0: _pad1(v, HRp, fill).reshape(HRp, 1)
    out = bcpnn_update.fused_row_update_kernel_call(
        _pad2(zij, HRp, Cp), _pad2(eij, HRp, Cp), _pad2(pij, HRp, Cp),
        _pad2(wij, HRp, Cp), _pad2(tij, HRp, Cp, fill=0),
        iv2(zi), iv2(ei), iv2(pi), iv2(ti),
        rows_eff, now, counts, _pad2(zj, W, Cp), p_i, _pad2(pj, W, Cp),
        zi_new, ei_new, pi_new, k=coeffs, eps=eps, hr=HR, interpret=interp)
    flats = tuple(o[:HR, :C] for o in out[:5])
    ivecs = tuple(o.reshape(HRp)[:HR] for o in out[5:9])
    return flats, ivecs, out[9][:, :C]


def fused_col_update(zij, eij, pij, wij, tij, h_idx, j_idx, now, zi_t, p_i,
                     pj_sc, coeffs: DecayCoeffs, eps: float, n_hcu: int,
                     rows: int, backend: str | None = None):
    """Fused worklist column phase over the canonical flat planes — Pallas
    megakernel dispatch (the "ref" fused path is
    `worklist.fused_col_stage_compute` + `worklist.write_cols`; this wrapper
    is the TPU/interpret half of `engine._column_worklist`'s fused branch).

    One kernel launch completes the whole column phase except the Zj bump:
    for each valid fired-batch entry the (rows, 1) column block at
    (h_idx*rows, j_idx) of the five (H*rows, C) ij planes is rewritten in
    place (aliased), Tij stamped to `now` in-kernel.

    h_idx/j_idx (K,): the compacted fired batch as produced by
    `network.select_fired` (padding entries carry h_idx == n_hcu and are
    rerouted onto the junk row-block appended by the alignment padding, so
    a padding grid step can never clobber — or stale-overwrite — a fired
    column). zi_t/p_i (K, rows): per-entry presynaptic traces at `now`
    (transposed here to column-major (rows, K), lane-padded); pj_sc (K,):
    per-entry postsynaptic P.
    Returns the five updated (H*rows, C) planes.
    """
    backend = backend or default_backend()
    HR, C = zij.shape
    K = h_idx.shape[0]
    L = bcpnn_update.DEFAULT_BLOCK_L
    # lane-align C and add one junk ROW-BLOCK (bs rows) for padding
    # entries. The pad + unpad copies per call are the same accepted
    # per-call trade as the row megakernel's — storing the planes
    # pre-aligned is the next layout step if TPU profiles show the pad
    # dominating.
    Cp = _round_up(C, L)
    bs = next(b for b in (bcpnn_update.DEFAULT_BLOCK_S, 4, 2, 1)
              if rows % b == 0)
    HRp = HR + bs
    assert K <= L, "fired-batch capacity exceeds one lane tile"
    interp = backend == "pallas_interpret"
    valid = h_idx < n_hcu
    r_bs = rows // bs
    row_base = jnp.where(valid, jnp.clip(h_idx, 0, n_hcu - 1) * r_bs,
                         HR // bs)
    row_step = valid.astype(jnp.int32)
    j_eff = jnp.where(valid, jnp.clip(j_idx, 0, C - 1), 0)
    out = bcpnn_update.fused_col_update_kernel_call(
        _pad2(zij, HRp, Cp), _pad2(eij, HRp, Cp), _pad2(pij, HRp, Cp),
        _pad2(wij, HRp, Cp), _pad2(tij, HRp, Cp, fill=0),
        row_base, row_step, j_eff // L, j_eff % L, now,
        _pad2(zi_t.T, rows, L), _pad2(p_i.T, rows, L),
        pj_sc.reshape(K, 1), k=coeffs, eps=eps, r=rows, bs=bs,
        interpret=interp)
    return tuple(o[:HR, :C] for o in out)


def col_update(z_col, e_col, p_col, t_col, now, zi_t, p_i, p_j_scalar,
               coeffs: DecayCoeffs, eps: float, backend: str | None = None,
               w_col=None):
    """Fused lazy column update on an (R,) column (paper: 100 row-sized chunks).

    All column args are (R,); returns (z', e', p', w', t') each (R,).
    ``w_col`` (optional) is aliased in place by the Pallas path (never read).
    """
    backend = backend or default_backend()
    if backend == "ref":
        return bcpnn_ref.col_update_ref(z_col, e_col, p_col, t_col, now,
                                        zi_t, p_i, p_j_scalar, coeffs, eps)
    (R,) = z_col.shape
    if w_col is None:
        w_col = jnp.zeros_like(z_col)
    L = bcpnn_update.DEFAULT_BLOCK_L
    bs = bcpnn_update.DEFAULT_BLOCK_S
    Rp = _round_up(R, L * bs)

    def shp(x, fill=0):
        return _pad1(x, Rp, fill).reshape(Rp // L, L)

    interp = backend == "pallas_interpret"
    out = bcpnn_update.col_update_kernel_call(
        shp(z_col), shp(e_col), shp(p_col), shp(w_col), shp(t_col), now,
        shp(zi_t), shp(p_i), p_j_scalar, k=coeffs, eps=eps, bs=bs,
        interpret=interp)
    return tuple(o.reshape(Rp)[:R] for o in out)
