"""Pallas TPU flash-attention (forward) — fused online-softmax attention.

The LM-side perf-critical kernel: never materializes the (Sq, Skv) logits in
HBM. Grid = (batch*heads, q_blocks, kv_blocks); the kv dimension is the
innermost (sequential) axis, carrying the running (max, denom, accumulator)
in VMEM scratch across kv steps — Pallas double-buffers the K/V tile DMA
against the MXU matmuls, the same ping-pong structure as the BCPNN update
kernel (and the paper's EQ3 k=2 design point).

Supports causal masking, sliding windows and logit softcap (gemma2).
Validated against ref.py / the dense jnp oracle in interpret mode
(tests/test_flash_attention.py); `repro.models.layers` uses it when
cfg.attn_impl == "pallas_flash" on a TPU backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except ImportError:  # pragma: no cover
    pltpu = None
    _VMEM = None

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(kvlen_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                  acc_ref, *, scale: float, causal: bool, window: int | None,
                  softcap: float | None, bq: int, bk: int, n_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                     # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                     # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    logits = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * scale
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < kvlen_ref[0, 0]        # dynamic cache-validity bound
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_ref[...]                                  # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1, keepdims=True))
    p = jnp.exp(logits - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "causal", "window",
                                             "softcap", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, scale: float, causal: bool = True,
                    window: int | None = None, softcap: float | None = None,
                    kv_len=None, bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = False):
    """q: (BH, Sq, hd), k/v: (BH, Skv, hd) -> (BH, Sq, hd).

    GQA callers fold (batch, kv_head, group) into BH with k/v broadcast.
    Sq % bq == 0 and Skv % bk == 0 required (caller pads). kv_len (dynamic
    int32 scalar) bounds the valid cache prefix; defaults to Skv.
    """
    BH, Sq, hd = q.shape
    Skv = k.shape[1]
    n_q, n_k = Sq // bq, Skv // bk
    grid = (BH, n_q, n_k)
    if kv_len is None:
        kv_len = Skv
    kv_arr = jnp.asarray(kv_len, jnp.int32).reshape(1, 1)
    kern = functools.partial(_flash_kernel, scale=scale, causal=causal,
                             window=window, softcap=softcap, bq=bq, bk=bk,
                             n_k=n_k)
    scratch = [
        _new_scratch((bq, 1), jnp.float32),
        _new_scratch((bq, 1), jnp.float32),
        _new_scratch((bq, hd), jnp.float32),
    ]
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, i, j: (0, 0)),
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(kv_arr, q, k, v)


def _new_scratch(shape, dtype):
    if pltpu is not None:
        return pltpu.VMEM(shape, dtype)
    return pl.MemorySpace.ANY(shape, dtype)  # pragma: no cover


def flash_attention_ref(q, k, v, *, scale: float, causal: bool = True,
                        window: int | None = None,
                        softcap: float | None = None):
    """Dense jnp oracle with identical masking semantics."""
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    Sq, Skv = q.shape[1], k.shape[1]
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    logits = jnp.where(mask[None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
