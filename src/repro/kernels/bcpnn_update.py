"""Pallas TPU kernel for the fused BCPNN lazy cell update.

This kernel is the TPU analogue of the paper's per-cell FPU-set datapath
(§VI.C: <3 mul, 2 add, 2 exp> + log/div, two cells per cycle) combined with
its ping-pong buffering (EQ3, k=2):

  * the whole closed-form ZEP decay + Hebbian increment + Bayesian weight is
    ONE fused VPU pipeline — traces never round-trip to HBM between stages;
  * Pallas double-buffers HBM->VMEM tile DMA across grid steps, overlapping
    memory with compute exactly like the paper's ping-pong buffers mask
    T_DRAM behind T_row_comp;
  * blocks are (BS, 128)-shaped: the 128-lane dimension is the hardware
    analogue of the paper's "cell-level parallelism" (#FPU_sets).

Five entry points:
  row_update_kernel_call        : (S, C) row blocks, rank-1 counts x zj
  col_update_kernel_call        : a column viewed as (R/128, 128) lanes
  worklist_update_kernel_call   : scalar-prefetch grid over a network-global
                                  worklist of flat (H*R, C) plane rows
  fused_row_update_kernel_call  : the worklist row-phase MEGAKERNEL — same
                                  scalar-prefetch grid, but one grid step
                                  completes the whole row phase for its
                                  entry: the five ij planes AND the four
                                  i-vector planes are aliased in place, and
                                  the freshly recomputed weight row is
                                  emitted per entry for the WTA drive
  fused_col_update_kernel_call  : the worklist column-phase MEGAKERNEL —
                                  2-D scalar-prefetch grid over FIRED
                                  ENTRIES x ROW-BLOCKS: each step rewrites
                                  one (8, 128) lane tile of the fired
                                  column in place through an in-kernel
                                  lane mask (Tij `now` stamp emitted
                                  in-kernel); padding fired-batch entries
                                  are pinned onto a dedicated junk
                                  row-block

All alias the five state-plane inputs onto their outputs
(``input_output_aliases``), so the Zij/Eij/Pij/Wij/Tij planes are rewritten
in place — the paper's in-situ 192-bit cell rewrite — instead of allocating
five fresh planes per call.

These kernels are layout-oblivious: they always see a flat (rows, lanes)
plane. The PR 8 column-blocked storage (`core.layout.BlockedLayout`) feeds
them at its TPU degenerate point (Tc == 1, the (8, 128) tile) as a pure
reshape (`BlockedLayout.flat_view`) with the row-index stream remapped by
the engine — no BlockSpec/index-map variant needed here.

The worklist kernel is the TPU half of the O(touched rows) tick runtime
(`repro.core.worklist` + `repro.core.engine.WorklistBackend`; the flat
(H*R, C) planes it consumes are the canonical STORED layout of
`NetworkState.hcus` since the TickEngine refactor): the deduplicated
worklist row indices arrive as a scalar-prefetch operand, every BlockSpec
index_map is driven by them, and
each grid step DMAs exactly one touched (1, C) row block per plane, updates
it with the fused cell math, and writes it back in place. Per tick the
planes therefore cost O(worklist) row-block DMAs instead of O(H*R*C)
gather/scatter traffic — the memory-access shape of the paper's lazy model
(§VI.D: bandwidth scales with spikes, not synapses). Grid steps past the
valid-entry count (and steps whose entry was deduplicated away) write their
block back unchanged. Because grid steps write data-dependent, potentially
repeated rows in place, the worklist grid is declared with
``("arbitrary",)`` dimension semantics — never "parallel", which is
reserved for the dense row/col kernels whose blocks are disjoint.

Validated against `bcpnn_ref` in interpret mode (tests/test_kernels.py,
tests/test_worklist.py); on a real TPU the same code path compiles to
Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # compiler params API varies across jax versions; best-effort only
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

from repro.core.traces import DecayCoeffs

# Default VMEM tiling. Row updates arrive as (n_spikes<=40, 128-padded C);
# column updates as (R/128, 128). (8,128) is the f32 native tile; BS=8 keeps
# the working set (12 planes * 8*128*4B = 48 KiB) far under VMEM while giving
# the DMA engine whole tiles.
DEFAULT_BLOCK_S = 8
DEFAULT_BLOCK_L = 128


def _cell_math(z, e, p, dt, dz, p_pre, p_post, k: DecayCoeffs, eps: float):
    """Shared per-cell arithmetic; mirrors traces.decay_zep + bayesian_weight."""
    ez = jnp.exp(-dt * k.inv_tau_z)
    ee = jnp.exp(-dt * k.inv_tau_e)
    ep_ = jnp.exp(-dt * k.inv_tau_p)
    e1 = e * ee + z * (ez - ee) * k.c_ze
    p1 = (p * ep_
          + (e - z * k.c_ze) * (ee - ep_) * k.c_ep
          + z * k.c_ze * (ez - ep_) * k.c_zp)
    z1 = z * ez + dz
    w1 = jnp.log((p1 + eps * eps) / ((p_pre + eps) * (p_post + eps)))
    return z1, e1, p1, w1


def _row_kernel(now_ref, z_ref, e_ref, p_ref, w_ref, t_ref, counts_ref,
                zj_ref, pi_ref, pj_ref, zo_ref, eo_ref, po_ref, wo_ref,
                to_ref, *, k: DecayCoeffs, eps: float):
    # w_ref is never read: Wij is recomputed, but threading it through as an
    # input lets pallas_call alias it onto wo_ref (in-place plane rewrite).
    del w_ref
    now = now_ref[0, 0]
    dt = (now - t_ref[...]).astype(jnp.float32)
    dz = counts_ref[...] * zj_ref[...]          # (BS,1) * (1,BL) rank-1 bcast
    z1, e1, p1, w1 = _cell_math(z_ref[...], e_ref[...], p_ref[...], dt, dz,
                                pi_ref[...], pj_ref[...], k, eps)
    to_ref[...] = jnp.full_like(t_ref[...], now)
    zo_ref[...] = z1
    eo_ref[...] = e1
    po_ref[...] = p1
    wo_ref[...] = w1


def _col_kernel(now_ref, z_ref, e_ref, p_ref, w_ref, t_ref, zi_ref, pi_ref,
                pj_ref, zo_ref, eo_ref, po_ref, wo_ref, to_ref,
                *, k: DecayCoeffs, eps: float):
    del w_ref                                    # alias-only input (see above)
    now = now_ref[0, 0]
    dt = (now - t_ref[...]).astype(jnp.float32)
    z1, e1, p1, w1 = _cell_math(z_ref[...], e_ref[...], p_ref[...], dt,
                                zi_ref[...], pi_ref[...], pj_ref[...], k, eps)
    to_ref[...] = jnp.full_like(t_ref[...], now)
    zo_ref[...] = z1
    eo_ref[...] = e1
    po_ref[...] = p1
    wo_ref[...] = w1


def _compiler_params(semantics=("parallel", "parallel")):
    """Best-effort TPU compiler params with explicit dimension semantics.

    The dense row/col kernels write disjoint (bs, bl) blocks, so their 2-D
    grids are genuinely ("parallel", "parallel"). The worklist kernel's grid
    is data-dependent — prefetched row indices may repeat (padding entries
    all alias one row) and every block is rewritten in place — so it MUST be
    ("arbitrary",): declaring it parallel would license Mosaic to reorder or
    overlap grid steps whose writes alias.
    """
    if pltpu is None:
        return None
    for name in ("CompilerParams", "TPUCompilerParams"):
        cls = getattr(pltpu, name, None)
        if cls is not None:
            try:
                return cls(dimension_semantics=tuple(semantics))
            except Exception:  # pragma: no cover
                return None
    return None


# Alias the five state planes onto the five outputs: Zij/Eij/Pij/Wij/Tij are
# rewritten in place (the TPU analogue of the paper's in-situ 192-bit cell
# rewrite, §VI.C) — per update the planes cost one HBM read + one write
# instead of read + write-to-fresh-allocation, halving traffic on the planes.
# Input indices: 0=now, 1=zij, 2=eij, 3=pij, 4=wij, 5=tij.
_PLANE_ALIASES = {1: 0, 2: 1, 3: 2, 4: 3, 5: 4}


@functools.partial(jax.jit, static_argnames=("k", "eps", "bs", "bl", "interpret"))
def row_update_kernel_call(zij, eij, pij, wij, tij, now, counts, zj, p_i, p_j,
                           k: DecayCoeffs, eps: float,
                           bs: int = DEFAULT_BLOCK_S, bl: int = DEFAULT_BLOCK_L,
                           interpret: bool = False):
    """Pallas row update over (S, C) blocks. S % bs == 0, C % bl == 0 required
    (ops.py pads). counts (S,), zj (C,), p_i (S,), p_j (C,). All five plane
    inputs are donated to the outputs via input_output_aliases."""
    S, C = zij.shape
    grid = (S // bs, C // bl)
    now_arr = jnp.asarray(now, jnp.int32).reshape(1, 1)
    sc = pl.BlockSpec((bs, bl), lambda i, j: (i, j))
    s1 = pl.BlockSpec((bs, 1), lambda i, j: (i, 0))
    c1 = pl.BlockSpec((1, bl), lambda i, j: (0, j))
    one = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    out_shape = [jax.ShapeDtypeStruct((S, C), jnp.float32)] * 4 \
        + [jax.ShapeDtypeStruct((S, C), jnp.int32)]
    kwargs = {}
    cp = _compiler_params()
    if cp is not None and not interpret:
        kwargs["compiler_params"] = cp
    fn = pl.pallas_call(
        functools.partial(_row_kernel, k=k, eps=eps),
        grid=grid,
        in_specs=[one, sc, sc, sc, sc, sc, s1, c1, s1, c1],
        out_specs=[sc, sc, sc, sc, sc],
        out_shape=out_shape,
        input_output_aliases=_PLANE_ALIASES,
        interpret=interpret,
        **kwargs,
    )
    return fn(now_arr, zij, eij, pij, wij, tij,
              counts.reshape(S, 1), zj.reshape(1, C),
              p_i.reshape(S, 1), p_j.reshape(1, C))


def _worklist_kernel(rows_ref, nv_ref, now_ref, z_ref, e_ref, p_ref, w_ref,
                     t_ref, counts_ref, zj_ref, pi_ref, pj_ref,
                     zo_ref, eo_ref, po_ref, wo_ref, to_ref,
                     *, k: DecayCoeffs, eps: float):
    """One worklist entry per grid step: the (1, C) row block the BlockSpec
    index_maps DMA'd in (rows_ref[i] selected it) is updated with the fused
    cell math and written back in place. Entries at or past nv pass their
    block through unchanged; the caller (ops.worklist_row_update) reroutes
    them onto a junk row past the logical plane, so a padding step can
    never even revisit a touched row — the `valid` gate here is defense in
    depth on top of that, under the ("arbitrary",) sequential grid
    semantics."""
    i = pl.program_id(0)
    valid = i < nv_ref[0]
    now = now_ref[0, 0]
    dt = (now - t_ref[...]).astype(jnp.float32)
    dz = counts_ref[...] * zj_ref[...]           # (1,1) * (1,BL) rank-1
    z1, e1, p1, w1 = _cell_math(z_ref[...], e_ref[...], p_ref[...], dt, dz,
                                pi_ref[...], pj_ref[...], k, eps)
    zo_ref[...] = jnp.where(valid, z1, z_ref[...])
    eo_ref[...] = jnp.where(valid, e1, e_ref[...])
    po_ref[...] = jnp.where(valid, p1, p_ref[...])
    wo_ref[...] = jnp.where(valid, w1, w_ref[...])
    to_ref[...] = jnp.where(valid, jnp.full_like(t_ref[...], now), t_ref[...])


# With PrefetchScalarGridSpec the alias indices count the scalar-prefetch
# operands first: 0=rows, 1=nv, then 2=now, 3=zij ... 7=tij.
_WORKLIST_ALIASES = {3: 0, 4: 1, 5: 2, 6: 3, 7: 4}


@functools.partial(jax.jit, static_argnames=("k", "eps", "interpret"))
def worklist_update_kernel_call(zij, eij, pij, wij, tij, rows, nv, now,
                                counts, zj, p_i, pj, k: DecayCoeffs,
                                eps: float, interpret: bool = False):
    """Scalar-prefetch Pallas worklist update over flat (HR, C) planes.

    rows (W,) int32 — flat plane row index per worklist entry, compacted
    valid-first and clipped into range (entries >= nv are ignored);
    nv (1,) int32 — valid-entry count; counts/p_i (W,) and zj/pj (W, C) —
    per-entry operands. HR % 8 == 0 and C % 128 == 0 required (ops.py pads).
    The five plane inputs alias the outputs: each grid step rewrites only
    its touched (1, C) row block in place — O(worklist) DMA per call.
    """
    HR, C = zij.shape
    W = rows.shape[0]
    if pltpu is None:  # pragma: no cover - pltpu import failed
        raise NotImplementedError(
            "worklist_update_kernel_call needs jax.experimental.pallas.tpu "
            "(PrefetchScalarGridSpec); use the 'ref' worklist path instead")
    now_arr = jnp.asarray(now, jnp.int32).reshape(1, 1)
    row_spec = pl.BlockSpec((1, C), lambda i, rows_ref, nv_ref:
                            (rows_ref[i], 0))
    ent_spec = pl.BlockSpec((1, C), lambda i, rows_ref, nv_ref: (i, 0))
    ent1_spec = pl.BlockSpec((1, 1), lambda i, rows_ref, nv_ref: (i, 0))
    one = pl.BlockSpec((1, 1), lambda i, rows_ref, nv_ref: (0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(W,),
        in_specs=[one, row_spec, row_spec, row_spec, row_spec, row_spec,
                  ent1_spec, ent_spec, ent1_spec, ent_spec],
        out_specs=[row_spec] * 5,
    )
    out_shape = [jax.ShapeDtypeStruct((HR, C), jnp.float32)] * 4 \
        + [jax.ShapeDtypeStruct((HR, C), jnp.int32)]
    kwargs = {}
    cp = _compiler_params(("arbitrary",))
    if cp is not None and not interpret:
        kwargs["compiler_params"] = cp
    fn = pl.pallas_call(
        functools.partial(_worklist_kernel, k=k, eps=eps),
        grid_spec=grid_spec,
        out_shape=out_shape,
        input_output_aliases=_WORKLIST_ALIASES,
        interpret=interpret,
        **kwargs,
    )
    return fn(rows.astype(jnp.int32), jnp.asarray(nv, jnp.int32).reshape(1),
              now_arr, zij, eij, pij, wij, tij,
              counts.reshape(W, 1), zj, p_i.reshape(W, 1), pj)


def _fused_row_kernel(rows_ref, now_ref, z_ref, e_ref, p_ref, w_ref, t_ref,
                      zi_ref, ei_ref, pi_ref, ti_ref, counts_ref, zj_ref,
                      piv_ref, pj_ref, zin_ref, ein_ref, pin_ref,
                      zo_ref, eo_ref, po_ref, wo_ref, to_ref,
                      zio_ref, eio_ref, pio_ref, tio_ref, wrow_ref,
                      *, k: DecayCoeffs, eps: float, hr: int):
    """One worklist entry per grid step, the WHOLE row phase fused:

      * the (1, C) ij-plane row blocks the index_maps DMA'd in are updated
        with the fused cell math and written back in place (aliased);
      * the entry's (1, 1) i-vector cells are rewritten in place from the
        prefetched post-decay values (the i-vector math runs once in the
        engine prologue — same sealed `ivec_decay` island as every other
        path — so the kernel only moves the results);
      * the recomputed weight row is emitted to the per-entry `wrow` output,
        which IS the WTA drive input — no post-kernel re-gather of Wij.

    Validity is per entry, not a compacted prefix: `rows` is slot-ordered
    and the caller reroutes invalid slots onto the junk row past the logical
    plane (row >= hr), so a padding step can only ever rewrite junk. The
    `valid` gate keeps even that write a pass-through."""
    i = pl.program_id(0)
    valid = rows_ref[i] < hr
    now = now_ref[0, 0]
    dt = (now - t_ref[...]).astype(jnp.float32)
    dz = counts_ref[...] * zj_ref[...]           # (1,1) * (1,BL) rank-1
    z1, e1, p1, w1 = _cell_math(z_ref[...], e_ref[...], p_ref[...], dt, dz,
                                piv_ref[...], pj_ref[...], k, eps)
    zo_ref[...] = jnp.where(valid, z1, z_ref[...])
    eo_ref[...] = jnp.where(valid, e1, e_ref[...])
    po_ref[...] = jnp.where(valid, p1, p_ref[...])
    wo_ref[...] = jnp.where(valid, w1, w_ref[...])
    to_ref[...] = jnp.where(valid, jnp.full_like(t_ref[...], now), t_ref[...])
    zio_ref[...] = jnp.where(valid, zin_ref[...], zi_ref[...])
    eio_ref[...] = jnp.where(valid, ein_ref[...], ei_ref[...])
    pio_ref[...] = jnp.where(valid, pin_ref[...], pi_ref[...])
    tio_ref[...] = jnp.where(valid, jnp.full_like(ti_ref[...], now),
                             ti_ref[...])
    wrow_ref[...] = jnp.where(valid, w1, jnp.zeros_like(w1))


# Megakernel aliases (prefetch operands count first): 0=rows, 1=now,
# 2=zij..6=tij -> plane outputs 0..4; 7=zi..10=ti -> i-vector outputs 5..8.
# Output 9 (the per-entry weight row) is the one fresh allocation.
_FUSED_ALIASES = {2: 0, 3: 1, 4: 2, 5: 3, 6: 4, 7: 5, 8: 6, 9: 7, 10: 8}


@functools.partial(jax.jit, static_argnames=("k", "eps", "hr", "interpret"))
def fused_row_update_kernel_call(zij, eij, pij, wij, tij, zi, ei, pi, ti,
                                 rows, now, counts, zj, p_i, pj,
                                 zi_new, ei_new, pi_new, k: DecayCoeffs,
                                 eps: float, hr: int, interpret: bool = False):
    """Scalar-prefetch Pallas megakernel for the fused worklist row phase.

    Planes (HRp, C) f32/int32, i-vectors (HRp, 1); rows (W,) int32 SLOT-
    ordered flat row indices — entries for padding/duplicate slots must be
    rerouted by the caller onto junk rows in [hr, HRp) (``hr`` is the
    logical H*R row count; everything at or past it is junk territory).
    counts/p_i/zi_new/ei_new/pi_new (W, 1) and zj/pj (W, C) are per-entry
    operands. The nine state-plane inputs alias the nine state outputs
    (in-place rewrite); the tenth output is the (W, C) weight-row buffer
    consumed by the WTA drive. HRp % 8 == 0 and C % 128 == 0 required
    (ops.py pads).
    """
    HR, C = zij.shape
    W = rows.shape[0]
    if pltpu is None:  # pragma: no cover - pltpu import failed
        raise NotImplementedError(
            "fused_row_update_kernel_call needs jax.experimental.pallas.tpu "
            "(PrefetchScalarGridSpec); use the 'ref' fused loop instead")
    now_arr = jnp.asarray(now, jnp.int32).reshape(1, 1)
    row_spec = pl.BlockSpec((1, C), lambda i, rows_ref: (rows_ref[i], 0))
    iv_spec = pl.BlockSpec((1, 1), lambda i, rows_ref: (rows_ref[i], 0))
    ent_spec = pl.BlockSpec((1, C), lambda i, rows_ref: (i, 0))
    ent1_spec = pl.BlockSpec((1, 1), lambda i, rows_ref: (i, 0))
    one = pl.BlockSpec((1, 1), lambda i, rows_ref: (0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(W,),
        in_specs=[one,
                  row_spec, row_spec, row_spec, row_spec, row_spec,
                  iv_spec, iv_spec, iv_spec, iv_spec,
                  ent1_spec, ent_spec, ent1_spec, ent_spec,
                  ent1_spec, ent1_spec, ent1_spec],
        out_specs=[row_spec] * 5 + [iv_spec] * 4 + [ent_spec],
    )
    out_shape = [jax.ShapeDtypeStruct((HR, C), jnp.float32)] * 4 \
        + [jax.ShapeDtypeStruct((HR, C), jnp.int32)] \
        + [jax.ShapeDtypeStruct((HR, 1), jnp.float32)] * 3 \
        + [jax.ShapeDtypeStruct((HR, 1), jnp.int32)] \
        + [jax.ShapeDtypeStruct((W, C), jnp.float32)]
    kwargs = {}
    cp = _compiler_params(("arbitrary",))
    if cp is not None and not interpret:
        kwargs["compiler_params"] = cp
    fn = pl.pallas_call(
        functools.partial(_fused_row_kernel, k=k, eps=eps, hr=hr),
        grid_spec=grid_spec,
        out_shape=out_shape,
        input_output_aliases=_FUSED_ALIASES,
        interpret=interpret,
        **kwargs,
    )
    return fn(rows.astype(jnp.int32), now_arr, zij, eij, pij, wij, tij,
              zi, ei, pi, ti, counts.reshape(W, 1), zj,
              p_i.reshape(W, 1), pj, zi_new.reshape(W, 1),
              ei_new.reshape(W, 1), pi_new.reshape(W, 1))


def _fused_col_kernel(rbase_ref, rstep_ref, jt_ref, jl_ref, now_ref, z_ref,
                      e_ref, p_ref, w_ref, t_ref, zi_ref, pi_ref, pj_ref,
                      zo_ref, eo_ref, po_ref, wo_ref, to_ref,
                      *, k: DecayCoeffs, eps: float, bs: int, bl: int,
                      kp: int):
    """Grid step (entry e, row-block rb) of the fused column phase: the
    (bs, bl) lane tile of the five ij planes containing rows
    [h*R + rb*bs, ...) of the entry's fired column (rbase_ref[e] and the
    tile index jt_ref[e] selected the block) is DMA'd in, the fused cell
    math runs on every lane, and ONLY the fired column's lane (jl_ref[e],
    an in-kernel iota mask) is replaced — every other lane is written back
    bit-unchanged. Lane tiles are 128 wide, so Mosaic's lane-dimension
    alignment rules are satisfied without data-dependent sub-lane offsets
    (a (R, 1) block at a prefetched lane offset would not lower).

    The per-entry presynaptic traces arrive as (bs, kp) tiles of the
    lane-padded (R, kp) buffers; the entry's own lane is selected with a
    second iota mask and a lane reduce. Validity arrives as
    rstep_ref[e] (1 = valid): the caller pins every one of a padding
    entry's grid steps onto the dedicated junk row-block past the logical
    plane (rbase = HR/bs, rstep = 0), so a padding step can only ever
    rewrite junk — which matters beyond defense in depth: the block
    pipeline hands each step the block contents as of its own DMA, so a
    padding step sharing a tile with an already-updated valid column
    would write the STALE tile back. Valid entries never collide with
    each other (fired-batch HCU indices are unique, so their (h, jt)
    tiles differ); padding entries share only the junk block."""
    e = pl.program_id(0)
    valid = rstep_ref[e] == 1
    jl = jl_ref[e]
    now = now_ref[0, 0]
    lane = jax.lax.broadcasted_iota(jnp.int32, (bs, bl), 1)
    hit = valid & (lane == jl)                              # (bs, bl) mask
    # select the entry's presynaptic lane out of the (bs, kp) trace tiles
    ent_lane = jax.lax.broadcasted_iota(jnp.int32, (bs, kp), 1)
    sel = (ent_lane == e).astype(jnp.float32)
    zi = jnp.sum(zi_ref[...] * sel, axis=1, keepdims=True)  # (bs, 1)
    p_i = jnp.sum(pi_ref[...] * sel, axis=1, keepdims=True)
    dt = (now - t_ref[...]).astype(jnp.float32)
    z1, e1, p1, w1 = _cell_math(z_ref[...], e_ref[...], p_ref[...], dt,
                                zi, p_i, pj_ref[...], k, eps)
    zo_ref[...] = jnp.where(hit, z1, z_ref[...])
    eo_ref[...] = jnp.where(hit, e1, e_ref[...])
    po_ref[...] = jnp.where(hit, p1, p_ref[...])
    wo_ref[...] = jnp.where(hit, w1, w_ref[...])
    to_ref[...] = jnp.where(hit, jnp.full_like(t_ref[...], now), t_ref[...])


# Column-megakernel aliases (prefetch operands count first): 0=row_base,
# 1=row_step, 2=j_tile, 3=j_lane, 4=now, 5=zij ... 9=tij -> outputs 0..4.
_FUSED_COL_ALIASES = {5: 0, 6: 1, 7: 2, 8: 3, 9: 4}


@functools.partial(jax.jit, static_argnames=("k", "eps", "r", "bs",
                                             "interpret"))
def fused_col_update_kernel_call(zij, eij, pij, wij, tij, row_base, row_step,
                                 j_tile, j_lane, now, zi_cols, pi_cols, pj_e,
                                 k: DecayCoeffs, eps: float, r: int,
                                 bs: int = DEFAULT_BLOCK_S,
                                 interpret: bool = False):
    """Scalar-prefetch Pallas megakernel for the fused worklist column phase.

    Planes (H*r + bs, Cp) f32/int32 with Cp % 128 == 0 and r % bs == 0
    (ops.py pads; the trailing bs rows are the junk row-block). Per
    fired-batch entry, four prefetched (K,) int32 arrays select the column
    as lane ``j_lane`` of the (bs, 128) tiles at block
    (row_base + rb * row_step, j_tile): valid entries carry
    (h*r/bs, 1, j//128, j%128); padding entries carry (H*r/bs, 0, 0, 0) so
    every one of their grid steps lands on the junk row-block (they must
    never share a tile with a valid entry — see the kernel docstring). The
    grid is 2-D (entry, row-block), so VMEM holds only (bs, 128) tiles
    regardless of R (a human-scale R=10000 column does NOT fit VMEM as one
    block). zi_cols/pi_cols (r, kp) are the per-entry presynaptic traces
    at `now`, column-major and lane-padded to kp == 128 so their blocks
    cover the lane dimension exactly; pj_e (K, 1) the per-entry
    postsynaptic P scalar. The five plane inputs alias the five outputs:
    each grid step rewrites one (bs, 128) tile of the fired column in
    place — O(fired columns x R/bs) tile DMAs per call, the minimum the
    128-lane tile granularity allows (the paper's §VI.D column budget, at
    hardware tile resolution). Data-dependent in-place tiles ->
    ("arbitrary", "arbitrary") dimension semantics, like the row worklist
    kernels.
    """
    HRp, Cp = zij.shape
    K = row_base.shape[0]
    R_BS = r // bs
    kp = zi_cols.shape[1]
    if pltpu is None:  # pragma: no cover - pltpu import failed
        raise NotImplementedError(
            "fused_col_update_kernel_call needs jax.experimental.pallas.tpu "
            "(PrefetchScalarGridSpec); use the 'ref' fused loop instead")
    now_arr = jnp.asarray(now, jnp.int32).reshape(1, 1)
    tile = pl.BlockSpec((bs, DEFAULT_BLOCK_L),
                        lambda e, rb, rbase, rstep, jt, jl:
                        (rbase[e] + rb * rstep[e], jt[e]))
    ent_tile = pl.BlockSpec((bs, kp),
                            lambda e, rb, rbase, rstep, jt, jl: (rb, 0))
    ent1 = pl.BlockSpec((1, 1), lambda e, rb, rbase, rstep, jt, jl: (e, 0))
    one = pl.BlockSpec((1, 1), lambda e, rb, rbase, rstep, jt, jl: (0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(K, R_BS),
        in_specs=[one, tile, tile, tile, tile, tile,
                  ent_tile, ent_tile, ent1],
        out_specs=[tile] * 5,
    )
    out_shape = [jax.ShapeDtypeStruct((HRp, Cp), jnp.float32)] * 4 \
        + [jax.ShapeDtypeStruct((HRp, Cp), jnp.int32)]
    kwargs = {}
    cp = _compiler_params(("arbitrary", "arbitrary"))
    if cp is not None and not interpret:
        kwargs["compiler_params"] = cp
    fn = pl.pallas_call(
        functools.partial(_fused_col_kernel, k=k, eps=eps, bs=bs,
                          bl=DEFAULT_BLOCK_L, kp=kp),
        grid_spec=grid_spec,
        out_shape=out_shape,
        input_output_aliases=_FUSED_COL_ALIASES,
        interpret=interpret,
        **kwargs,
    )
    return fn(row_base.astype(jnp.int32), row_step.astype(jnp.int32),
              j_tile.astype(jnp.int32), j_lane.astype(jnp.int32), now_arr,
              zij, eij, pij, wij, tij, zi_cols, pi_cols, pj_e)


@functools.partial(jax.jit, static_argnames=("k", "eps", "bs", "bl", "interpret"))
def col_update_kernel_call(zij, eij, pij, wij, tij, now, zi_t, p_i, p_j_scalar,
                           k: DecayCoeffs, eps: float,
                           bs: int = DEFAULT_BLOCK_S, bl: int = DEFAULT_BLOCK_L,
                           interpret: bool = False):
    """Pallas column update; the (R,) column is pre-reshaped to (R/bl, bl).
    Plane inputs alias the outputs (in-place update, see _PLANE_ALIASES)."""
    S, C = zij.shape
    grid = (S // bs, C // bl)
    now_arr = jnp.asarray(now, jnp.int32).reshape(1, 1)
    sc = pl.BlockSpec((bs, bl), lambda i, j: (i, j))
    one = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    out_shape = [jax.ShapeDtypeStruct((S, C), jnp.float32)] * 4 \
        + [jax.ShapeDtypeStruct((S, C), jnp.int32)]
    kwargs = {}
    cp = _compiler_params()
    if cp is not None and not interpret:
        kwargs["compiler_params"] = cp
    fn = pl.pallas_call(
        functools.partial(_col_kernel, k=k, eps=eps),
        grid=grid,
        in_specs=[one, sc, sc, sc, sc, sc, sc, sc, one],
        out_specs=[sc, sc, sc, sc, sc],
        out_shape=out_shape,
        input_output_aliases=_PLANE_ALIASES,
        interpret=interpret,
        **kwargs,
    )
    return fn(now_arr, zij, eij, pij, wij, tij, zi_t, p_i,
              jnp.asarray(p_j_scalar, jnp.float32).reshape(1, 1))
