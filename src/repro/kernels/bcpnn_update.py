"""Pallas TPU kernel for the fused BCPNN lazy cell update.

This kernel is the TPU analogue of the paper's per-cell FPU-set datapath
(§VI.C: <3 mul, 2 add, 2 exp> + log/div, two cells per cycle) combined with
its ping-pong buffering (EQ3, k=2):

  * the whole closed-form ZEP decay + Hebbian increment + Bayesian weight is
    ONE fused VPU pipeline — traces never round-trip to HBM between stages;
  * Pallas double-buffers HBM->VMEM tile DMA across grid steps, overlapping
    memory with compute exactly like the paper's ping-pong buffers mask
    T_DRAM behind T_row_comp;
  * blocks are (BS, 128)-shaped: the 128-lane dimension is the hardware
    analogue of the paper's "cell-level parallelism" (#FPU_sets).

Two entry points:
  row_update_kernel_call : (S, C) row blocks, rank-1 increment counts x zj
  col_update_kernel_call : a column viewed as (R/128, 128) lanes, full-rank dz

Both alias the five state-plane inputs onto their outputs
(``input_output_aliases``), so the Zij/Eij/Pij/Wij/Tij planes are rewritten
in place — the paper's in-situ 192-bit cell rewrite — instead of allocating
five fresh planes per call.

Validated against `bcpnn_ref` in interpret mode (tests/test_kernels.py); on a
real TPU the same code path compiles to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # compiler params API varies across jax versions; best-effort only
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

from repro.core.traces import DecayCoeffs

# Default VMEM tiling. Row updates arrive as (n_spikes<=40, 128-padded C);
# column updates as (R/128, 128). (8,128) is the f32 native tile; BS=8 keeps
# the working set (12 planes * 8*128*4B = 48 KiB) far under VMEM while giving
# the DMA engine whole tiles.
DEFAULT_BLOCK_S = 8
DEFAULT_BLOCK_L = 128


def _cell_math(z, e, p, dt, dz, p_pre, p_post, k: DecayCoeffs, eps: float):
    """Shared per-cell arithmetic; mirrors traces.decay_zep + bayesian_weight."""
    ez = jnp.exp(-dt * k.inv_tau_z)
    ee = jnp.exp(-dt * k.inv_tau_e)
    ep_ = jnp.exp(-dt * k.inv_tau_p)
    e1 = e * ee + z * (ez - ee) * k.c_ze
    p1 = (p * ep_
          + (e - z * k.c_ze) * (ee - ep_) * k.c_ep
          + z * k.c_ze * (ez - ep_) * k.c_zp)
    z1 = z * ez + dz
    w1 = jnp.log((p1 + eps * eps) / ((p_pre + eps) * (p_post + eps)))
    return z1, e1, p1, w1


def _row_kernel(now_ref, z_ref, e_ref, p_ref, w_ref, t_ref, counts_ref,
                zj_ref, pi_ref, pj_ref, zo_ref, eo_ref, po_ref, wo_ref,
                to_ref, *, k: DecayCoeffs, eps: float):
    # w_ref is never read: Wij is recomputed, but threading it through as an
    # input lets pallas_call alias it onto wo_ref (in-place plane rewrite).
    del w_ref
    now = now_ref[0, 0]
    dt = (now - t_ref[...]).astype(jnp.float32)
    dz = counts_ref[...] * zj_ref[...]          # (BS,1) * (1,BL) rank-1 bcast
    z1, e1, p1, w1 = _cell_math(z_ref[...], e_ref[...], p_ref[...], dt, dz,
                                pi_ref[...], pj_ref[...], k, eps)
    to_ref[...] = jnp.full_like(t_ref[...], now)
    zo_ref[...] = z1
    eo_ref[...] = e1
    po_ref[...] = p1
    wo_ref[...] = w1


def _col_kernel(now_ref, z_ref, e_ref, p_ref, w_ref, t_ref, zi_ref, pi_ref,
                pj_ref, zo_ref, eo_ref, po_ref, wo_ref, to_ref,
                *, k: DecayCoeffs, eps: float):
    del w_ref                                    # alias-only input (see above)
    now = now_ref[0, 0]
    dt = (now - t_ref[...]).astype(jnp.float32)
    z1, e1, p1, w1 = _cell_math(z_ref[...], e_ref[...], p_ref[...], dt,
                                zi_ref[...], pi_ref[...], pj_ref[...], k, eps)
    to_ref[...] = jnp.full_like(t_ref[...], now)
    zo_ref[...] = z1
    eo_ref[...] = e1
    po_ref[...] = p1
    wo_ref[...] = w1


def _compiler_params():
    if pltpu is None:
        return None
    for name in ("CompilerParams", "TPUCompilerParams"):
        cls = getattr(pltpu, name, None)
        if cls is not None:
            try:
                return cls(dimension_semantics=("parallel", "parallel"))
            except Exception:  # pragma: no cover
                return None
    return None


# Alias the five state planes onto the five outputs: Zij/Eij/Pij/Wij/Tij are
# rewritten in place (the TPU analogue of the paper's in-situ 192-bit cell
# rewrite, §VI.C) — per update the planes cost one HBM read + one write
# instead of read + write-to-fresh-allocation, halving traffic on the planes.
# Input indices: 0=now, 1=zij, 2=eij, 3=pij, 4=wij, 5=tij.
_PLANE_ALIASES = {1: 0, 2: 1, 3: 2, 4: 3, 5: 4}


@functools.partial(jax.jit, static_argnames=("k", "eps", "bs", "bl", "interpret"))
def row_update_kernel_call(zij, eij, pij, wij, tij, now, counts, zj, p_i, p_j,
                           k: DecayCoeffs, eps: float,
                           bs: int = DEFAULT_BLOCK_S, bl: int = DEFAULT_BLOCK_L,
                           interpret: bool = False):
    """Pallas row update over (S, C) blocks. S % bs == 0, C % bl == 0 required
    (ops.py pads). counts (S,), zj (C,), p_i (S,), p_j (C,). All five plane
    inputs are donated to the outputs via input_output_aliases."""
    S, C = zij.shape
    grid = (S // bs, C // bl)
    now_arr = jnp.asarray(now, jnp.int32).reshape(1, 1)
    sc = pl.BlockSpec((bs, bl), lambda i, j: (i, j))
    s1 = pl.BlockSpec((bs, 1), lambda i, j: (i, 0))
    c1 = pl.BlockSpec((1, bl), lambda i, j: (0, j))
    one = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    out_shape = [jax.ShapeDtypeStruct((S, C), jnp.float32)] * 4 \
        + [jax.ShapeDtypeStruct((S, C), jnp.int32)]
    kwargs = {}
    cp = _compiler_params()
    if cp is not None and not interpret:
        kwargs["compiler_params"] = cp
    fn = pl.pallas_call(
        functools.partial(_row_kernel, k=k, eps=eps),
        grid=grid,
        in_specs=[one, sc, sc, sc, sc, sc, s1, c1, s1, c1],
        out_specs=[sc, sc, sc, sc, sc],
        out_shape=out_shape,
        input_output_aliases=_PLANE_ALIASES,
        interpret=interpret,
        **kwargs,
    )
    return fn(now_arr, zij, eij, pij, wij, tij,
              counts.reshape(S, 1), zj.reshape(1, C),
              p_i.reshape(S, 1), p_j.reshape(1, C))


@functools.partial(jax.jit, static_argnames=("k", "eps", "bs", "bl", "interpret"))
def col_update_kernel_call(zij, eij, pij, wij, tij, now, zi_t, p_i, p_j_scalar,
                           k: DecayCoeffs, eps: float,
                           bs: int = DEFAULT_BLOCK_S, bl: int = DEFAULT_BLOCK_L,
                           interpret: bool = False):
    """Pallas column update; the (R,) column is pre-reshaped to (R/bl, bl).
    Plane inputs alias the outputs (in-place update, see _PLANE_ALIASES)."""
    S, C = zij.shape
    grid = (S // bs, C // bl)
    now_arr = jnp.asarray(now, jnp.int32).reshape(1, 1)
    sc = pl.BlockSpec((bs, bl), lambda i, j: (i, j))
    one = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    out_shape = [jax.ShapeDtypeStruct((S, C), jnp.float32)] * 4 \
        + [jax.ShapeDtypeStruct((S, C), jnp.int32)]
    kwargs = {}
    cp = _compiler_params()
    if cp is not None and not interpret:
        kwargs["compiler_params"] = cp
    fn = pl.pallas_call(
        functools.partial(_col_kernel, k=k, eps=eps),
        grid=grid,
        in_specs=[one, sc, sc, sc, sc, sc, sc, sc, one],
        out_specs=[sc, sc, sc, sc, sc],
        out_shape=out_shape,
        input_output_aliases=_PLANE_ALIASES,
        interpret=interpret,
        **kwargs,
    )
    return fn(now_arr, zij, eij, pij, wij, tij, zi_t, p_i,
              jnp.asarray(p_j_scalar, jnp.float32).reshape(1, 1))
