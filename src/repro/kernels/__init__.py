"""Pallas TPU kernels for the perf-critical compute layers.

  bcpnn_update     fused lazy ZEP decay + Hebbian increment + Bayesian
                   weight per synaptic cell (row + column variants) — the
                   paper's FPU-set datapath (§VI.C) with ping-pong DMA
                   overlap (EQ3 k=2) as Pallas double buffering
  ops              jit'd dispatcher (ref | pallas | pallas_interpret)
  bcpnn_ref        pure-jnp oracle (golden model)
  flash_attention  fused online-softmax attention for the LM substrate
                   (causal / sliding-window / softcap / dynamic kv_len)

All kernels are validated against their oracles in interpret mode on CPU
(tests/test_kernels.py, tests/test_flash_attention.py) and compile to
Mosaic on a real TPU unchanged.
"""
from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention, flash_attention_ref

__all__ = ["ops", "flash_attention", "flash_attention_ref"]
