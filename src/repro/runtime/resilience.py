"""Resilient realtime BCPNN runtime: crash recovery, DRAM-retention fault
injection, and drop-budget health accounting.

eBrainII is not just a fast BCPNN — it is a *fault-priced* one. The paper
dimensions its spike queues against an explicit drop budget (queue size 36 ≈
one dropped spike per month, Fig 7 / EQ1 — `repro.core.queues`), and its
custom 3D DRAM deliberately relaxes refresh because BCPNN tolerates
synaptic-plane bit errors. This module turns those robustness claims into
runnable machinery over the tick engine, across three fault classes:

1. Crash/restart — `ResilientRunner` drives `Simulator.run` in chunks with
   async checkpoints every `save_every` chunks and injectable failures
   (`repro.runtime.elastic.InjectedFailure`). Restore-and-replay is BITWISE
   identical to the uninterrupted trajectory: the checkpoint stores exact
   NetworkState bits (incl. `base_key`), per-tick RNG keys are derived from
   the tick index (`engine.tick` folds `t` into `base_key`), external input
   is a pre-staged tensor re-sliced at the restored `t`, and scan-chunk
   boundaries do not affect bits (the PR 3 head-fixture contract, pinned by
   tests/test_resilience.py).

2. Memory faults — `flip_bits` / `inject_retention_faults` corrupt the flat
   synaptic ij planes (Zij/Eij/Pij/Wij/Tij) at a configurable per-bit rate
   and pattern, emulating relaxed-refresh 3D DRAM retention errors. The
   recall-quality experiment (`benchmarks/resilience.py`) measures
   associative-recall overlap vs flip rate and emits `BENCH_resilience.json`.

3. Overload/deadline faults — `HealthMonitor` reads the engine's
   already-maintained drop counters (`Simulator.drops`) per chunk, compares
   observed drops against the Fig 7 analytic budget
   (`repro.core.queues.drop_probability_per_ms` scaled to run length and HCU
   count), and folds in `StragglerMonitor` wall-clock accounting against the
   paper's 1 ms/tick realtime target. The policy is graceful degradation:
   log + flag in the structured health report (ok / over-budget /
   deadline-missed), never stall or abort the scan.

Everything here is host-side orchestration: the compiled tick graphs are
untouched, so enabling resilience cannot perturb trajectories.
"""
from __future__ import annotations

import dataclasses
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, restore_latest
from repro.core import network as N
from repro.core import queues
from repro.core.params import BCPNNParams
from repro.runtime.elastic import (DeviceLoss, InjectedFailure,
                                   RestartBudgetExceeded, StragglerMonitor,
                                   remesh)

log = logging.getLogger("repro.resilience")

# the five synaptic ij planes the paper stores in (relaxed-refresh) 3D DRAM
# — the 192-bit AoS cell, here as flat (H*R, C) SoA planes
IJ_PLANES = ("zij", "eij", "pij", "wij", "tij")

# paper realtime target: one biological ms per wall-clock ms
REALTIME_US_PER_TICK = 1000.0


def _host_copy(tree):
    """Deep host-memory snapshot (np.array forces a copy; on CPU jax,
    np.asarray may alias the device buffer a later donation invalidates)."""
    return jax.tree.map(np.array, tree)


def _device_tree(tree):
    return jax.tree.map(jnp.asarray, tree)


# ---------------------------------------------------------------------------
# fault class 2: DRAM-retention bit flips
# ---------------------------------------------------------------------------

def flip_bits(plane: jnp.ndarray, key, rate: float, *, mode: str = "flip",
              bit_mask: int = 0xFFFFFFFF) -> jnp.ndarray:
    """Corrupt a 32-bit state plane with independent per-bit faults.

    Each of the 32 bits of every cell is hit with probability `rate`
    (restricted to the bits set in `bit_mask`); `mode` selects the fault
    pattern:
      * "flip"  — invert the hit bits (generic soft error),
      * "clear" — force hit bits to 0 (a DRAM true-cell losing charge under
                  relaxed refresh — the retention-error pattern),
      * "set"   — force hit bits to 1 (anti-cell decay).
    rate=0.0 is a bitwise no-op. Deterministic in `key`. Works for the f32
    planes and the int32 Tij timestamps alike (both are bitcast to uint32).
    """
    if mode not in ("flip", "clear", "set"):
        raise ValueError(f"unknown fault mode {mode!r}")
    bits = jax.lax.bitcast_convert_type(plane, jnp.uint32)
    hit = jax.random.bernoulli(key, rate, bits.shape + (32,))
    weights = jnp.left_shift(jnp.uint32(1), jnp.arange(32, dtype=jnp.uint32))
    noise = jnp.sum(jnp.where(hit, weights, jnp.uint32(0)), axis=-1,
                    dtype=jnp.uint32) & jnp.uint32(bit_mask)
    if mode == "flip":
        bits = bits ^ noise
    elif mode == "clear":
        bits = bits & ~noise
    else:
        bits = bits | noise
    return jax.lax.bitcast_convert_type(bits, plane.dtype)


def inject_retention_faults(state, key, rate: float, *,
                            planes=IJ_PLANES, mode: str = "flip",
                            bit_mask: int = 0xFFFFFFFF):
    """Corrupt the selected synaptic planes of a NetworkState at per-bit
    `rate` — the software stand-in for running the paper's 3D DRAM below its
    worst-case refresh interval. Only the named ij planes are touched; queue
    state, j-vectors and RNG key stay exact (they live in the ASIC's SRAM,
    not the relaxed-refresh DRAM). Returns the corrupted state."""
    upd = {}
    for i, name in enumerate(planes):
        if name not in IJ_PLANES:
            raise ValueError(f"{name!r} is not a DRAM-resident ij plane "
                             f"{IJ_PLANES}")
        upd[name] = flip_bits(getattr(state.hcus, name),
                              jax.random.fold_in(key, i), rate,
                              mode=mode, bit_mask=bit_mask)
    return state._replace(hcus=state.hcus._replace(**upd))


# ---------------------------------------------------------------------------
# fault class 3: overload / deadline health accounting
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class HealthMonitor:
    """Per-chunk drop-budget + realtime-deadline accounting.

    Drops: the engine counts three Fig 7 failure classes — delay-queue
    overflows (`drops_in`), fired-batch overflows (`drops_fire`) and
    inter-device route-capacity overflows (`drops_route`, sharded fabric
    only). Each class is budgeted separately against its own analytic
    expectation (`repro.core.queues`, EQ1, scaled by `budget_headroom`):
    'in' at the dimensioned Poisson input rate over `n_hcu` queues, and —
    when the sharded context is known (`n_dev` + `route_cfg`, kept current
    by `ElasticRunner` across remeshes) — 'fire'/'route' at the per-device
    fired/fan-out rates against the RouteConfig capacities, so a degraded
    (shrunken-mesh) run is judged against the budget at its NEW capacity.

    Deadlines: a `StragglerMonitor` tracks per-chunk wall time against the
    paper's realtime target (`target_us_per_tick`, default 1 ms/tick).

    Policy: graceful degradation. The monitor never raises and never blocks;
    `report()` returns the structured verdict (ok / over-budget /
    deadline-missed) and violations are logged as they are observed.
    """
    p: BCPNNParams
    n_hcu: int | None = None
    target_us_per_tick: float = REALTIME_US_PER_TICK
    budget_headroom: float = 1.0
    n_dev: int = 1
    route_cfg: object | None = None    # RouteConfig of the current mesh
    ticks: int = 0
    straggler: StragglerMonitor = dataclasses.field(
        default_factory=lambda: StragglerMonitor(deadline_s=0.0))
    worst_us_per_tick: float = 0.0
    _drops0: dict | None = None
    _drops: dict | None = None

    def begin(self, drops: dict) -> None:
        """Record the drop-counter baseline (cumulative {'in','fire'})."""
        self._drops0 = dict(drops)
        self._drops = dict(drops)

    def chunk_start(self, n_ticks: int) -> None:
        self.straggler.deadline_s = n_ticks * self.target_us_per_tick / 1e6
        self.straggler.start()

    def chunk_end(self, n_ticks: int, drops: dict) -> bool:
        """Close out a chunk: wall-clock + drop accounting. Returns True if
        the chunk met its realtime deadline."""
        met = self.straggler.finish()
        per_tick_us = self.straggler.last_s * 1e6 / max(n_ticks, 1)
        if per_tick_us > self.worst_us_per_tick:
            self.worst_us_per_tick = per_tick_us
        self.ticks += n_ticks
        if self._drops0 is None:
            self._drops0 = {k: 0 for k in drops}
        self._drops = dict(drops)
        if not met:
            log.warning("deadline miss: chunk of %d ticks ran %.0f us/tick "
                        "(target %.0f)", n_ticks, per_tick_us,
                        self.target_us_per_tick)
        return met

    # -- verdict -------------------------------------------------------------
    def set_mesh(self, n_dev: int, route_cfg) -> None:
        """Refresh the sharded budgeting context after an (elastic) remesh:
        fire/route budgets from here on are priced at the new capacity."""
        self.n_dev = int(n_dev)
        self.route_cfg = route_cfg

    def class_budgets(self) -> dict:
        """Fig 7 analytic budget PER DROP CLASS, scaled to this run.

        'in'   — expected delay-queue drops over `ticks` ms x `n_hcu` queues
                 at the dimensioned Poisson input rate (EQ1);
        'fire' — expected fired-batch overflows: per device the fired count
                 is ~Poisson(out_rate * h_local) against cap_fire slots;
        'route'— expected fabric drops: each of the n_dev^2 (src, dst) pairs
                 carries ~Poisson(out_rate * h_local * fanout / n_dev)
                 messages against cap_route slots.
        'fire'/'route' require the sharded context (`route_cfg`); a local
        run budgets only 'in' — exactly the pre-elastic behaviour."""
        p = self.p
        n = self.n_hcu if self.n_hcu is not None else p.n_hcu
        out = {"in": queues.drop_probability_per_ms(p.active_queue, p.in_rate)
               * self.ticks * n}
        rc = self.route_cfg
        if rc is not None:
            nd = max(int(self.n_dev), 1)
            h_local = max(n // nd, 1)
            lam_fire = max(p.out_rate * h_local, 1e-6)
            out["fire"] = (queues.drop_probability_per_ms(rc.cap_fire,
                                                          lam_fire)
                           * self.ticks * nd)
            lam_route = max(p.out_rate * h_local * p.fanout / nd, 1e-6)
            out["route"] = (queues.drop_probability_per_ms(rc.cap_route,
                                                           lam_route)
                            * self.ticks * nd * nd)
        return out

    def expected_drops(self) -> float:
        """Fig 7 analytic budget scaled to this run: expected dropped spikes
        over `ticks` ms summed across the budgeted drop classes."""
        return sum(self.class_budgets().values())

    def observed_drops(self) -> dict:
        d0 = self._drops0 or {}
        d1 = self._drops or {}
        out = {k: int(d1.get(k, 0)) - int(d0.get(k, 0)) for k in d1}
        out["total"] = sum(out.values())
        return out

    def report(self, restarts: int = 0) -> dict:
        """Structured health verdict. Never raises; see docs/RESILIENCE.md
        for the schema."""
        obs = self.observed_drops()
        budgets = self.class_budgets()
        classes = {
            k: {"observed": obs.get(k, 0),
                "budget": b * self.budget_headroom,
                "over": obs.get(k, 0) > b * self.budget_headroom}
            for k, b in budgets.items()}
        budget = self.expected_drops() * self.budget_headroom
        over = (obs.get("total", 0) > budget
                or any(c["over"] for c in classes.values()))
        missed = self.straggler.slow_steps > 0
        status = ("over-budget" if over
                  else "deadline-missed" if missed else "ok")
        ticks = max(self.ticks, 1)
        rep = {
            "status": status,
            "ticks": self.ticks,
            "restarts": restarts,
            "drops": obs,
            "classes": classes,
            "budget": {
                "queue_size": self.p.active_queue,
                "lam": self.p.in_rate,
                "drop_p_per_ms": queues.drop_probability_per_ms(
                    self.p.active_queue, self.p.in_rate),
                "expected_drops_run": self.expected_drops(),
                "expected_drops_per_month_per_hcu":
                    queues.expected_drops_per_month(self.p.active_queue,
                                                    self.p.in_rate),
                "headroom": self.budget_headroom,
                "over_budget": over,
            },
            "deadline": {
                "target_us_per_tick": self.target_us_per_tick,
                "observed_us_per_tick": self.straggler.total_s * 1e6 / ticks,
                "worst_chunk_us_per_tick": self.worst_us_per_tick,
                "chunks": self.straggler.total,
                "chunks_missed": self.straggler.slow_steps,
                "missed": missed,
            },
        }
        if status != "ok":
            log.warning("health: %s (drops=%s budget=%.3f, %d/%d chunks "
                        "missed deadline)", status, obs, budget,
                        self.straggler.slow_steps, self.straggler.total)
        return rep


@dataclasses.dataclass
class ServingHealthMonitor(HealthMonitor):
    """HealthMonitor with the serving request queue as a fourth drop class.

    The continuous-batching recall server (`repro.launch.serve_bcpnn`) holds
    a fixed-capacity admission queue that is dimensioned exactly like the
    paper's spike queues: request arrivals ~ Poisson(`req_rate` per engine
    step) against `queue_capacity` waiting slots, drained once per step. The
    expected number of REJECTED requests over the run is therefore EQ1's
    tail mass at the queue size — `repro.core.queues.drop_probability_per_ms`
    with the engine step standing in for the millisecond — times the number
    of steps taken (`StragglerMonitor.total` chunks). Observed rejections
    ride in on the 'reject' key of the cumulative drops dict the server
    passes to `chunk_end`, so `report()` prices admission-queue overflow the
    same way it prices delay-queue ('in'), fired-batch ('fire') and fabric
    ('route') overflow: Fig 7, per class, at current capacity.

    With `req_rate == 0` (unknown offered load) no 'reject' budget is
    published; any observed rejection then counts against the total budget —
    an unprovisioned queue that rejects is unhealthy by definition.
    """
    queue_capacity: int = 0
    req_rate: float = 0.0      # expected request arrivals per engine step

    def class_budgets(self) -> dict:
        out = super().class_budgets()
        if self.queue_capacity and self.req_rate > 0:
            out["reject"] = (queues.drop_probability_per_ms(
                self.queue_capacity, self.req_rate) * self.straggler.total)
        return out


# ---------------------------------------------------------------------------
# fault class 1: crash / restart with bitwise replay
# ---------------------------------------------------------------------------

class ResilientRunner:
    """Drive a `Simulator` through a long staged run with checkpoints,
    bounded crash recovery, and health accounting.

        sim = Simulator(p, key=0)
        runner = ResilientRunner(sim, "ckpt", chunk_ticks=64, save_every=2)
        fired, health = runner.run(ext)          # (T, H) history + report

    The run is cut into `chunk_ticks`-tick scan calls; after every
    `save_every` chunks the NetworkState is snapshotted to host memory and
    written asynchronously (`repro.checkpoint.AsyncCheckpointer` — atomic
    step dirs, stale-tmp sweep). `fail_injector(chunk_index) -> bool`
    simulates a crash before that chunk (raised as `InjectedFailure`); the
    runner then restores the newest complete checkpoint — or the initial
    state when none landed yet — re-slices the staged input at the restored
    `t`, and replays. Replay is bitwise-identical to the uninterrupted run:
    per-tick RNG is derived from the tick index and the checkpointed
    `base_key`, and chunk boundaries do not affect bits (head-fixture
    contract). `max_restarts` bounds recovery (`RestartBudgetExceeded`).
    Real exceptions are never swallowed.

    Overlapping fired history is overwritten on replay with identical
    values, so the returned (T, H) history is exactly the uninterrupted one.
    """

    def __init__(self, sim, ckpt_dir: str, *, chunk_ticks: int = 64,
                 save_every: int = 1, keep_last: int = 3,
                 fail_injector=None, max_restarts: int = 8,
                 monitor: HealthMonitor | None = None):
        self.sim = sim
        self.ckpt = AsyncCheckpointer(ckpt_dir, keep_last=keep_last)
        self.ckpt_dir = ckpt_dir
        self.chunk_ticks = int(chunk_ticks)
        self.save_every = int(save_every)
        self.fail_injector = fail_injector
        self.max_restarts = int(max_restarts)
        self.monitor = monitor if monitor is not None else HealthMonitor(
            sim.p, n_hcu=sim.n_hcu)
        self.restarts = 0

    def run(self, ext, n_ticks: int | None = None):
        """Run `ext` (staged (T, H, A_ext) tensor, iterable of frames, or
        callable ext_fn(t) with `n_ticks`) to completion through crashes.
        Returns (fired_history (T, H) int32, health report dict)."""
        sim = self.sim
        t0 = int(sim.state.t)
        if callable(ext) or not hasattr(ext, "ndim"):
            ext = N.stage_external(ext, n_ticks, t0=t0)
        ext = jnp.asarray(ext)
        if n_ticks is not None:
            ext = ext[:n_ticks]
        T = int(ext.shape[0])
        n = sim.state.delay_rows.shape[0]
        fired = np.full((T, n), -1, np.int32)
        # restart-from-scratch target (drivers donate sim.state, so only a
        # host copy survives the first chunk)
        initial = _host_copy(sim.state)
        self.monitor.begin(sim.drops())
        done = 0                       # ticks completed == history position
        chunks_done = 0
        while done < T:
            step = min(self.chunk_ticks, T - done)
            try:
                if self.fail_injector is not None and \
                        self.fail_injector(done // self.chunk_ticks):
                    raise InjectedFailure(
                        f"injected failure at tick {t0 + done}")
                self.monitor.chunk_start(step)
                f = sim.run(jax.lax.slice_in_dim(ext, done, done + step))
                fired[done:done + step] = np.asarray(f)
                done += step
                chunks_done += 1
                self.monitor.chunk_end(step, sim.drops())
                if chunks_done % self.save_every == 0:
                    # snapshot-to-host is synchronous (and a true copy —
                    # the next chunk donates these buffers); disk write is
                    # backgrounded
                    self.ckpt.save_async(t0 + done, sim.state)
            except InjectedFailure as e:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise RestartBudgetExceeded(
                        f"{self.restarts - 1} restarts exhausted the budget "
                        f"of {self.max_restarts}") from e
                self.ckpt.wait()
                restored, t_saved = restore_latest(self.ckpt_dir, sim.state)
                if restored is None:
                    sim.state = _device_tree(initial)
                    done = 0
                    log.warning("restart %d/%d: no checkpoint yet, replaying "
                                "from t=%d", self.restarts, self.max_restarts,
                                t0)
                else:
                    sim.state = _device_tree(restored)
                    done = int(t_saved) - t0
                    log.warning("restart %d/%d: restored t=%d, replaying",
                                self.restarts, self.max_restarts,
                                int(t_saved))
        self.ckpt.wait()
        return fired, self.monitor.report(restarts=self.restarts)


# ---------------------------------------------------------------------------
# fault class 4: device loss — degraded-mode sharded runtime
# ---------------------------------------------------------------------------

class ElasticRunner:
    """ResilientRunner's crash recovery lifted onto the SHARDED path
    (`make_dist_run` over an HCU mesh), surviving device LOSS by remeshing.

        sim = Simulator(p, key=0)                       # H hypercolumns
        runner = ElasticRunner(sim, "ckpt", chunk_ticks=64,
                               fail_injector=lambda c: 2 if c == 3 else 0)
        fired, health = runner.run(ext)                 # loses 2 devices

    The run is cut into `chunk_ticks`-tick sharded scan calls with async
    checkpoints of the FULL logical state every `save_every` chunks.
    `fail_injector(chunk_index)` may return a truthy int `k` (raised as
    `DeviceLoss(k)`: the trailing k devices go away for good) or True (a
    plain `InjectedFailure`: crash, same mesh). Recovery in both cases:
    restore the newest verified checkpoint (`repro.checkpoint` — checksum
    fall-back included), rebuild the largest whole-HCU-divisible mesh over
    the survivors (`launch.mesh.make_elastic_mesh`), re-derive `h_local`
    and the `RouteConfig` for the new device count, re-lower the dist run
    (cached per device count), re-place state + connectivity via `remesh`,
    and replay from the restored tick.

    The replayed trajectory is BITWISE the uninterrupted one because the
    sharded tick is mesh-shape-invariant under the default
    `lossless_route_config` dimensioning: per-HCU RNG folds GLOBAL ids
    (`gid_base`), the exchange never drops (capacity covers the worst
    case), and padded route slots carry no trajectory-relevant bits —
    pinned by tests/test_elastic.py for 1/2/4 devices, both backends,
    restore-across-mesh-shape included. Passing a lossy `route_config`
    (e.g. `default_route_config`) trades that invariance for Fig 7-priced
    fabric drops — `HealthMonitor.set_mesh` keeps the budget honest at
    each new capacity.

    `rescale(chunk_index) -> int | None` additionally models GRACEFUL
    elasticity: a device-count target applied at the chunk boundary as pure
    data movement (remesh of the live state, no restore, no replay) —
    shrink onto fewer devices and regrow later, trajectory unchanged.

    Telemetry: `recoveries` records one dict per failure (kind, restored
    tick, surviving device count, recovery wall seconds) — the source of
    the BENCH_resilience.json device-loss scenario.
    """

    def __init__(self, sim, ckpt_dir: str, *, chunk_ticks: int = 64,
                 save_every: int = 1, keep_last: int = 3,
                 fail_injector=None, rescale=None, max_restarts: int = 8,
                 devices=None, axis: str = "hcu", route_config=None,
                 monitor: HealthMonitor | None = None):
        if sim.merged:
            raise NotImplementedError(
                "elastic runtime: merged mode has no sharded path "
                "(Simulator.run_sharded)")
        self.sim = sim
        self.ckpt = AsyncCheckpointer(ckpt_dir, keep_last=keep_last)
        self.ckpt_dir = ckpt_dir
        self.chunk_ticks = int(chunk_ticks)
        self.save_every = int(save_every)
        self.fail_injector = fail_injector
        self.rescale = rescale
        self.max_restarts = int(max_restarts)
        self.axis = axis
        self.devices = (list(devices) if devices is not None
                        else list(jax.devices()))
        self.route_config = route_config   # callable(p, h_local, ndev) -> rc
        self.monitor = monitor if monitor is not None else HealthMonitor(
            sim.p, n_hcu=sim.n_hcu)
        self.restarts = 0
        self.recoveries: list[dict] = []
        # connectivity is static: keep one host master, re-place per mesh
        self._conn_host = _host_copy(sim.conn)
        self._lowered: dict[int, tuple] = {}

    # -- mesh / lowering ----------------------------------------------------
    def _usable(self, limit: int | None = None) -> int:
        from repro.launch.mesh import elastic_device_count
        n = len(self.devices) if limit is None else min(len(self.devices),
                                                        int(limit))
        return elastic_device_count(self.sim.n_hcu, n)

    def _lower(self, ndev: int):
        """(mesh, rc, compiled run, state/conn specs) for `ndev` devices.

        Cached per device count: losses take the TRAILING devices, so the
        ndev-prefix mesh (and its compiled executable) stays valid across
        later shrinks."""
        if ndev not in self._lowered:
            from repro.core import distributed as DD
            from repro.launch.mesh import make_elastic_mesh
            sim = self.sim
            mesh = make_elastic_mesh(sim.n_hcu, self.devices[:ndev],
                                     self.axis)
            h_local = sim.n_hcu // ndev
            rc = (self.route_config(sim.p, h_local, ndev)
                  if self.route_config is not None
                  else DD.lossless_route_config(sim.p, h_local))
            fn = DD.make_dist_run(mesh, sim.p, rc, axis=self.axis,
                                  eager=sim.eager, backend=sim.kernel,
                                  worklist=sim.worklist, fused=sim.fused,
                                  fused_cols=sim.fused_cols)
            state_specs, conn_specs, _, _ = DD._shard_specs((self.axis,))
            self._lowered[ndev] = (mesh, rc, fn, state_specs, conn_specs)
        return self._lowered[ndev]

    def _place(self, host_state, ndev: int):
        """Remap all H hypercolumns onto the ndev-device mesh."""
        mesh, rc, fn, state_specs, conn_specs = self._lower(ndev)
        state = remesh(host_state, mesh, state_specs)
        conn = remesh(self._conn_host, mesh, conn_specs)
        self.monitor.set_mesh(ndev, rc)
        return state, conn, fn

    # -- driver -------------------------------------------------------------
    def run(self, ext, n_ticks: int | None = None):
        """Run `ext` (staged (T, H, A_ext) tensor, iterable of frames, or
        callable ext_fn(t) with `n_ticks`) to completion through crashes,
        device losses, and graceful rescales. Returns (fired history (T, H)
        int32, health report dict)."""
        sim = self.sim
        t0 = int(sim.state.t)
        if callable(ext) or not hasattr(ext, "ndim"):
            ext = N.stage_external(ext, n_ticks, t0=t0)
        ext = np.asarray(ext)
        if n_ticks is not None:
            ext = ext[:n_ticks]
        T = int(ext.shape[0])
        fired = np.full((T, sim.n_hcu), -1, np.int32)
        initial = _host_copy(sim.state)
        ndev = self._usable()
        state, conn, fn = self._place(sim.state, ndev)
        self.monitor.begin(N.drop_counters(state))
        done, chunks_done = 0, 0
        while done < T:
            step = min(self.chunk_ticks, T - done)
            chunk = done // self.chunk_ticks
            try:
                if self.rescale is not None:
                    want = self.rescale(chunk)
                    if want and self._usable(want) != ndev:
                        # graceful elasticity: pure data movement at a chunk
                        # boundary — no restore, no replay, bits unchanged
                        ndev = self._usable(want)
                        state, conn, fn = self._place(_host_copy(state),
                                                      ndev)
                        log.info("rescaled onto %d device(s) at tick %d",
                                 ndev, t0 + done)
                if self.fail_injector is not None:
                    lost = self.fail_injector(chunk)
                    if lost:
                        if lost is True:
                            raise InjectedFailure(
                                f"injected crash at tick {t0 + done}")
                        raise DeviceLoss(int(lost))
                self.monitor.chunk_start(step)
                state, f = fn(state, conn, ext[done:done + step])
                fired[done:done + step] = np.asarray(f)
                done += step
                chunks_done += 1
                self.monitor.chunk_end(step, N.drop_counters(state))
                if chunks_done % self.save_every == 0:
                    # full logical arrays — restorable onto ANY future mesh
                    self.ckpt.save_async(t0 + done, state)
            except InjectedFailure as e:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise RestartBudgetExceeded(
                        f"{self.restarts - 1} restarts exhausted the budget "
                        f"of {self.max_restarts}") from e
                rec_start = time.monotonic()
                if isinstance(e, DeviceLoss):
                    if e.n_lost >= len(self.devices):
                        raise RestartBudgetExceeded(
                            "all devices lost — nothing to remesh onto"
                        ) from e
                    del self.devices[len(self.devices) - e.n_lost:]
                self.ckpt.wait()
                restored, t_saved = restore_latest(self.ckpt_dir, initial)
                if restored is None:
                    host, done = initial, 0
                else:
                    host, done = restored, int(t_saved) - t0
                ndev = self._usable()
                state, conn, fn = self._place(host, ndev)
                rec = {"kind": ("device-loss" if isinstance(e, DeviceLoss)
                                else "crash"),
                       "restored_tick": t0 + done,
                       "devices": ndev,
                       "recovery_s": time.monotonic() - rec_start}
                self.recoveries.append(rec)
                log.warning("restart %d/%d (%s): restored t=%d onto %d "
                            "device(s) in %.3f s", self.restarts,
                            self.max_restarts, rec["kind"], t0 + done, ndev,
                            rec["recovery_s"])
        self.ckpt.wait()
        # hand the (sharded) final state back to the facade; its dist cache
        # is stale for this placement
        sim.state = state
        sim._dist_cache = None
        return fired, self.monitor.report(restarts=self.restarts)
