"""Elastic scaling, failure recovery, and straggler mitigation.

BCPNN makes elasticity unusually clean: every HCU's state is self-contained
("no memory consistency problem", paper §II.B), so re-scaling is pure data
movement — re-place the same logical arrays under a new mesh. The same holds
for LM training state (params/optimizer are logical arrays; GSPMD re-lowers
the step for the new mesh).

Components:
  remesh(tree, mesh, specs)   re-place a pytree onto a (new) mesh
  StragglerMonitor            per-step deadline tracking; slow-step log +
                              skip-budget accounting (BCPNN spikes are
                              droppable by design — the paper's queue-drop
                              budget, Fig 7, prices exactly this)
  InjectedFailure             the simulated-fault exception: everything the
                              restart machinery is allowed to swallow
  RestartableLoop             run steps with checkpoint/restore + simulated
                              failure injection, bounded by `max_restarts`

The BCPNN-specific resilience layer (crash-restore-replay over the tick
engine, DRAM-retention bit-flip injection, the drop-budget health monitor)
builds on these primitives in `repro.runtime.resilience`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.checkpoint import AsyncCheckpointer, restore_latest


def remesh(tree, mesh: Mesh, specs):
    """Re-place `tree` onto `mesh` using a congruent pytree of PartitionSpecs
    (or one spec broadcast to all leaves)."""
    if isinstance(specs, PartitionSpec):
        specs = jax.tree.map(lambda _: specs, tree)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs)


def remesh_network(state, conn, mesh: Mesh, axis="hcu"):
    """Re-place a sharded BCPNN network (state + connectivity) onto `mesh`.

    The whole elastic-rescale data plane in one call: HCU shards are
    self-contained (paper §II.B), so moving the network between mesh shapes
    is `remesh` with the canonical HCU shard specs and nothing else — no
    consistency protocol, no replay. Under `lossless_route_config` the
    trajectory is bitwise invariant to where the remesh lands
    (tests/test_elastic.py); `ElasticRunner` uses this for recovery and
    graceful rescale, and `benchmarks/weak_scaling.py` exercises it mid-run
    across the swept mesh shapes."""
    from repro.core.distributed import _shard_specs
    axes = axis if isinstance(axis, tuple) else (axis,)
    state_specs, conn_specs, _, _ = _shard_specs(axes)
    return remesh(state, mesh, state_specs), remesh(conn, mesh, conn_specs)


class InjectedFailure(RuntimeError):
    """A *simulated* node failure raised by a `fail_injector`.

    Dedicated type so the restart machinery can recover from injected faults
    while real errors — a genuine `RuntimeError` from XLA, a shape bug —
    propagate to the caller instead of being silently retried forever."""


class DeviceLoss(InjectedFailure):
    """A simulated loss of `n_lost` mesh devices (the paper's tile-failure
    class, §II: an HCU tile is self-contained, so losing one is survivable
    by re-placing its hypercolumns). Unlike a plain `InjectedFailure` —
    restore and replay on the SAME mesh — recovering from a DeviceLoss
    requires a remesh: the survivors get all H hypercolumns
    (`repro.runtime.resilience.ElasticRunner`). The loss is modeled as the
    trailing `n_lost` devices of the runner's device list going away."""

    def __init__(self, n_lost: int = 1, message: str | None = None):
        super().__init__(message or f"injected loss of {n_lost} device(s)")
        self.n_lost = int(n_lost)


class RestartBudgetExceeded(RuntimeError):
    """Raised when a restart loop exhausts its `max_restarts` budget —
    the "crash loop" guard a real scheduler applies before paging a human."""


@dataclasses.dataclass
class StragglerMonitor:
    """Deadline-based straggler accounting for a fixed-rate loop.

    In a real multi-host deployment each host reports step wall time; a step
    exceeding `deadline_s` is logged and (for droppable work like BCPNN spike
    delivery) may be skipped against a drop budget instead of stalling the
    collective — the paper's 1-spike-per-month budget generalized. Wall-clock
    totals (`total_s`, `worst_s`, `last_s`) feed the realtime-deadline half
    of `repro.runtime.resilience.HealthMonitor`.
    """
    deadline_s: float
    slow_steps: int = 0
    skipped: int = 0
    total: int = 0
    total_s: float = 0.0
    worst_s: float = 0.0
    last_s: float = 0.0
    _last: float = 0.0

    def start(self):
        self._last = time.monotonic()

    def finish(self) -> bool:
        """Returns True if the step met its deadline."""
        dt = time.monotonic() - self._last
        self.total += 1
        self.total_s += dt
        self.last_s = dt
        if dt > self.worst_s:
            self.worst_s = dt
        if dt > self.deadline_s:
            self.slow_steps += 1
            return False
        return True

    def skip(self):
        self.skipped += 1

    def summary(self):
        return {"total": self.total, "slow": self.slow_steps,
                "skipped": self.skipped, "total_s": self.total_s,
                "worst_s": self.worst_s}


class RestartableLoop:
    """Checkpointed step loop with bounded failure recovery.

    fail_injector(step) -> bool lets tests simulate node failures (raised as
    `InjectedFailure`); on an injected failure the loop restores the latest
    checkpoint and continues — exactly the restart path a real deployment
    takes after re-scheduling. Only `InjectedFailure` is recovered: a real
    exception out of `step_fn` propagates immediately (it would recur on
    replay anyway). `max_restarts` bounds the recovery budget — an
    always-failing step (e.g. a failure injected before the first checkpoint
    ever lands) raises `RestartBudgetExceeded` instead of spinning forever.
    """

    def __init__(self, ckpt_dir: str, save_every: int = 10,
                 fail_injector: Callable[[int], bool] | None = None,
                 max_restarts: int = 32):
        self.ckpt = AsyncCheckpointer(ckpt_dir)
        self.ckpt_dir = ckpt_dir
        self.save_every = save_every
        self.fail_injector = fail_injector
        self.max_restarts = max_restarts
        self.restarts = 0

    def run(self, state: Any, step_fn: Callable[[Any, int], Any],
            n_steps: int):
        # host snapshot of the entry state: a restart with no checkpoint on
        # disk must replay from HERE, not from the half-mutated live state
        # (np.array forces a real copy — on CPU jax, np.asarray can alias
        # the device buffer, which a later donation would invalidate)
        initial = jax.tree.map(np.array, state)
        step = 0
        while step < n_steps:
            try:
                if self.fail_injector and self.fail_injector(step):
                    raise InjectedFailure(f"injected failure at step {step}")
                state = step_fn(state, step)
                step += 1
                if step % self.save_every == 0:
                    self.ckpt.save_async(step, state)
            except InjectedFailure as e:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise RestartBudgetExceeded(
                        f"{self.restarts - 1} restarts exhausted the budget "
                        f"of {self.max_restarts}") from e
                self.ckpt.wait()
                restored, s = restore_latest(self.ckpt_dir, state)
                if restored is None:
                    # no checkpoint yet: restart from scratch
                    state = jax.tree.map(np.array, initial)
                    step = 0
                else:
                    state, step = restored, s
        self.ckpt.wait()
        return state, step
