"""Elastic scaling, failure recovery, and straggler mitigation.

BCPNN makes elasticity unusually clean: every HCU's state is self-contained
("no memory consistency problem", paper §II.B), so re-scaling is pure data
movement — re-place the same logical arrays under a new mesh. The same holds
for LM training state (params/optimizer are logical arrays; GSPMD re-lowers
the step for the new mesh).

Components:
  remesh(tree, mesh, specs)   re-place a pytree onto a (new) mesh
  StragglerMonitor            per-step deadline tracking; slow-step log +
                              skip-budget accounting (BCPNN spikes are
                              droppable by design — the paper's queue-drop
                              budget, Fig 7, prices exactly this)
  RestartableLoop             run steps with checkpoint/restore + simulated
                              failure injection (used by tests)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.checkpoint import AsyncCheckpointer, restore_latest


def remesh(tree, mesh: Mesh, specs):
    """Re-place `tree` onto `mesh` using a congruent pytree of PartitionSpecs
    (or one spec broadcast to all leaves)."""
    if isinstance(specs, PartitionSpec):
        specs = jax.tree.map(lambda _: specs, tree)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs)


@dataclasses.dataclass
class StragglerMonitor:
    """Deadline-based straggler accounting for a fixed-rate loop.

    In a real multi-host deployment each host reports step wall time; a step
    exceeding `deadline_s` is logged and (for droppable work like BCPNN spike
    delivery) may be skipped against a drop budget instead of stalling the
    collective — the paper's 1-spike-per-month budget generalized.
    """
    deadline_s: float
    slow_steps: int = 0
    skipped: int = 0
    total: int = 0
    _last: float = 0.0

    def start(self):
        self._last = time.monotonic()

    def finish(self) -> bool:
        """Returns True if the step met its deadline."""
        dt = time.monotonic() - self._last
        self.total += 1
        if dt > self.deadline_s:
            self.slow_steps += 1
            return False
        return True

    def skip(self):
        self.skipped += 1

    def summary(self):
        return {"total": self.total, "slow": self.slow_steps,
                "skipped": self.skipped}


class RestartableLoop:
    """Checkpointed step loop with failure recovery.

    fail_injector(step) -> bool lets tests simulate node failures; on
    failure the loop restores the latest checkpoint and continues, exactly
    the restart path a real deployment takes after re-scheduling.
    """

    def __init__(self, ckpt_dir: str, save_every: int = 10,
                 fail_injector: Callable[[int], bool] | None = None):
        self.ckpt = AsyncCheckpointer(ckpt_dir)
        self.ckpt_dir = ckpt_dir
        self.save_every = save_every
        self.fail_injector = fail_injector
        self.restarts = 0

    def run(self, state: Any, step_fn: Callable[[Any, int], Any],
            n_steps: int):
        step = 0
        while step < n_steps:
            try:
                if self.fail_injector and self.fail_injector(step):
                    raise RuntimeError(f"injected failure at step {step}")
                state = step_fn(state, step)
                step += 1
                if step % self.save_every == 0:
                    self.ckpt.save_async(step, state)
            except RuntimeError:
                self.ckpt.wait()
                restored, s = restore_latest(self.ckpt_dir, state)
                if restored is None:
                    step = 0          # no checkpoint yet: restart from scratch
                else:
                    state, step = restored, s
                self.restarts += 1
        self.ckpt.wait()
        return state, step
