from repro.runtime.elastic import (DeviceLoss, InjectedFailure,
                                   RestartableLoop, RestartBudgetExceeded,
                                   StragglerMonitor, remesh)
from repro.runtime.resilience import (ElasticRunner, HealthMonitor,
                                      ResilientRunner, flip_bits,
                                      inject_retention_faults)

__all__ = [
    "DeviceLoss", "ElasticRunner", "HealthMonitor", "InjectedFailure",
    "ResilientRunner", "RestartableLoop", "RestartBudgetExceeded",
    "StragglerMonitor", "flip_bits", "inject_retention_faults", "remesh",
]
