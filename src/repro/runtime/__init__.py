from repro.runtime.elastic import (InjectedFailure, RestartableLoop,
                                   RestartBudgetExceeded, StragglerMonitor,
                                   remesh)
from repro.runtime.resilience import (HealthMonitor, ResilientRunner,
                                      flip_bits, inject_retention_faults)

__all__ = [
    "HealthMonitor", "InjectedFailure", "ResilientRunner", "RestartableLoop",
    "RestartBudgetExceeded", "StragglerMonitor", "flip_bits",
    "inject_retention_faults", "remesh",
]
