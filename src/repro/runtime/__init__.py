from repro.runtime.elastic import RestartableLoop, StragglerMonitor, remesh

__all__ = ["RestartableLoop", "StragglerMonitor", "remesh"]
