from repro.runtime.elastic import (DeviceLoss, InjectedFailure,
                                   RestartableLoop, RestartBudgetExceeded,
                                   StragglerMonitor, remesh, remesh_network)
from repro.runtime.resilience import (ElasticRunner, HealthMonitor,
                                      ResilientRunner, ServingHealthMonitor,
                                      flip_bits, inject_retention_faults)

__all__ = [
    "DeviceLoss", "ElasticRunner", "HealthMonitor", "InjectedFailure",
    "ResilientRunner", "RestartableLoop", "RestartBudgetExceeded",
    "ServingHealthMonitor",
    "StragglerMonitor", "flip_bits", "inject_retention_faults", "remesh",
    "remesh_network",
]
