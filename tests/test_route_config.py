"""RouteConfig dimensioning + drops_route accounting for the sparse exchange.

Two tiers:

 * capacity math (in-process) — `default_route_config` sizes the per-pair
   route capacity the way the paper sizes its queues (§IV): the smallest
   Poisson-tail queue meeting the monthly drop budget, clamped into
   [8, cap_fire * fanout] (the worst case a device can physically emit);
 * drops_route accounting (subprocess, forced 2- and 4-device meshes) —
   when `cap_route` deliberately binds, overflow lands in the dedicated
   `drops_route` Fig 7 class, identically across the scan and host-loop
   sharded drivers and across the overlapped (split send/recv) vs
   sequential exchange.
"""
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(script: str) -> subprocess.CompletedProcess:
    return subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True,
                          env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                               "HOME": "/root", "JAX_PLATFORMS": "cpu"})


def test_default_route_config_poisson_bound():
    from repro.core.distributed import default_route_config
    from repro.core.params import BCPNNParams
    from repro.core.queues import expected_drops_per_month

    p = BCPNNParams()          # n_hcu=16, fanout=100, out_rate=0.1
    h_local, n_dev = 4, 4
    rc = default_route_config(p, h_local, n_dev=n_dev)
    assert rc.cap_route <= rc.cap_fire * p.fanout
    assert rc.cap_route >= 8
    lam = p.out_rate * h_local * p.fanout / n_dev
    # strictly inside the clamp window the capacity is the MINIMAL queue
    # meeting the <= 1 drop/month budget (paper Fig 7 discipline)
    assert 8 < rc.cap_route < rc.cap_fire * p.fanout
    assert expected_drops_per_month(rc.cap_route, lam) <= 1.0
    assert expected_drops_per_month(rc.cap_route - 1, lam) > 1.0


def test_default_route_config_clamps_and_monotonicity():
    from repro.core.distributed import default_route_config
    from repro.core.params import BCPNNParams

    p = BCPNNParams()
    # no mesh context -> worst case: a device's whole fired fanout to one peer
    rc = default_route_config(p, 4)
    assert rc.cap_route == rc.cap_fire * p.fanout
    # more devices at fixed HCUs/device -> thinner per-pair traffic -> the
    # capacity never grows
    caps = [default_route_config(p, 4, n_dev=n).cap_route
            for n in (1, 2, 4, 8)]
    assert caps == sorted(caps, reverse=True)
    # floor: even a near-silent pair keeps >= 8 slots
    tiny = BCPNNParams(n_hcu=64, out_rate=0.001)
    assert default_route_config(tiny, 1, n_dev=64).cap_route >= 8


def test_lossless_route_config_never_binds():
    from repro.core.distributed import lossless_route_config
    from repro.core.params import BCPNNParams

    p = BCPNNParams()
    for h_local in (1, 2, 4, 16):
        rc = lossless_route_config(p, h_local)
        assert rc.cap_fire == h_local
        # every fired HCU can route its entire fanout to ONE peer
        assert rc.cap_route == rc.cap_fire * p.fanout


ROUTE_DROPS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import *
    from repro.core import distributed as DD

    p = test_scale(n_hcu=8, rows=64, cols=16)
    key = jax.random.PRNGKey(0)
    conn = make_connectivity(p, jax.random.fold_in(key, 1))
    rng = np.random.default_rng(5)
    T = 24
    ext = np.empty((T, p.n_hcu, 8), np.int32)
    for t in range(T):                       # drive every HCU hard
        ext[t] = rng.integers(0, p.rows, (p.n_hcu, 8))
    ext = jnp.asarray(ext)

    for ndev in (2, 4):
        mesh = jax.make_mesh((ndev,), ("hcu",),
                             devices=jax.devices()[:ndev])
        h_local = p.n_hcu // ndev

        # lossless fabric: capacity never binds, drops_route stays 0
        s, c = DD.shard_network(mesh, init_network(p, key), conn)
        fn = DD.make_dist_run(mesh, p, DD.lossless_route_config(p, h_local))
        s, f = fn(s, c, ext)
        assert int(s.drops_route) == 0
        assert (np.asarray(f) >= 0).sum() > 0   # the drive actually fires

        # deliberately binding fabric: 1 message per (src, dst) pair per
        # tick, full fire cap -> overflow must land in drops_route
        rc = DD.RouteConfig(cap_fire=h_local, cap_route=1)
        s, c = DD.shard_network(mesh, init_network(p, key), conn)
        run = DD.make_dist_run(mesh, p, rc)
        sR, fR = run(s, c, ext)
        dropsR = int(sR.drops_route)
        assert dropsR > 0, f"ndev={ndev}: binding cap_route never dropped"

        # scan driver == host-loop driver, drop accounting included
        s, c = DD.shard_network(mesh, init_network(p, key), conn)
        tick = DD.make_dist_tick(mesh, p, rc)
        fs = []
        for t in range(T):
            s, ft = tick(s, c, ext[t])
            fs.append(np.asarray(ft))
        np.testing.assert_array_equal(np.stack(fs), np.asarray(fR))
        assert int(s.drops_route) == dropsR

        # overlapped (split send/recv) == sequential exchange, bitwise,
        # even while dropping
        s, c = DD.shard_network(mesh, init_network(p, key), conn)
        seq = DD.make_dist_run(mesh, p, rc, overlap=False)
        sS, fS = seq(s, c, ext)
        np.testing.assert_array_equal(np.asarray(fS), np.asarray(fR))
        assert int(sS.drops_route) == dropsR
        for name in sR.hcus._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(sR.hcus, name)),
                np.asarray(getattr(sS.hcus, name)),
                err_msg=f"ndev={ndev} plane {name}")
        print(f"ndev={ndev} drops_route={dropsR} OK")
    print("ROUTE_DROPS_OK")
""")


def test_drops_route_accounting_when_cap_binds():
    r = _run(ROUTE_DROPS_SCRIPT)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    assert "ROUTE_DROPS_OK" in r.stdout
