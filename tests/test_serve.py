"""LM ServingEngine (`repro.launch.serve`): wave slot recycling, per-slot
completion, and ragged (mixed prompt length) waves.

The load-bearing pin is the ragged one: a wave mixing prompt lengths must
emit, per request, exactly the greedy tokens the same request produces
alone in a slots=1 engine — the left-pad slots are masked out of the KV
cache (layers.attend pad path), not silently attended as prompt.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.serve import Request, ServingEngine
from repro.models.transformer import Model


@pytest.fixture(scope="module")
def lm():
    cfg = get_smoke_config("qwen2-1.5b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, n).astype(np.int32) for n in lens]


def test_queue_deeper_than_slots_drains_no_loss_no_dup(lm):
    cfg, model, params = lm
    eng = ServingEngine(model, params, batch_slots=3, max_len=32)
    prompts = _prompts(cfg, [8] * 7, seed=1)
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid, p, max_new=4))
    done = eng.run()
    assert sorted(r.rid for r in done) == list(range(7))
    assert not eng.queue
    assert all(r.done and len(r.out) == 4 for r in done)


def test_per_slot_max_new_truncation(lm):
    """One wave, mixed max_new: each request stops at ITS budget while the
    wave keeps decoding for the longest one."""
    cfg, model, params = lm
    eng = ServingEngine(model, params, batch_slots=3, max_len=32)
    prompts = _prompts(cfg, [6, 6, 6], seed=2)
    budgets = [1, 3, 7]
    for rid, (p, m) in enumerate(zip(prompts, budgets)):
        eng.submit(Request(rid, p, max_new=m))
    done = sorted(eng.run(), key=lambda r: r.rid)
    assert [len(r.out) for r in done] == budgets


def test_ragged_wave_matches_solo_runs(lm):
    """Mixed prompt lengths in ONE wave reproduce each request's solo
    (slots=1) greedy output — the fix this test pins."""
    cfg, model, params = lm
    lens = [6, 3, 9]
    solo = []
    for rid, p in enumerate(_prompts(cfg, lens, seed=3)):
        eng = ServingEngine(model, params, batch_slots=1, max_len=32)
        eng.submit(Request(rid, p, max_new=5))
        solo.append(eng.run()[0].out)
    eng = ServingEngine(model, params, batch_slots=3, max_len=32)
    assert eng.ragged
    for rid, p in enumerate(_prompts(cfg, lens, seed=3)):
        eng.submit(Request(rid, p, max_new=5))
    done = sorted(eng.run(), key=lambda r: r.rid)
    assert len(done) == 3 and len({r.rid for r in done}) == 3
    for r, want in zip(done, solo):
        assert r.out == want, f"request {r.rid} diverged in the ragged wave"


def test_non_attention_stack_groups_waves_by_length():
    """Recurrent mixers can't mask left-pad: the engine must group each
    wave by equal prompt length instead (and refuse a mixed wave)."""
    cfg = get_smoke_config("xlstm-125m")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, batch_slots=3, max_len=32)
    assert not eng.ragged
    prompts = _prompts(cfg, [4, 6, 4], seed=4)
    with pytest.raises(ValueError):
        eng._run_wave([Request(90 + i, p, max_new=2)
                       for i, p in enumerate(prompts)])
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid, p, max_new=2))
    done = eng.run()
    assert sorted(r.rid for r in done) == [0, 1, 2]
    assert all(len(r.out) == 2 for r in done)
