"""Row-Merge layout: bijection property + paper Fig 10 objective."""
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core.layout import (RowMergeLayout, best_tile,
                               dram_row_misses_per_s, paper_fig10_table,
                               tile_bytes_touched_per_s)


def test_fig10_minimum_at_x_10():
    """Paper Fig 10: X=10 minimizes DRAM row misses, ~5x better than X=1."""
    table = paper_fig10_table()
    best_x = min(table, key=table.get)
    assert best_x == 10
    assert table[1] / table[10] >= 4.5   # "5 times less compared to direct"


def test_fig10_closed_form_values():
    # rowmiss(X) = 10000 * (X + 100/X) * 2
    assert dram_row_misses_per_s(1) == 10000 * 101 * 2
    assert dram_row_misses_per_s(10) == 10000 * 20 * 2
    assert dram_row_misses_per_s(100) == 10000 * 101 * 2


@settings(max_examples=40, deadline=None)
@given(r=st.integers(1, 300), c=st.integers(1, 200), seed=st.integers(0, 999))
def test_pack_unpack_bijection(r, c, seed):
    lay = RowMergeLayout(rows=r, cols=c, xr=8, xc=128)
    rng = np.random.default_rng(seed)
    plane = jnp.asarray(rng.normal(size=(r, c)), jnp.float32)
    np.testing.assert_array_equal(lay.unpack(lay.pack(plane)), plane)


def test_tiled_shape_is_tpu_aligned():
    lay = RowMergeLayout(rows=10_000, cols=100)
    t = lay.pack(jnp.zeros((10_000, 100), jnp.float32))
    assert t.shape == (1250, 1, 8, 128)
    assert t.shape[-1] % 128 == 0 and t.shape[-2] % 8 == 0


def test_tpu_tile_objective_prefers_balanced_tiles():
    """With BCPNN's 100:1 row:column access ratio the objective must punish
    huge row-tiles (column reads explode) and huge col-tiles alike —
    the same trade-off as the paper's X sweep."""
    R, C, rr, cr = 10_000, 100, 10_000.0, 100.0
    best, scored = best_tile(R, C, rr, cr)
    # degenerate huge tiles must lose to the (8..32, 128) family
    assert scored[best] <= scored[(256, 128)]
    assert scored[best] <= scored[(8, 512)]
    # and the model reproduces the paper's asymmetry: row cost ~ flat in xr,
    # column cost shrinks with xr
    a = tile_bytes_touched_per_s(8, 128, R, C, rr, cr)
    b = tile_bytes_touched_per_s(64, 128, R, C, rr, cr)
    col_a = 2 * 8 * 128 * 20 * cr * (-(-R // 8))
    col_b = 2 * 64 * 128 * 20 * cr * (-(-R // 64))
    assert abs(col_a - col_b) / col_a < 0.01  # same column bytes (mod ceil)...
    assert b > a                               # ...but row cost grows with xr
