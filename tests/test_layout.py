"""Row-Merge layout: bijection property + paper Fig 10 objective + the
pluggable PlaneLayout storage abstraction (FlatLayout/BlockedLayout)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core.layout import (BlockedLayout, FlatLayout, RowMergeLayout,
                               as_blocked, best_tile,
                               cache_lines_touched_per_s, cpu_blocked,
                               dram_row_misses_per_s, layout_from_tag,
                               layout_tag, paper_fig10_table, resolve_layout,
                               tile_bytes_touched_per_s, tpu_blocked)
from repro.core.params import BCPNNParams


def test_fig10_minimum_at_x_10():
    """Paper Fig 10: X=10 minimizes DRAM row misses, 5x better than X=1
    (their "5 times less compared to direct" claim — the exact ratio at the
    paper's rates is (1+100)/(10+10) = 5.05)."""
    table = paper_fig10_table()
    best_x = min(table, key=table.get)
    assert best_x == 10
    assert table[1] / table[10] >= 5.0
    np.testing.assert_allclose(table[1] / table[10], 5.05)


def test_fig10_closed_form_values():
    # rowmiss(X) = 10000 * (X + 100/X) * 2
    assert dram_row_misses_per_s(1) == 10000 * 101 * 2
    assert dram_row_misses_per_s(10) == 10000 * 20 * 2
    assert dram_row_misses_per_s(100) == 10000 * 101 * 2


@settings(max_examples=40, deadline=None)
@given(r=st.integers(1, 300), c=st.integers(1, 200), seed=st.integers(0, 999))
def test_pack_unpack_bijection(r, c, seed):
    lay = RowMergeLayout(rows=r, cols=c, xr=8, xc=128)
    rng = np.random.default_rng(seed)
    plane = jnp.asarray(rng.normal(size=(r, c)), jnp.float32)
    np.testing.assert_array_equal(lay.unpack(lay.pack(plane)), plane)


def test_tiled_shape_is_tpu_aligned():
    lay = RowMergeLayout(rows=10_000, cols=100)
    t = lay.pack(jnp.zeros((10_000, 100), jnp.float32))
    assert t.shape == (1250, 1, 8, 128)
    assert t.shape[-1] % 128 == 0 and t.shape[-2] % 8 == 0


def test_tpu_tile_objective_prefers_balanced_tiles():
    """With BCPNN's 100:1 row:column access ratio the objective must punish
    huge row-tiles (column reads explode) and huge col-tiles alike —
    the same trade-off as the paper's X sweep."""
    R, C, rr, cr = 10_000, 100, 10_000.0, 100.0
    best, scored = best_tile(R, C, rr, cr)
    # degenerate huge tiles must lose to the (8..32, 128) family
    assert scored[best] <= scored[(256, 128)]
    assert scored[best] <= scored[(8, 512)]
    # and the model reproduces the paper's asymmetry: row cost ~ flat in xr,
    # column cost shrinks with xr
    a = tile_bytes_touched_per_s(8, 128, R, C, rr, cr)
    b = tile_bytes_touched_per_s(64, 128, R, C, rr, cr)
    col_a = 2 * 8 * 128 * 20 * cr * (-(-R // 8))
    col_b = 2 * 64 * 128 * 20 * cr * (-(-R // 64))
    assert abs(col_a - col_b) / col_a < 0.01  # same column bytes (mod ceil)...
    assert b > a                               # ...but row cost grows with xr

# ---------------------------------------------------------------- PlaneLayout

def _plane(h, r, c, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(h * r, c)), jnp.float32)


def test_blocked_store_load_roundtrip_divisible():
    lay = BlockedLayout(rows=64, cols=16, xr=8, xc=4)
    f = _plane(3, 64, 16)
    t = lay.store(f)
    assert t.shape == lay.plane_shape(3)
    np.testing.assert_array_equal(lay.load(t), f)


def test_blocked_store_load_roundtrip_non_divisible():
    # R=10 not divisible by xr=4, C=6 not divisible by xc=4: pad cells exist
    lay = BlockedLayout(rows=10, cols=6, xr=4, xc=4)
    f = _plane(3, 10, 6, seed=1)
    t = lay.store(f)
    assert t.shape == (3 * lay.row_tiles_n, lay.col_tiles_n, 4, 4)
    np.testing.assert_array_equal(lay.load(t), f)


def test_blocked_store_matches_rowmerge_pack_per_hcu():
    """Network-wide blocked storage == per-HCU RowMergeLayout.pack stacked:
    the engine path and the standalone Fig 9 reference are the same layout."""
    R, C, xr, xc = 10, 6, 4, 4
    lay = BlockedLayout(rows=R, cols=C, xr=xr, xc=xc)
    rm = RowMergeLayout(rows=R, cols=C, xr=xr, xc=xc)
    f = _plane(3, R, C, seed=2)
    t = lay.store(f)
    per_hcu = jnp.concatenate(
        [rm.pack(f[h * R:(h + 1) * R]) for h in range(3)], axis=0)
    np.testing.assert_array_equal(t, per_hcu)


def test_rowmerge_tile_coords():
    """row_tiles/col_tiles enumerate the tiles a logical row/column crosses
    — the paper's Fig 9 access pattern (a row touches one tile-row, a
    column touches every tile-row in one tile-column)."""
    lay = RowMergeLayout(rows=10, cols=6, xr=4, xc=4)
    tr, tcs = lay.row_tiles(9)
    assert tr == 2
    np.testing.assert_array_equal(tcs, [0, 1])
    trs, tc = lay.col_tiles(5)
    assert tc == 1
    np.testing.assert_array_equal(trs, [0, 1, 2])
    # the addressed cell in the packed tensor is the flat cell
    f = _plane(1, 10, 6, seed=3)
    t = lay.pack(f)
    assert t[9 // 4, 5 // 4, 9 % 4, 5 % 4] == f[9, 5]


def test_blocked_accessors_match_flat():
    """read_row/read_col/write_row/write_col/add_cell agree with FlatLayout
    on the canonical plane, for a non-divisible tile."""
    H, R, C = 3, 10, 6
    lay = BlockedLayout(rows=R, cols=C, xr=4, xc=4)
    flat = FlatLayout(rows=R)
    f = _plane(H, R, C, seed=4)
    t = lay.store(f)
    for h, r, j in [(0, 0, 0), (1, 3, 5), (2, 9, 2)]:
        g = h * R + r
        np.testing.assert_array_equal(
            lay.read_row(t, g)[0], flat.read_row(f, g)[0])
        np.testing.assert_array_equal(
            lay.read_col(t, h, j), flat.read_col(f, h, j))
    # writes: apply the same edits through both layouts, compare planes
    row_val = jnp.arange(C, dtype=jnp.float32).reshape(1, C)
    col_val = jnp.arange(R, dtype=jnp.float32).reshape(1, R)
    t2 = lay.write_row(t, 1 * R + 3, row_val)
    f2 = flat.write_row(f, 1 * R + 3, row_val)
    t2 = lay.write_col(t2, 2, 5, col_val)
    f2 = flat.write_col(f2, 2, 5, col_val)
    t2 = lay.add_cell(t2, 0, 9, 1, 2.5)
    f2 = flat.add_cell(f2, 0, 9, 1, 2.5)
    np.testing.assert_array_equal(lay.load(t2), f2)


def test_blocked_degenerate_flat_view():
    """TPU degenerate tiles (Tc == 1): flat_view is a pure reshape to the
    row-padded flat plane and pad_row_index remaps global row ids."""
    lay = BlockedLayout(rows=10, cols=6, xr=4, xc=8)
    assert lay.tpu_degenerate
    f = _plane(2, 10, 6, seed=5)
    t = lay.store(f)
    v = lay.flat_view(t)
    assert v.shape == (2 * lay.padded_rows, lay.padded_cols)
    np.testing.assert_array_equal(v[:10, :6], f[:10])
    np.testing.assert_array_equal(lay.load(lay.from_flat_view(v)), f)
    # g -> (g // R) * Pr + g % R ; sentinel n*R -> n*Pr
    g = jnp.asarray([0, 9, 10, 19, 20], jnp.int32)
    np.testing.assert_array_equal(
        lay.pad_row_index(g, 2), jnp.asarray([0, 9, 12, 21, 24]))
    iv = jnp.arange(20, dtype=jnp.float32)
    np.testing.assert_array_equal(lay.unpad_ivec(lay.pad_ivec(iv, 2), 2), iv)


def test_cache_lines_model_prefers_narrow_tiles_for_bcpnn():
    """CPU cache-line objective: with BCPNN's row-heavy access mix a
    (8, 4) tile beats both flat rows (1, C) and a TPU (8, 128) tile."""
    R, C, rr, cr = 10_000, 100, 10_000.0, 100.0
    flat = cache_lines_touched_per_s(1, C, R, C, rr, cr)
    cpu = cache_lines_touched_per_s(8, 4, R, C, rr, cr)
    tpu = cache_lines_touched_per_s(8, 128, R, C, rr, cr)
    assert cpu < flat
    assert cpu < tpu


def test_layout_tag_roundtrip_and_resolve():
    p = BCPNNParams(n_hcu=2, rows=10, cols=6, fanout=2, active_queue=4,
                    max_delay=4)
    assert layout_tag(None) == "flat"
    assert layout_from_tag("flat", p) is None
    lay = cpu_blocked(p)
    assert layout_from_tag(layout_tag(lay), p) == lay
    tpu = tpu_blocked(p)
    assert tpu.tpu_degenerate and (tpu.xr, tpu.xc) == (8, 128)
    assert resolve_layout("blocked", p) == lay
    assert resolve_layout("blocked_tpu", p) == tpu
    assert resolve_layout(None, p) is None
    assert resolve_layout(lay, p) == lay
    assert as_blocked(lay) is lay and as_blocked(None) is None
