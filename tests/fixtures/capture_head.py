"""Capture reference trajectories from the CURRENT runtime into .npz fixtures.

Run from the repo root:

    PYTHONPATH=src:tests python tests/fixtures/capture_head.py

The engine-refactor bitwise-identity tests (tests/test_engine_fixtures.py)
compare the live runtime against these files, so the fixtures pin the
trajectory of the runtime AT THE COMMIT THEY WERE CAPTURED FROM. Regenerate
them ONLY when a PR intentionally changes trajectories (and say so in the PR):
the whole point of the TickEngine refactor contract is that trajectories do
NOT change.

Fixtures store, per mode: the staged external input, the connectivity arrays,
the fired history, and every NetworkState leaf (ij-planes reshaped to the
canonical flat (H*R, C) layout so comparisons are layout-independent).

Note: trajectories are bitwise-reproducible on a given machine/jax build;
libm/codegen differences across machines can drift transcendentals by 1 ulp.
If test_engine_fixtures fails on a *fresh* machine with tiny max-ulp diffs,
regenerate the fixtures there and diff against git to confirm magnitude.
"""
from __future__ import annotations

import os
import pathlib
import subprocess
import sys
import textwrap

import numpy as np

HERE = pathlib.Path(__file__).resolve().parent
SRC = str(HERE.parents[1] / "src")
sys.path.insert(0, SRC)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import (init_network, make_connectivity, network_run,  # noqa: E402
                        run)
from repro.core.params import BCPNNParams, test_scale  # noqa: E402

# Must match tests/test_engine_fixtures.py exactly.
LAZY_P = test_scale(n_hcu=4, rows=64, cols=16)
MERGED_P = BCPNNParams(n_hcu=4, rows=24, cols=16, fanout=4, active_queue=8,
                       max_delay=8, out_rate=0.6)


def ext_tensor(p, seed, n_ticks, width=8, lam=3.0):
    rng = np.random.default_rng(seed)
    out = np.full((n_ticks, p.n_hcu, width), p.rows, np.int32)
    for t in range(n_ticks):
        for h in range(p.n_hcu):
            n = min(width, rng.poisson(lam))
            out[t, h, :n] = rng.integers(0, p.rows, n)
    return out


def flat2(x):
    """(H, R, C) -> (H*R, C) / (H, R) -> (H*R,) canonical flat layout."""
    a = np.asarray(x)
    if a.ndim == 3:
        return a.reshape(a.shape[0] * a.shape[1], a.shape[2])
    return a


def state_arrays(state, p):
    out = {}
    for name in state.hcus._fields:
        leaf = np.asarray(getattr(state.hcus, name))
        if name in ("zij", "eij", "pij", "wij", "tij"):
            leaf = leaf.reshape(p.n_hcu * p.rows, p.cols)
        elif name in ("zi", "ei", "pi", "ti"):
            leaf = leaf.reshape(p.n_hcu * p.rows)
        out[f"hcus_{name}"] = leaf
    out["delay_rows"] = np.asarray(state.delay_rows)
    out["delay_count"] = np.asarray(state.delay_count)
    out["t"] = np.asarray(state.t)
    out["drops_in"] = np.asarray(state.drops_in)
    out["drops_fire"] = np.asarray(state.drops_fire)
    if state.jring is not None:
        out["jring"] = np.asarray(state.jring)
    return out


def capture_local(name, p, *, merged=False, eager=False, worklist=None,
                  seed, n_ticks, lam, chunk, cap_fire=None, host=False):
    key = jax.random.PRNGKey(0)
    conn = make_connectivity(p, jax.random.fold_in(key, 1))
    ext = ext_tensor(p, seed, n_ticks, lam=lam)
    state = init_network(p, key, merged=merged)
    kw = dict(eager=eager, merged=merged, worklist=worklist,
              cap_fire=cap_fire)
    if host:
        ext_j = jnp.asarray(ext)
        state, fired = run(state, conn, lambda t: ext_j[t - 1], n_ticks, p,
                           **kw)
    else:
        state, fired = network_run(state, conn, jnp.asarray(ext), p,
                                   chunk=chunk, **kw)
    data = state_arrays(state, p)
    data.update(ext=ext, fired=np.asarray(fired),
                conn_dest_hcu=np.asarray(conn.dest_hcu),
                conn_dest_row=np.asarray(conn.dest_row),
                conn_delay=np.asarray(conn.delay))
    np.savez_compressed(HERE / f"head_{name}.npz", **data)
    print(f"captured {name}: {int((np.asarray(fired) >= 0).sum())} spikes, "
          f"t={int(state.t)}")


SHARDED_SCRIPT = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    sys.path.insert(0, {src!r})
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import init_network, make_connectivity, test_scale
    from repro.core import distributed as DD
    sys.path.insert(0, {fixtures!r})
    from capture_head import ext_tensor, state_arrays

    p = test_scale(n_hcu=8, rows=64, cols=16)
    key = jax.random.PRNGKey(0)
    conn = make_connectivity(p, jax.random.fold_in(key, 1))
    mesh = jax.make_mesh((4,), ("hcu",))
    rc = DD.default_route_config(p, 2)
    ext = ext_tensor(p, seed=7, n_ticks=25, lam=3.0)
    for wl in (False, True):
        s0, c0 = DD.shard_network(mesh, init_network(p, key), conn)
        fn = DD.make_dist_run(mesh, p, rc, axis="hcu", worklist=wl)
        s1, f1 = fn(s0, c0, jnp.asarray(ext))
        data = state_arrays(s1, p)
        data.update(ext=ext, fired=np.asarray(f1),
                    conn_dest_hcu=np.asarray(conn.dest_hcu),
                    conn_dest_row=np.asarray(conn.dest_row),
                    conn_delay=np.asarray(conn.delay))
        name = "sharded_worklist" if wl else "sharded_dense"
        np.savez_compressed(os.path.join({fixtures!r}, f"head_{{name}}.npz"),
                            **data)
        print(f"captured {{name}}: {{int((np.asarray(f1) >= 0).sum())}} spikes")
""")


def main():
    capture_local("lazy_dense", LAZY_P, worklist=False, seed=11, n_ticks=40,
                  lam=3.0, chunk=13)
    capture_local("lazy_worklist", LAZY_P, worklist=True, seed=11, n_ticks=40,
                  lam=3.0, chunk=13)
    capture_local("eager", LAZY_P, eager=True, seed=11, n_ticks=40, lam=3.0,
                  chunk=13)
    capture_local("merged_dense", MERGED_P, merged=True, worklist=False,
                  seed=7, n_ticks=60, lam=5.0, chunk=13,
                  cap_fire=MERGED_P.n_hcu)
    capture_local("merged_worklist", MERGED_P, merged=True, worklist=True,
                  seed=7, n_ticks=60, lam=5.0, chunk=13,
                  cap_fire=MERGED_P.n_hcu)
    capture_local("host_lazy", LAZY_P, worklist=False, seed=11, n_ticks=20,
                  lam=3.0, chunk=0, host=True)
    script = SHARDED_SCRIPT.format(src=SRC, fixtures=str(HERE))
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": SRC})
    print(r.stdout)
    if r.returncode != 0:
        sys.exit("sharded capture failed:\n" + r.stderr[-3000:])


if __name__ == "__main__":
    main()
