"""Worklist tick runtime vs the per-HCU vmap path — bitwise identity.

The flat-plane worklist runtime (core/worklist.py + the worklist branches in
core/network.py) is a memory-traffic refactor, not a semantics change: with
`worklist=True` forced on small sizes, every trajectory — fired history,
all state planes, queues, rings — must be bit-for-bit identical to the
per-HCU vmapped path, in lazy, merged and sharded modes, across random
spike patterns, duplicate rows, queue-overflow ticks and empty ticks.

The worklist path achieves this by construction: it stages touched rows
into buffers with in-place dynamic-slice loops and then runs the *same
vmapped compute graph* (same shapes, same broadcasts, same code objects)
as the per-HCU path — XLA:CPU's fused codegen is context-sensitive at the
1-ulp level, so these tests are the guard that the shared-graph discipline
holds.
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import (init_network, make_connectivity, network_run,
                        test_scale as tiny_scale)
from repro.core import hcu as H
from repro.core import worklist as WL
from repro.core.params import BCPNNParams

SRC = str(Path(__file__).resolve().parents[1] / "src")

# two fixed dimensionings so jit caches are reused across cases/examples
LAZY_P = tiny_scale(n_hcu=4, rows=64, cols=16)
HOT_P = BCPNNParams(n_hcu=6, rows=48, cols=12, fanout=12, active_queue=6,
                    max_delay=6, out_rate=0.5)      # queue-overflow regime
MERGED_P = BCPNNParams(n_hcu=4, rows=24, cols=16, fanout=4, active_queue=8,
                       max_delay=8, out_rate=0.6)   # ring-overflow regime


def _ext_tensor(p, seed, n_ticks, width=8, lam=3.0, duplicates=False):
    """Random staged input; lam=0 gives all-empty ticks; duplicates=True
    forces repeated row indices within a tick's slot array."""
    rng = np.random.default_rng(seed)
    out = np.full((n_ticks, p.n_hcu, width), p.rows, np.int32)
    for t in range(n_ticks):
        for h in range(p.n_hcu):
            n = min(width, rng.poisson(lam))
            rows = rng.integers(0, p.rows, n)
            if duplicates and n >= 2:
                rows[1] = rows[0]
            out[t, h, :n] = rows
    return jnp.asarray(out)


def _run_both(p, ext, merged=False, chunk=16, key_seed=0, fused=None,
              fused_cols=None):
    key = jax.random.PRNGKey(key_seed)
    conn = make_connectivity(p, jax.random.fold_in(key, 1))
    kw = dict(merged=merged, chunk=chunk,
              cap_fire=p.n_hcu if merged else None)
    sa, fa = network_run(init_network(p, key, merged=merged), conn, ext, p,
                         worklist=False, **kw)
    sb, fb = network_run(init_network(p, key, merged=merged), conn, ext, p,
                         worklist=True, fused=fused, fused_cols=fused_cols,
                         **kw)
    return sa, fa, sb, fb


def _assert_bitwise(sa, fa, sb, fb, merged=False):
    np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))
    for name in sa.hcus._fields:
        a, b = np.asarray(getattr(sa.hcus, name)), \
            np.asarray(getattr(sb.hcus, name))
        np.testing.assert_array_equal(a, b, err_msg=f"plane {name}")
    np.testing.assert_array_equal(np.asarray(sa.delay_rows),
                                  np.asarray(sb.delay_rows))
    np.testing.assert_array_equal(np.asarray(sa.delay_count),
                                  np.asarray(sb.delay_count))
    assert int(sa.drops_in) == int(sb.drops_in)
    assert int(sa.drops_fire) == int(sb.drops_fire)
    if merged:
        np.testing.assert_array_equal(np.asarray(sa.jring),
                                      np.asarray(sb.jring))


@pytest.mark.parametrize("case", ["random", "duplicates", "empty"])
def test_lazy_worklist_bitwise(case):
    lam = {"random": 3.0, "duplicates": 4.0, "empty": 0.0}[case]
    ext = _ext_tensor(LAZY_P, seed=11, n_ticks=40, lam=lam,
                      duplicates=(case == "duplicates"))
    sa, fa, sb, fb = _run_both(LAZY_P, ext)
    if case != "empty":
        assert (np.asarray(fa) >= 0).sum() > 0, "must exercise output spikes"
    _assert_bitwise(sa, fa, sb, fb)


def test_lazy_worklist_bitwise_under_queue_overflow():
    """High rate + tight queues: delay-queue and fired-batch drops occur and
    must be counted identically (the worklist never drops row updates —
    cap_total covers every slot)."""
    ext = _ext_tensor(HOT_P, seed=5, n_ticks=60, lam=6.0)
    sa, fa, sb, fb = _run_both(HOT_P, ext, chunk=60)
    assert int(sa.drops_in) > 0 and int(sa.drops_fire) > 0, \
        "case must exercise queue overflow"
    _assert_bitwise(sa, fa, sb, fb)


@pytest.mark.parametrize("case", ["random", "empty"])
def test_merged_worklist_bitwise(case):
    """Merged mode: ring pushes, overflow flushes and same-tick patches all
    ride the worklist; jring must match bit-for-bit too."""
    lam = {"random": 6.0, "empty": 0.0}[case]
    ext = _ext_tensor(MERGED_P, seed=7, n_ticks=80, lam=lam)
    sa, fa, sb, fb = _run_both(MERGED_P, ext, merged=True, chunk=11)
    if case == "random":
        assert (np.asarray(fa) >= 0).sum() > MERGED_P.n_hcu * 8, \
            "case must exercise ring overflow (fires > H * RING_DEPTH)"
    _assert_bitwise(sa, fa, sb, fb, merged=True)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), lam=st.sampled_from([0.0, 2.0, 6.0]),
       merged=st.booleans())
def test_worklist_bitwise_property(seed, lam, merged):
    """Property form: any spike pattern, any regime, both modes."""
    p = MERGED_P if merged else LAZY_P
    ext = _ext_tensor(p, seed=seed, n_ticks=24, lam=lam,
                      duplicates=bool(seed % 2))
    sa, fa, sb, fb = _run_both(p, ext, merged=merged, chunk=24,
                               key_seed=seed % 7)
    _assert_bitwise(sa, fa, sb, fb, merged=merged)


def test_sharded_worklist_bitwise():
    """make_dist_run(worklist=True) == make_dist_run(worklist=False), planes
    and fired history, over 4 host devices (subprocess: device count must be
    set before jax initializes)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import *
        from repro.core import distributed as DD

        p = test_scale(n_hcu=8, rows=64, cols=16)
        key = jax.random.PRNGKey(0)
        conn = make_connectivity(p, jax.random.fold_in(key, 1))
        mesh = jax.make_mesh((4,), ("hcu",))
        rc = DD.default_route_config(p, 2)
        rng = np.random.default_rng(7)
        ext = np.full((25, p.n_hcu, 8), p.rows, np.int32)
        for t in range(25):
            for h in range(p.n_hcu):
                n = min(8, rng.poisson(3))
                ext[t, h, :n] = rng.integers(0, p.rows, n)
        ext = jnp.asarray(ext)
        outs = {}
        for wl in (False, True):
            s0, c0 = DD.shard_network(mesh, init_network(p, key), conn)
            fn = DD.make_dist_run(mesh, p, rc, axis="hcu", worklist=wl)
            s1, f1 = fn(s0, c0, ext)
            outs[wl] = (s1, np.asarray(f1))
        np.testing.assert_array_equal(outs[False][1], outs[True][1])
        assert (outs[False][1] >= 0).sum() > 0
        for name in outs[False][0].hcus._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(outs[False][0].hcus, name)),
                np.asarray(getattr(outs[True][0].hcus, name)), err_msg=name)
        print("SHARDED-WORKLIST-OK")
    """)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env={**__import__("os").environ,
                                       "PYTHONPATH": SRC})
    assert "SHARDED-WORKLIST-OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.parametrize("fused", [False, True])
def test_lazy_worklist_fused_vs_staged_bitwise(fused):
    """The fused single-pass row phase (`fused=True`, the default) and the
    three-phase staged form (`fused=False`) must both match the dense path
    bit-for-bit — the fused loop inlines the SAME (1, C) cell formulas the
    vmapped compute runs, and the lazy island is small enough that XLA:CPU
    compiles it identically in both contexts (docs/NUMERICS.md)."""
    ext = _ext_tensor(LAZY_P, seed=23, n_ticks=40, lam=3.0)
    sa, fa, sb, fb = _run_both(LAZY_P, ext, fused=fused)
    assert (np.asarray(fa) >= 0).sum() > 0
    _assert_bitwise(sa, fa, sb, fb)


def test_lazy_fused_bitwise_at_rodent_dimensioning():
    """Pin the fused/staged identity AT A SHAPE WHERE FUSED ACTUALLY RUNS
    BY DEFAULT: R=1200, C=70 (rodent dimensioning, R*C > DENSE_CELLS_MAX so
    `use_worklist` holds without an override). The numerics doctrine
    (docs/NUMERICS.md) is that codegen identity across compilation contexts
    is shape-dependent and must be empirically pinned — the toy-size A/Bs
    above do not cover the large-shape compilations a jax/XLA upgrade could
    change."""
    p = BCPNNParams(n_hcu=2, rows=1200, cols=70, fanout=2, active_queue=8,
                    max_delay=8)
    assert H.use_worklist(p), "must exercise the default-on regime"
    ext = _ext_tensor(p, seed=13, n_ticks=8, lam=4.0)
    key = jax.random.PRNGKey(0)
    conn = make_connectivity(p, jax.random.fold_in(key, 1))
    sa, fa = network_run(init_network(p, key), conn, ext, p, chunk=8,
                         fused=False)
    sb, fb = network_run(init_network(p, key), conn, ext, p, chunk=8,
                         fused=True)
    _assert_bitwise(sa, fa, sb, fb)


def test_lazy_worklist_fused_under_queue_overflow():
    ext = _ext_tensor(HOT_P, seed=5, n_ticks=60, lam=6.0)
    sa, fa, sb, fb = _run_both(HOT_P, ext, chunk=60, fused=True)
    assert int(sa.drops_in) > 0 and int(sa.drops_fire) > 0
    _assert_bitwise(sa, fa, sb, fb)


def test_pallas_interpret_fused_megakernel_matches_vmap_path():
    """The fused scalar-prefetch megakernel (`ops.fused_row_update`,
    interpret mode) must reproduce the vmapped pallas-interpret path exactly
    — ij planes, i-vectors (rewritten in place by the kernel) and weight
    planes alike."""
    ext = _ext_tensor(LAZY_P, seed=3, n_ticks=12, lam=3.0)
    key = jax.random.PRNGKey(0)
    conn = make_connectivity(LAZY_P, jax.random.fold_in(key, 1))
    sa, fa = network_run(init_network(LAZY_P, key), conn, ext, LAZY_P,
                         chunk=12, worklist=False, backend="pallas_interpret")
    sb, fb = network_run(init_network(LAZY_P, key), conn, ext, LAZY_P,
                         chunk=12, worklist=True, fused=True,
                         backend="pallas_interpret")
    _assert_bitwise(sa, fa, sb, fb)


@pytest.mark.parametrize("xr", [8, 7])
def test_pallas_interpret_blocked_layout_matches_flat(xr):
    """The TPU story for the blocked layout: at a degenerate (Tc == 1)
    tile the stored plane is a pure reshape of the row-padded flat plane
    (`BlockedLayout.flat_view`), so the scalar-prefetch megakernels run
    unmodified — only the row-index stream is remapped. The blocked
    pallas-interpret trajectory must equal the flat one bitwise; xr=7
    forces row padding (junk rows + sentinel remap)."""
    from repro.core import layout as L
    ext = _ext_tensor(LAZY_P, seed=3, n_ticks=12, lam=3.0)
    key = jax.random.PRNGKey(0)
    conn = make_connectivity(LAZY_P, jax.random.fold_in(key, 1))
    lay = L.BlockedLayout(rows=LAZY_P.rows, cols=LAZY_P.cols, xr=xr, xc=128)
    assert lay.tpu_degenerate
    assert (lay.padded_rows > LAZY_P.rows) == (xr == 7)
    sa, fa = network_run(init_network(LAZY_P, key), conn, ext, LAZY_P,
                         chunk=12, worklist=True, fused=True,
                         backend="pallas_interpret")
    sb, fb = network_run(init_network(LAZY_P, key, layout=lay), conn, ext,
                         LAZY_P, chunk=12, worklist=True, fused=True,
                         backend="pallas_interpret", layout=lay)
    sb = sb._replace(hcus=L.load_hcus(sb.hcus, lay))
    _assert_bitwise(sa, fa, sb, fb)


def test_pallas_interpret_worklist_matches_vmap_path():
    """The non-fused scalar-prefetch Pallas worklist kernel (interpret mode)
    must reproduce the vmapped pallas-interpret path exactly: both run the
    same kernel cell math, so even the weight planes match bitwise."""
    ext = _ext_tensor(LAZY_P, seed=3, n_ticks=12, lam=3.0)
    key = jax.random.PRNGKey(0)
    conn = make_connectivity(LAZY_P, jax.random.fold_in(key, 1))
    sa, fa = network_run(init_network(LAZY_P, key), conn, ext, LAZY_P,
                         chunk=12, worklist=False, backend="pallas_interpret")
    sb, fb = network_run(init_network(LAZY_P, key), conn, ext, LAZY_P,
                         chunk=12, worklist=True, fused=False,
                         backend="pallas_interpret")
    _assert_bitwise(sa, fa, sb, fb)


@pytest.mark.parametrize("fused_cols", [False, True])
def test_lazy_worklist_fused_cols_vs_staged_bitwise(fused_cols):
    """The fused single-pass column phase (`fused_cols=True`, the default)
    and the three-phase staged form (`fused_cols=False`) must both match the
    dense path bit-for-bit — the fused loop inlines the SAME (R,) cell
    formulas the vmapped compute runs, and the lazy column island (one
    `decay_zep` + increment + `log`, the same island the fused row phase
    proved) compiles identically in both contexts (docs/NUMERICS.md)."""
    ext = _ext_tensor(LAZY_P, seed=29, n_ticks=40, lam=3.0)
    sa, fa, sb, fb = _run_both(LAZY_P, ext, fused_cols=fused_cols)
    assert (np.asarray(fa) >= 0).sum() > 0, "must exercise column updates"
    _assert_bitwise(sa, fa, sb, fb)


@pytest.mark.parametrize("rows,cols", [(1200, 70), (10000, 100)])
def test_lazy_fused_cols_bitwise_at_scale_dimensioning(rows, cols):
    """Pin the fused/staged COLUMN identity at shapes where fused is the
    default-on path (R*C > DENSE_CELLS_MAX): rodent16 (R=1200, C=70) and
    human-column (R=10000, C=100) dimensioning. Codegen identity across
    compilation contexts is shape-dependent and must be empirically pinned
    (docs/NUMERICS.md) — the toy-size A/Bs do not cover these
    compilations."""
    p = BCPNNParams(n_hcu=2, rows=rows, cols=cols, fanout=2, active_queue=8,
                    max_delay=8, out_rate=0.9)
    assert H.use_worklist(p), "must exercise the default-on regime"
    n_ticks = 8 if rows <= 1200 else 4
    ext = _ext_tensor(p, seed=17, n_ticks=n_ticks, lam=4.0)
    key = jax.random.PRNGKey(0)
    conn = make_connectivity(p, jax.random.fold_in(key, 1))
    sa, fa = network_run(init_network(p, key), conn, ext, p, chunk=n_ticks,
                         fused_cols=False)
    sb, fb = network_run(init_network(p, key), conn, ext, p, chunk=n_ticks,
                         fused_cols=True)
    assert (np.asarray(fa) >= 0).sum() > 0, "must exercise column updates"
    _assert_bitwise(sa, fa, sb, fb)


def test_lazy_worklist_fused_cols_under_queue_overflow():
    """Queue/fired-batch overflow under the fused column path: drops must be
    counted identically and the padding fired-batch slots (h_idx == n) must
    stay no-ops in the fused loop."""
    ext = _ext_tensor(HOT_P, seed=5, n_ticks=60, lam=6.0)
    sa, fa, sb, fb = _run_both(HOT_P, ext, chunk=60, fused_cols=True)
    assert int(sa.drops_in) > 0 and int(sa.drops_fire) > 0
    _assert_bitwise(sa, fa, sb, fb)


@pytest.mark.parametrize("fused_cols", [False, True])
def test_merged_worklist_fused_cols_is_inert(fused_cols):
    """Merged mode: `fused_cols` is accepted but the merged column flush and
    the same-tick `patch_cells` interaction keep the shared
    `merged_col_math` island — trajectories (incl. ring overflow flushes)
    must be bitwise-identical to the dense merged path either way."""
    ext = _ext_tensor(MERGED_P, seed=7, n_ticks=80, lam=6.0)
    sa, fa, sb, fb = _run_both(MERGED_P, ext, merged=True, chunk=11,
                               fused_cols=fused_cols)
    assert (np.asarray(fa) >= 0).sum() > MERGED_P.n_hcu * 8, \
        "case must exercise ring overflow (fires > H * RING_DEPTH)"
    _assert_bitwise(sa, fa, sb, fb, merged=True)


def test_pallas_interpret_fused_col_megakernel_matches_vmap_path():
    """The fused column megakernel (`ops.fused_col_update`, interpret mode)
    must reproduce the vmapped pallas-interpret path exactly — the fired
    (R, 1) column blocks are rewritten in place with the same kernel cell
    math the batched column kernel runs."""
    ext = _ext_tensor(LAZY_P, seed=3, n_ticks=12, lam=3.0)
    key = jax.random.PRNGKey(0)
    conn = make_connectivity(LAZY_P, jax.random.fold_in(key, 1))
    sa, fa = network_run(init_network(LAZY_P, key), conn, ext, LAZY_P,
                         chunk=12, worklist=False, backend="pallas_interpret")
    sb, fb = network_run(init_network(LAZY_P, key), conn, ext, LAZY_P,
                         chunk=12, worklist=True, fused_cols=True,
                         backend="pallas_interpret")
    assert (np.asarray(fa) >= 0).sum() > 0
    _assert_bitwise(sa, fa, sb, fb)


def test_fused_cols_megakernel_large_fired_batch_fallback():
    """A fired-batch capacity larger than one lane tile (cap_fire > 128)
    cannot use the column megakernel (its per-entry lane select is one
    128-wide tile): `engine.worklist_col_dispatch` must fall back to the
    batched-view kernel instead of tracing an unsatisfiable kernel, still
    bitwise against the vmapped path."""
    ext = _ext_tensor(LAZY_P, seed=3, n_ticks=6, lam=3.0)
    key = jax.random.PRNGKey(0)
    conn = make_connectivity(LAZY_P, jax.random.fold_in(key, 1))
    cap = 130
    sa, fa = network_run(init_network(LAZY_P, key), conn, ext, LAZY_P,
                         chunk=6, worklist=False, cap_fire=cap,
                         backend="pallas_interpret")
    sb, fb = network_run(init_network(LAZY_P, key), conn, ext, LAZY_P,
                         chunk=6, worklist=True, fused_cols=True,
                         cap_fire=cap, backend="pallas_interpret")
    _assert_bitwise(sa, fa, sb, fb)


# ----------------------------- unit tests ------------------------------------

def test_build_worklist_compaction_and_dedup_sentinels():
    rows_u = jnp.asarray([[1, 4, 64, 64],      # 2 valid
                          [64, 64, 64, 64],    # empty HCU
                          [0, 63, 64, 64]],    # 2 valid
                         jnp.int32)
    g_row, order, nv = WL.build_worklist(rows_u, 64)
    assert int(nv) == 4
    got = np.asarray(g_row)[np.asarray(order)[:4]]
    np.testing.assert_array_equal(got, [1, 4, 128, 191])
    # padding slots carry the H*R sentinel
    assert np.asarray(g_row)[2] == 3 * 64


def test_compact_mask_matches_stable_argsort():
    rng = np.random.default_rng(0)
    for _ in range(16):
        mask = jnp.asarray(rng.random(23) < 0.4)
        order, count = WL.compact_mask(mask)
        ref = np.argsort(~np.asarray(mask), kind="stable")
        k = int(count)
        assert k == int(np.asarray(mask).sum())
        np.testing.assert_array_equal(np.asarray(order)[:k], ref[:k])


def test_use_worklist_guard():
    assert not H.use_worklist(LAZY_P)                      # 64*16 cells
    assert H.use_worklist(BCPNNParams(n_hcu=2, rows=1200, cols=70))
    assert H.use_worklist(LAZY_P, override=True)
    assert not H.use_worklist(BCPNNParams(n_hcu=2, rows=1200, cols=70),
                              override=False)


def test_use_fused_cols_guard():
    assert H.use_fused_cols(LAZY_P)                        # default on
    assert not H.use_fused_cols(LAZY_P, override=False)
    assert H.use_fused_cols(LAZY_P, override=True)
