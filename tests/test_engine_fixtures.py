"""TickEngine refactor contract: trajectories are bitwise-identical to the
pre-refactor runtime.

tests/fixtures/head_*.npz hold trajectories captured from the runtime BEFORE
the engine/flat-layout refactor (see tests/fixtures/capture_head.py): staged
input, connectivity, fired history, and every NetworkState leaf (ij planes
stored in the canonical flat (H*R, C) layout, which is a pure reshape of the
old batched layout). The live runtime must reproduce them bit for bit in
every mode — lazy / eager / merged, dense and worklist backends, scan and
host-loop drivers, local and sharded.

If one of these fails after an INTENTIONAL trajectory change, regenerate the
fixtures (and say so in the PR). On a fresh machine, 1-ulp libm/codegen
drift is conceivable — see capture_head.py's note.
"""
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Connectivity, init_network, network_run, run,
                        test_scale as tiny_scale)
from repro.core.params import BCPNNParams

FIXTURES = pathlib.Path(__file__).resolve().parent / "fixtures"
SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")

# must match tests/fixtures/capture_head.py
LAZY_P = tiny_scale(n_hcu=4, rows=64, cols=16)
MERGED_P = BCPNNParams(n_hcu=4, rows=24, cols=16, fanout=4, active_queue=8,
                       max_delay=8, out_rate=0.6)


def _conn(d):
    return Connectivity(jnp.asarray(d["conn_dest_hcu"]),
                        jnp.asarray(d["conn_dest_row"]),
                        jnp.asarray(d["conn_delay"]))


def _assert_matches(state, fired, d, name):
    np.testing.assert_array_equal(np.asarray(fired), d["fired"],
                                  err_msg=f"{name}: fired history")
    for f in state.hcus._fields:
        np.testing.assert_array_equal(np.asarray(getattr(state.hcus, f)),
                                      d[f"hcus_{f}"],
                                      err_msg=f"{name}: plane {f}")
    np.testing.assert_array_equal(np.asarray(state.delay_rows),
                                  d["delay_rows"], err_msg=name)
    np.testing.assert_array_equal(np.asarray(state.delay_count),
                                  d["delay_count"], err_msg=name)
    assert int(state.t) == int(d["t"])
    assert int(state.drops_in) == int(d["drops_in"])
    assert int(state.drops_fire) == int(d["drops_fire"])
    if "jring" in d:
        np.testing.assert_array_equal(np.asarray(state.jring), d["jring"],
                                      err_msg=name)


CASES = {
    # name: (params, kwargs, host-loop?)
    "lazy_dense": (LAZY_P, dict(worklist=False), False),
    "lazy_worklist": (LAZY_P, dict(worklist=True), False),
    "eager": (LAZY_P, dict(eager=True), False),
    "merged_dense": (MERGED_P, dict(merged=True, worklist=False,
                                    cap_fire=MERGED_P.n_hcu), False),
    "merged_worklist": (MERGED_P, dict(merged=True, worklist=True,
                                       cap_fire=MERGED_P.n_hcu), False),
    "host_lazy": (LAZY_P, dict(worklist=False), True),
}


@pytest.mark.parametrize("name", sorted(CASES))
def test_trajectory_matches_pre_refactor(name):
    p, kw, host = CASES[name]
    d = np.load(FIXTURES / f"head_{name}.npz")
    conn = _conn(d)
    ext = jnp.asarray(d["ext"])
    state = init_network(p, jax.random.PRNGKey(0),
                         merged=kw.get("merged", False))
    if host:
        state, fired = run(state, conn, lambda t: ext[t - 1], ext.shape[0],
                           p, **kw)
    else:
        state, fired = network_run(state, conn, ext, p, chunk=13, **kw)
    assert (np.asarray(fired) >= 0).sum() > 0, "fixture must exercise spikes"
    _assert_matches(state, fired, d, name)


LAYOUT_CASES = [c for c in ("lazy_dense", "lazy_worklist", "merged_dense",
                            "merged_worklist")]


@pytest.mark.parametrize("name", LAYOUT_CASES)
@pytest.mark.parametrize("tile", [(8, 4), (7, 5)])
def test_trajectory_layout_invariant(name, tile):
    """The PR 8 contract: plane storage order is NOT semantics. The same
    fixtures that pin the flat runtime must reproduce bitwise when the
    planes are stored column-blocked (Row-Merge tiles) — including a
    non-divisible tile, where pad cells exist but never feed compute."""
    from repro.core import layout as L
    p, kw, _ = CASES[name]
    lay = L.BlockedLayout(rows=p.rows, cols=p.cols, xr=tile[0], xc=tile[1])
    d = np.load(FIXTURES / f"head_{name}.npz")
    state = init_network(p, jax.random.PRNGKey(0),
                         merged=kw.get("merged", False), layout=lay)
    state, fired = network_run(state, _conn(d), jnp.asarray(d["ext"]), p,
                               chunk=13, layout=lay, **kw)
    state = state._replace(hcus=L.load_hcus(state.hcus, lay))
    _assert_matches(state, fired, d, f"{name}:blocked{tile}")


def test_sharded_trajectory_matches_pre_refactor():
    """Both sharded backends vs the pre-refactor sharded runtime (subprocess:
    device count must be set before jax initializes)."""
    script = textwrap.dedent("""
        import os, sys
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import Connectivity, init_network, test_scale
        from repro.core import distributed as DD

        p = test_scale(n_hcu=8, rows=64, cols=16)
        key = jax.random.PRNGKey(0)
        mesh = jax.make_mesh((4,), ("hcu",))
        rc = DD.default_route_config(p, 2)
        FIXTURES = os.environ["REPRO_FIXTURES_DIR"]
        for name, wl in (("sharded_dense", False), ("sharded_worklist", True)):
            d = np.load(FIXTURES + f"/head_{name}.npz")
            conn = Connectivity(jnp.asarray(d["conn_dest_hcu"]),
                                jnp.asarray(d["conn_dest_row"]),
                                jnp.asarray(d["conn_delay"]))
            s0, c0 = DD.shard_network(mesh, init_network(p, key), conn)
            fn = DD.make_dist_run(mesh, p, rc, axis="hcu", worklist=wl)
            s1, f1 = fn(s0, c0, jnp.asarray(d["ext"]))
            np.testing.assert_array_equal(np.asarray(f1), d["fired"],
                                          err_msg=name)
            for f in s1.hcus._fields:
                np.testing.assert_array_equal(
                    np.asarray(getattr(s1.hcus, f)), d[f"hcus_{f}"],
                    err_msg=f"{name}:{f}")
            np.testing.assert_array_equal(np.asarray(s1.delay_rows),
                                          d["delay_rows"], err_msg=name)
        print("SHARDED-FIXTURES-OK")
    """)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True,
                       env={**os.environ, "PYTHONPATH": SRC,
                            "REPRO_FIXTURES_DIR": str(FIXTURES)})
    assert "SHARDED-FIXTURES-OK" in r.stdout, r.stdout + r.stderr[-3000:]
