"""End-to-end behaviour tests for the paper's system.

The core claim chain:
  1. the lazy BCPNN network runs in fixed memory with bounded queues,
  2. it implements a working cortical associative memory (paper §I-II),
  3. it is checkpointable mid-stream and resumes bit-exactly,
  4. the serving/training substrate runs end to end on the same repo.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (BCPNNParams, flush, hcu_view, init_network,
                        make_connectivity, network_tick)
from repro.data import make_patterns, poisson_external_drive


def _run(p, state, conn, exts, **kw):
    fired = []
    for e in exts:
        state, f = network_tick(state, conn, e, p, **kw)
        fired.append(np.asarray(f))
    return state, np.stack(fired)


def test_network_long_run_stays_bounded():
    p = BCPNNParams(n_hcu=4, rows=128, cols=16, fanout=4, active_queue=12,
                    max_delay=8, out_rate=0.3)
    key = jax.random.PRNGKey(0)
    conn = make_connectivity(p, jax.random.fold_in(key, 1))
    st = init_network(p, key)
    exts = list(poisson_external_drive(p, 300, seed=1, lam=4.0))
    st, fired = _run(p, st, conn, exts)
    assert int(st.t) == 300
    hc = jax.vmap(lambda s: flush(s, st.t, p))(hcu_view(st))
    assert bool(jnp.all(jnp.isfinite(hc.wij)))
    assert bool(jnp.all(hc.pij >= 0)) and bool(jnp.all(hc.pij <= 2.0))
    assert (fired >= -1).all() and (fired < p.cols).all()
    # network actually spikes
    assert (fired >= 0).sum() > 10


def test_associative_memory_recall():
    """Pattern completion well above chance (paper's functional claim)."""
    p = BCPNNParams(n_hcu=10, rows=48, cols=8, fanout=10, active_queue=16,
                    max_delay=4, mean_delay=1.5, out_rate=1.0, wta_temp=0.25,
                    tau_p=400.0)
    key = jax.random.PRNGKey(0)
    conn = make_connectivity(p, jax.random.fold_in(key, 1))
    patterns = make_patterns(p, 2, seed=3)

    def drive(rows_, mask):
        ext = np.full((p.n_hcu, 4), p.rows, np.int32)
        for h in range(p.n_hcu):
            if mask[h]:
                ext[h, 0] = rows_[h]
        return jnp.asarray(ext)

    st = init_network(p, key)
    all_on = np.ones(p.n_hcu, bool)
    attract = np.zeros((2, p.n_hcu), np.int64)
    for rep in range(25):
        for pid in range(2):
            winners = np.full(p.n_hcu, -1, np.int64)
            for _ in range(6):
                st, f = network_tick(st, conn, drive(patterns[pid], all_on),
                                     p, cap_fire=p.n_hcu)
                fa = np.asarray(f)
                winners[fa >= 0] = fa[fa >= 0]
            if rep == 24:
                attract[pid] = winners
        for _ in range(2):
            st, _ = network_tick(
                st, conn, drive(patterns[0], np.zeros(p.n_hcu, bool)), p,
                cap_fire=p.n_hcu)

    rng = np.random.default_rng(0)
    correct = total = 0
    for pid in range(2):
        mask = rng.random(p.n_hcu) < 0.6
        winners = np.full(p.n_hcu, -1, np.int64)
        for _ in range(12):
            st, f = network_tick(st, conn, drive(patterns[pid], mask), p,
                                 cap_fire=p.n_hcu)
            fa = np.asarray(f)
            winners[fa >= 0] = fa[fa >= 0]
        probe = ~mask & (winners >= 0) & (attract[pid] >= 0)
        correct += int((winners[probe] == attract[pid][probe]).sum())
        total += int(probe.sum())
    assert total >= 5, "recall must probe undriven HCUs"
    acc = correct / total
    assert acc > 2.0 / p.cols, f"recall {acc:.2f} not above chance"


def test_checkpoint_resume_spiking_network(tmp_path):
    """Mid-stream checkpoint + restore reproduces the exact trajectory."""
    from repro.checkpoint import restore, save
    p = BCPNNParams(n_hcu=4, rows=64, cols=16, fanout=4, active_queue=12,
                    max_delay=8, out_rate=0.3)
    key = jax.random.PRNGKey(0)
    conn = make_connectivity(p, jax.random.fold_in(key, 1))
    exts = list(poisson_external_drive(p, 40, seed=2, lam=3.0))

    st = init_network(p, key)
    st, _ = _run(p, st, conn, exts[:20])
    save(str(tmp_path), 20, st)
    st_a, fired_a = _run(p, st, conn, exts[20:])

    st_b = restore(str(tmp_path), 20, init_network(p, key))
    st_b, fired_b = _run(p, st_b, conn, exts[20:])
    np.testing.assert_array_equal(fired_a, fired_b)
    a = jax.vmap(lambda s: flush(s, st_a.t, p))(hcu_view(st_a))
    b = jax.vmap(lambda s: flush(s, st_b.t, p))(hcu_view(st_b))
    np.testing.assert_allclose(np.asarray(a.pij), np.asarray(b.pij),
                               rtol=1e-6)


def test_lm_substrate_end_to_end():
    """Tiny LM: train a few steps, then serve greedily — full-stack check."""
    from repro.launch.serve import Request, ServingEngine
    from repro.launch.train import train
    from repro.configs import get_smoke_config
    from repro.models.transformer import Model

    params, losses = train("internlm2-1.8b", steps=10, batch=4, seq=16,
                           smoke=True, lr=1e-3, log_every=1000)
    assert np.isfinite(losses).all()
    cfg = get_smoke_config("internlm2-1.8b")
    model = Model(cfg)
    eng = ServingEngine(model, params, batch_slots=2, max_len=48)
    rng = np.random.default_rng(0)
    for rid in range(3):
        eng.submit(Request(rid, rng.integers(0, cfg.vocab, 8), 8))
    done = eng.run()
    assert len(done) == 3 and all(len(r.out) == 8 for r in done)
