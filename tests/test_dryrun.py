"""Dry-run plumbing test: one small cell lowered + compiled on 512 host
devices in a subprocess (device count must be set pre-jax-init), plus
offline tests of the roofline parsing/correction machinery."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

from repro.launch.roofline import (_shape_bytes, collective_bytes,
                                   scan_factor)

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    # forced host devices only mean anything on the CPU platform; pin it so
    # a machine with an accelerator plugin (e.g. a baked-in libtpu) doesn't
    # spend minutes probing hardware this test never uses
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import json
    from repro.launch.dryrun import lower_cell, lower_bcpnn
    compiled, text, rec = lower_cell("xlstm-125m", "decode_32k",
                                     multi_pod=True)
    assert rec["chips"] == 512
    assert rec["cost"]["flops"] > 0
    mem = rec["memory"]
    assert mem["argument_bytes"] > 0
    print("CELL_OK", json.dumps({k: rec[k] for k in ("chips", "scan_factor")}))
""")


def test_one_cell_lowers_and_compiles_multipod():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=560,
                       env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    assert "CELL_OK" in r.stdout


def test_shape_bytes_parser():
    assert _shape_bytes("f32[16,128]") == 16 * 128 * 4
    assert _shape_bytes("bf16[2,3,4]") == 24 * 2
    assert _shape_bytes("(f32[8], s32[4])") == 32 + 16
    assert _shape_bytes("pred[7]") == 7


def test_collective_parser_with_loop_scaling():
    hlo = textwrap.dedent("""\
    HloModule m

    %body.1 (p: (s32[], f32[4])) -> (s32[], f32[4]) {
      %ar = f32[4]{0} all-reduce(%x), replica_groups={}
      ROOT %t = (s32[], f32[4]) tuple(%i, %ar)
    }

    %cond.1 (p: (s32[], f32[4])) -> pred[] {
      ROOT %lt = pred[] compare(%i, %n), direction=LT
    }

    ENTRY %main (a: f32[4]) -> f32[4] {
      %ag = f32[8]{0} all-gather(%a), dimensions={0}
      %w = (s32[], f32[4]) while(%init), condition=%cond.1, body=%body.1
      ROOT %out = f32[4] get-tuple-element(%w), index=1
    }
    """)
    flat = collective_bytes(hlo, loop_factor=1.0)
    assert flat["all-gather"] == 32
    assert flat["all-reduce"] == 32          # 2x payload of f32[4]
    scaled = collective_bytes(hlo, loop_factor=10.0)
    assert scaled["all-reduce"] == 320       # in-loop: x10
    assert scaled["all-gather"] == 32        # entry: x1


def test_scan_factor_values():
    from repro.configs import get_config
    assert scan_factor(get_config("qwen2-1.5b")) == 28.0
    assert scan_factor(get_config("gemma2-9b")) == 21.0
    f = scan_factor(get_config("zamba2-7b"))
    assert 11.0 < f < 12.0                   # (13*7 + 3*1) / (7 + 1)
    # whisper encoder adds a 32-repeat scan
    f2 = scan_factor(get_config("whisper-large-v3"), extra_repeats=32)
    assert f2 == (32 + 32) / 2


def test_dryrun_records_complete():
    """Every (arch x shape x mesh) record exists and carries the roofline
    inputs (runs after the sweep; skipped when results are absent)."""
    import glob
    import pytest
    recs = glob.glob("results/dryrun/*__*.json")
    if len(recs) < 80:
        pytest.skip("full dry-run sweep not present in this checkout")
    from repro.configs import ARCH_IDS
    from repro.launch.shapes import SHAPES, applicable
    seen = {}
    for f in recs:
        r = json.load(open(f))
        seen[(r.get("arch"), r.get("shape"), r.get("mesh"))] = r
    for mesh in ("pod16x16", "pod2x16x16"):
        for a in ARCH_IDS:
            for s in SHAPES:
                r = seen.get((a, s, mesh))
                assert r is not None, f"missing cell {a} {s} {mesh}"
                if applicable(a, s):
                    assert "error" not in r, f"{a} {s} {mesh}: {r.get('error')}"
                    assert r["cost"]["flops"] > 0
                    assert r["memory"]["argument_bytes"] > 0
                else:
                    assert "skipped" in r
