"""Unit tests for HCU-level semantics (dedup, row/column updates, flush)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core import hcu as H
from repro.core import test_scale as tiny_scale
from repro.core.traces import ZEP, decay_zep


P = tiny_scale(n_hcu=1, rows=32, cols=16)


def test_dedup_rows_merges_duplicates():
    rows = jnp.array([5, 3, 5, 32, 3, 5, 32, 32], jnp.int32)  # 32 == padding
    r, c = H.dedup_rows(rows, 32)
    got = {int(a): int(b) for a, b in zip(r, c) if int(a) < 32}
    assert got == {3: 2, 5: 3}
    # dropped slots point out of range with zero count
    assert all(int(a) == 32 for a, b in zip(r, c) if int(b) == 0)


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_dedup_rows_property(seed):
    rng = np.random.default_rng(seed)
    raw = rng.integers(0, 12, size=8)
    pad = rng.integers(0, 8)
    raw[8 - pad:] = 12
    r, c = H.dedup_rows(jnp.asarray(raw, jnp.int32), 12)
    # total multiplicity preserved
    assert int(jnp.sum(c)) == int((raw < 12).sum())
    # each unique row appears exactly once among kept slots
    kept = [int(a) for a, b in zip(r, c) if int(b) > 0]
    assert len(kept) == len(set(kept))


def test_row_update_touches_only_selected_rows():
    st_ = H.init_hcu_state(P)
    rows = jnp.full((4,), P.rows, jnp.int32).at[0].set(7)
    st2, w_rows, counts, _ = H.row_updates(st_, rows, 5, P)
    changed = np.asarray(st2.tij != st_.tij)
    assert changed[7].all() and changed.sum() == P.cols
    assert int(st2.ti[7]) == 5 and int(st2.ti[3]) == 0
    assert float(st2.zi[7]) > 0.0


def test_column_update_masked_noop():
    st_ = H.init_hcu_state(P)
    st2 = H.column_update(st_, jnp.asarray(-1, jnp.int32), 5, P)
    np.testing.assert_array_equal(st2.zij, st_.zij)
    np.testing.assert_array_equal(st2.tij, st_.tij)
    np.testing.assert_array_equal(st2.zj, st_.zj)


def test_column_update_applies_increment():
    st_ = H.init_hcu_state(P)
    # give presynaptic traces something to correlate with
    rows = jnp.full((4,), P.rows, jnp.int32).at[0].set(3)
    st_, *_ = H.row_updates(st_, rows, 2, P)
    st2 = H.column_update(st_, jnp.asarray(4, jnp.int32), 6, P)
    # column 4 stamped at t=6; zj[4] incremented
    assert int(st2.tij[0, 4]) == 6 and int(st2.tij[0, 3]) == 0
    assert float(st2.zj[4]) == 1.0
    # Zij[3,4] must have gained ~Zi_3(6) (decayed from t=2)
    zi6 = float(decay_zep(ZEP(st_.zi[3], st_.ei[3], st_.pi[3]), 4.0,
                          H.coeffs_i(P)).z)
    got = float(st2.zij[3, 4])
    assert abs(got - zi6) < 1e-5


def test_flush_is_idempotent():
    st_ = H.init_hcu_state(P)
    rows = jnp.full((4,), P.rows, jnp.int32).at[0].set(1).at[1].set(9)
    st_, *_ = H.row_updates(st_, rows, 3, P)
    f1 = H.flush(st_, 10, P)
    f2 = H.flush(f1, 10, P)
    for a, b in zip(f1, f2):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)


def test_flush_equals_stepwise_decay():
    st_ = H.init_hcu_state(P)
    rows = jnp.full((4,), P.rows, jnp.int32).at[0].set(1)
    st_, *_ = H.row_updates(st_, rows, 1, P)
    direct = H.flush(st_, 21, P)
    two_step = H.flush(H.flush(st_, 11, P), 21, P)
    np.testing.assert_allclose(direct.pij, two_step.pij, rtol=1e-5)
    np.testing.assert_allclose(direct.zij, two_step.zij, rtol=1e-5, atol=1e-7)
