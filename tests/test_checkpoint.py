"""Checkpointing + fault tolerance: roundtrip, atomicity, restart loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, latest_step, restore,
                              restore_latest, save)
from repro.runtime import RestartableLoop, StragglerMonitor, remesh


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32),
                  "d": (jnp.zeros(()), jnp.full((2, 2), 7.0))}}


def test_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 3, t)
    r = restore(str(tmp_path), 3, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(a, b)
    assert latest_step(str(tmp_path)) == 3


def test_restore_latest_and_prune(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        save(str(tmp_path), s, t, keep_last=2)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_4", "step_5"]
    r, s = restore_latest(str(tmp_path), t)
    assert s == 5 and r is not None


def test_shape_mismatch_rejected(tmp_path):
    t = _tree()
    save(str(tmp_path), 1, t)
    bad = dict(t, a=jnp.zeros((2, 2)))
    with pytest.raises(ValueError):
        restore(str(tmp_path), 1, bad)


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    t = _tree()
    ck.save_async(7, t)
    ck.wait()
    assert latest_step(str(tmp_path)) == 7


def test_no_partial_dirs_on_disk(tmp_path):
    save(str(tmp_path), 1, _tree())
    assert not any(d.startswith(".tmp") for d in os.listdir(tmp_path))


def test_restartable_loop_recovers(tmp_path):
    """Inject failures; the loop must resume from checkpoints and finish
    with the same result as an uninterrupted run."""
    failures = {7, 23}

    def injector(step):
        if step in failures:
            failures.discard(step)
            return True
        return False

    def step_fn(state, step):
        return {"x": state["x"] + step}

    loop = RestartableLoop(str(tmp_path), save_every=5,
                           fail_injector=injector)
    out, n = loop.run({"x": jnp.zeros(())}, step_fn, 30)
    assert n == 30 and loop.restarts == 2
    ref = {"x": jnp.zeros(())}
    for s in range(30):
        ref = step_fn(ref, s)
    np.testing.assert_allclose(out["x"], ref["x"])


def test_straggler_monitor():
    mon = StragglerMonitor(deadline_s=10.0)
    mon.start()
    assert mon.finish() is True
    mon2 = StragglerMonitor(deadline_s=0.0)
    mon2.start()
    assert mon2.finish() is False
    mon2.skip()
    assert mon2.summary() == {"total": 1, "slow": 1, "skipped": 1}


def test_remesh_roundtrip():
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(shape=(1, 1))
    t = _tree()
    out = remesh(t, mesh, P())
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(a, b)


def test_bcpnn_state_checkpoint_roundtrip(tmp_path):
    """Flushed BCPNN network state is checkpointable and bit-stable."""
    from repro.core import init_network, test_scale
    p = test_scale(n_hcu=2, rows=32, cols=16)
    st = init_network(p, jax.random.PRNGKey(0))
    save(str(tmp_path), 0, st)
    r = restore(str(tmp_path), 0, st)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
