"""Checkpointing + fault tolerance: roundtrip, atomicity, restart loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, CheckpointCorruption,
                              latest_step, restore, restore_latest,
                              restore_network, save)
from repro.runtime import RestartableLoop, StragglerMonitor, remesh


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32),
                  "d": (jnp.zeros(()), jnp.full((2, 2), 7.0))}}


def test_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 3, t)
    r = restore(str(tmp_path), 3, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(a, b)
    assert latest_step(str(tmp_path)) == 3


def test_restore_latest_and_prune(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        save(str(tmp_path), s, t, keep_last=2)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_4", "step_5"]
    r, s = restore_latest(str(tmp_path), t)
    assert s == 5 and r is not None


def test_shape_mismatch_rejected(tmp_path):
    t = _tree()
    save(str(tmp_path), 1, t)
    bad = dict(t, a=jnp.zeros((2, 2)))
    with pytest.raises(ValueError):
        restore(str(tmp_path), 1, bad)


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    t = _tree()
    ck.save_async(7, t)
    ck.wait()
    assert latest_step(str(tmp_path)) == 7


def test_no_partial_dirs_on_disk(tmp_path):
    save(str(tmp_path), 1, _tree())
    assert not any(d.startswith(".tmp") for d in os.listdir(tmp_path))


def test_restartable_loop_recovers(tmp_path):
    """Inject failures; the loop must resume from checkpoints and finish
    with the same result as an uninterrupted run."""
    failures = {7, 23}

    def injector(step):
        if step in failures:
            failures.discard(step)
            return True
        return False

    def step_fn(state, step):
        return {"x": state["x"] + step}

    loop = RestartableLoop(str(tmp_path), save_every=5,
                           fail_injector=injector)
    out, n = loop.run({"x": jnp.zeros(())}, step_fn, 30)
    assert n == 30 and loop.restarts == 2
    ref = {"x": jnp.zeros(())}
    for s in range(30):
        ref = step_fn(ref, s)
    np.testing.assert_allclose(out["x"], ref["x"])


def test_straggler_monitor():
    mon = StragglerMonitor(deadline_s=10.0)
    mon.start()
    assert mon.finish() is True
    mon2 = StragglerMonitor(deadline_s=0.0)
    mon2.start()
    assert mon2.finish() is False
    mon2.skip()
    s = mon2.summary()
    assert s["total"] == 1 and s["slow"] == 1 and s["skipped"] == 1
    assert s["total_s"] >= 0.0 and s["worst_s"] == mon2.worst_s
    assert mon2.last_s >= 0.0 and mon2.total_s >= mon2.worst_s


def test_stale_tmp_swept_on_next_save(tmp_path):
    """A crash mid-save leaves a .tmp_step_* staging dir; the next save must
    sweep it and pruning must not trip over it."""
    t = _tree()
    orphan = tmp_path / ".tmp_step_9_12345"
    orphan.mkdir()
    (orphan / "leaf_0.npy").write_bytes(b"partial garbage")
    save(str(tmp_path), 1, t, keep_last=1)
    names = os.listdir(tmp_path)
    assert not any(d.startswith(".tmp") for d in names)
    assert "step_1" in names
    r, s = restore_latest(str(tmp_path), t)
    assert s == 1 and r is not None


def test_latest_corrupt_pointer_falls_back(tmp_path):
    """A corrupt or dangling LATEST is only a hint: restore must fall back
    to scanning for the newest complete step."""
    t = _tree()
    save(str(tmp_path), 3, t)
    save(str(tmp_path), 5, t)
    (tmp_path / "LATEST").write_text("not a number")
    assert latest_step(str(tmp_path)) == 5
    (tmp_path / "LATEST").write_text("999")        # dangling pointer
    assert latest_step(str(tmp_path)) == 5
    (tmp_path / "LATEST").write_text("")           # empty file
    r, s = restore_latest(str(tmp_path), t)
    assert s == 5 and r is not None


def test_latest_skips_incomplete_step(tmp_path):
    """A step dir with a manifest promising more leaves than exist (e.g. a
    partially copied checkpoint) must not be selected as latest."""
    import json as _json
    t = _tree()
    save(str(tmp_path), 2, t)
    fake = tmp_path / "step_9"
    fake.mkdir()
    (fake / "manifest.json").write_text(_json.dumps({"n_leaves": 3}))
    os.remove(tmp_path / "LATEST")
    assert latest_step(str(tmp_path)) == 2
    junk = tmp_path / "step_bogus"                 # unparseable step name
    junk.mkdir()
    assert latest_step(str(tmp_path)) == 2


def test_restore_latest_empty_and_missing_dir(tmp_path):
    t = _tree()
    assert restore_latest(str(tmp_path), t) == (None, None)
    assert restore_latest(str(tmp_path / "nope"), t) == (None, None)
    assert latest_step(str(tmp_path / "nope")) is None


def test_remesh_roundtrip():
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(shape=(1, 1))
    t = _tree()
    out = remesh(t, mesh, P())
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(a, b)
        # values bitwise AND actually re-placed under the target mesh
        assert b.sharding == NamedSharding(mesh, P())


# -- manifest checksums: torn/bit-rotted leaves are detected and survivable -

def _corrupt_leaf(tmp_path, step, leaf=0):
    f = tmp_path / f"step_{step}" / f"leaf_{leaf}.npy"
    raw = bytearray(f.read_bytes())
    raw[-1] ^= 0xFF                  # flip bits in the data, not the header
    f.write_bytes(bytes(raw))


def test_manifest_has_checksums(tmp_path):
    import json as _json
    t = _tree()
    save(str(tmp_path), 1, t)
    meta = _json.loads((tmp_path / "step_1" / "manifest.json").read_text())
    assert len(meta["checksums"]) == meta["n_leaves"]
    assert all(isinstance(c, str) and len(c) == 8 for c in meta["checksums"])


def test_restore_detects_corruption(tmp_path):
    t = _tree()
    save(str(tmp_path), 1, t)
    _corrupt_leaf(tmp_path, 1)
    with pytest.raises(CheckpointCorruption):
        restore(str(tmp_path), 1, t)


def test_restore_latest_falls_back_and_prunes_corrupt(tmp_path):
    t = _tree()
    save(str(tmp_path), 1, t)
    save(str(tmp_path), 2, t)
    _corrupt_leaf(tmp_path, 2)
    r, s = restore_latest(str(tmp_path), t)
    assert s == 1 and r is not None
    # the corrupt dir was pruned so the next scan can't trip on it again
    assert not (tmp_path / "step_2").exists()
    # forensics mode: corruption re-raised, dir left in place
    save(str(tmp_path), 3, t)
    _corrupt_leaf(tmp_path, 3)
    with pytest.raises(CheckpointCorruption):
        restore_latest(str(tmp_path), t, prune_corrupt=False)
    assert (tmp_path / "step_3").exists()


def test_restore_latest_all_corrupt_returns_none(tmp_path):
    t = _tree()
    save(str(tmp_path), 1, t)
    _corrupt_leaf(tmp_path, 1)
    assert restore_latest(str(tmp_path), t) == (None, None)


def test_checksumless_manifest_still_restores(tmp_path):
    """Pre-checksum checkpoints (no 'checksums' key) load unverified."""
    import json as _json
    t = _tree()
    save(str(tmp_path), 1, t)
    mf = tmp_path / "step_1" / "manifest.json"
    meta = _json.loads(mf.read_text())
    del meta["checksums"]
    mf.write_text(_json.dumps(meta))
    _corrupt_leaf(tmp_path, 1, leaf=3)   # undetectable without checksums
    r, s = restore_latest(str(tmp_path), t)
    assert s == 1 and r is not None


def test_async_save_error_reraised(tmp_path):
    """A failed background save must surface on wait() / next save_async,
    not vanish — otherwise crash recovery silently degrades to an older
    checkpoint."""
    target = tmp_path / "ckpt"
    target.write_text("a file where the checkpoint dir should go")
    ck = AsyncCheckpointer(str(target))
    ck.save_async(1, _tree())
    with pytest.raises(OSError):
        ck.wait()
    ck.wait()                            # exception is consumed, not sticky
    ck2 = AsyncCheckpointer(str(target))
    ck2.save_async(1, _tree())
    with pytest.raises(OSError):
        ck2.save_async(2, _tree())       # surfaces on the NEXT save too


def test_restore_network_shims_missing_drops_route(tmp_path):
    """Pre-PR 7 NetworkState checkpoints are one trailing leaf short
    (drops_route was appended last); restore_network re-initializes the
    missing counter to 0 and restores everything else bitwise."""
    from repro.core import init_network, test_scale
    p = test_scale(n_hcu=2, rows=32, cols=16)
    st = init_network(p, jax.random.PRNGKey(0))
    st = st._replace(drops_in=jnp.asarray(5, jnp.int32))
    old = st._replace(drops_route=None)          # the pre-PR 7 leaf set
    save(str(tmp_path), 4, old)
    r = restore_network(str(tmp_path), 4, st)
    assert int(np.asarray(r.drops_route)) == 0
    assert int(np.asarray(r.drops_in)) == 5
    for a, b in zip(jax.tree.leaves(old), jax.tree.leaves(
            r._replace(drops_route=None))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # new-format checkpoints restore the counter verbatim
    st2 = st._replace(drops_route=jnp.asarray(9, jnp.int32))
    save(str(tmp_path), 5, st2)
    r2 = restore_network(str(tmp_path), 5, st)
    assert int(np.asarray(r2.drops_route)) == 9


def test_bcpnn_state_checkpoint_roundtrip(tmp_path):
    """Flushed BCPNN network state is checkpointable and bit-stable."""
    from repro.core import init_network, test_scale
    p = test_scale(n_hcu=2, rows=32, cols=16)
    st = init_network(p, jax.random.PRNGKey(0))
    save(str(tmp_path), 0, st)
    r = restore(str(tmp_path), 0, st)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_cross_layout_restore_bitwise(tmp_path):
    """A checkpoint saved under one plane layout restores under the other
    bitwise (PR 8): the manifest's layout tag picks the saved storage
    order, `layout.convert_hcus` is pure data movement. Exercised in both
    directions, flat <-> column-blocked, on a mid-run state."""
    from repro.core import Simulator, test_scale
    from repro.core import layout as L
    p = test_scale(n_hcu=2, rows=32, cols=16)
    lay = L.BlockedLayout(rows=32, cols=16, xr=7, xc=5)  # non-divisible
    rng = np.random.default_rng(0)
    ext = np.full((10, 2, 4), p.rows, np.int32)
    for t in range(10):
        for h in range(2):
            k = min(4, rng.poisson(2.0))
            ext[t, h, :k] = rng.integers(0, p.rows, k)
    ext = jnp.asarray(ext)

    flat = Simulator(p, key=0)
    flat.run(ext)
    blocked = Simulator(p, key=0, layout=lay)
    blocked.run(ext)

    # save flat -> load blocked
    flat.save(str(tmp_path / "a"), 1)
    import json as _json
    meta = _json.loads(
        (tmp_path / "a" / "step_1" / "manifest.json").read_text())
    assert meta["layout"] == "flat"
    b2 = Simulator(p, key=0, layout=lay).load(str(tmp_path / "a"))
    for a, b in zip(jax.tree.leaves(blocked.state), jax.tree.leaves(b2.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # save blocked -> load flat
    blocked.save(str(tmp_path / "b"), 1)
    meta = _json.loads(
        (tmp_path / "b" / "step_1" / "manifest.json").read_text())
    assert meta["layout"] == L.layout_tag(lay)
    f2 = Simulator(p, key=0).load(str(tmp_path / "b"))
    for a, b in zip(jax.tree.leaves(flat.state), jax.tree.leaves(f2.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # same-layout restore is the plain path
    b3 = Simulator(p, key=0, layout=lay).load(str(tmp_path / "b"))
    for a, b in zip(jax.tree.leaves(blocked.state), jax.tree.leaves(b3.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
