"""Elasticity primitives + degraded-mode runtime.

The multi-device pieces run in subprocesses with 4 forced host-platform
devices (XLA_FLAGS must be set before jax initializes). Two contracts are
pinned here:

 * mesh-shape invariance — under `lossless_route_config` the sharded
   trajectory is BITWISE identical on 1/2/4 devices for both engine
   backends, across a remesh round-trip, and across a
   checkpoint-on-one-mesh / restore-onto-another boundary;
 * ElasticRunner recovery — an injected device loss (restore + remesh onto
   the survivors + re-lower + replay) and a graceful shrink-then-regrow
   both reproduce the uninterrupted local trajectory bitwise.
"""
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(script: str) -> subprocess.CompletedProcess:
    return subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True,
                          env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                               "HOME": "/root",
                               # forced host devices only mean anything on
                               # the CPU platform; without the pin a machine
                               # with an accelerator plugin (e.g. a baked-in
                               # libtpu) probes hardware for minutes per test
                               "JAX_PLATFORMS": "cpu"})


def test_elastic_device_count():
    from repro.launch.mesh import elastic_device_count
    assert elastic_device_count(16, 4) == 4
    assert elastic_device_count(16, 3) == 2   # rodent16 losing 1 of 4
    assert elastic_device_count(16, 1) == 1
    assert elastic_device_count(12, 5) == 4
    assert elastic_device_count(7, 3) == 1
    assert elastic_device_count(8, 100) == 8


def test_device_loss_is_injected_failure():
    from repro.runtime import DeviceLoss, InjectedFailure
    e = DeviceLoss(2)
    assert isinstance(e, InjectedFailure)
    assert e.n_lost == 2


MESH_INV_SCRIPT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import *
    from repro.core import distributed as DD
    from repro.checkpoint import save, restore_network
    from repro.runtime import remesh

    p = test_scale(n_hcu=8, rows=64, cols=16)
    key = jax.random.PRNGKey(0)
    conn = make_connectivity(p, jax.random.fold_in(key, 1))
    rng = np.random.default_rng(7)
    def frame():
        out = np.full((p.n_hcu, 8), p.rows, np.int32)
        for h in range(p.n_hcu):
            n = min(8, rng.poisson(3))
            out[h, :n] = rng.integers(0, p.rows, n)
        return out
    exts = jnp.asarray(np.stack([frame() for _ in range(20)]))

    m4 = jax.make_mesh((4,), ("hcu",))
    m2 = jax.make_mesh((2,), ("hcu",), devices=jax.devices()[:2])
    state_specs, conn_specs, spec_h, rep = DD._shard_specs(("hcu",))

    # -- remesh round-trip: values bitwise, shardings actually re-placed
    s0 = init_network(p, key)
    host = jax.tree.map(np.array, s0)
    s4 = remesh(s0, m4, state_specs)
    assert s4.hcus.zij.sharding == NamedSharding(m4, P("hcu"))
    assert s4.delay_rows.sharding == NamedSharding(m4, P("hcu"))
    assert s4.t.sharding == NamedSharding(m4, P())
    s2 = remesh(s4, m2, state_specs)
    assert s2.hcus.zij.sharding == NamedSharding(m2, P("hcu"))
    assert s2.base_key.sharding == NamedSharding(m2, P())
    for a, b in zip(jax.tree.leaves(jax.tree.map(np.asarray, s2)),
                    jax.tree.leaves(host)):
        np.testing.assert_array_equal(a, b)
    print("REMESH_OK")

    # -- same logical trajectory on 1/2/4 devices, both backends
    results = {}
    for wl in (False, True):
        for ndev in (1, 2, 4):
            mesh = jax.make_mesh((ndev,), ("hcu",),
                                 devices=jax.devices()[:ndev])
            rc = DD.lossless_route_config(p, p.n_hcu // ndev)
            s, c = DD.shard_network(mesh, init_network(p, key), conn)
            fn = DD.make_dist_run(mesh, p, rc, worklist=wl)
            s, f = fn(s, c, exts)
            results[(wl, ndev)] = (np.asarray(f), jax.tree.map(np.asarray, s))
            # the overlapped split exchange (send -> columns -> recv) must be
            # bitwise identical to the sequential exchange at every count
            sq, cq = DD.shard_network(mesh, init_network(p, key), conn)
            seq = DD.make_dist_run(mesh, p, rc, worklist=wl, overlap=False)
            sq, fq = seq(sq, cq, exts)
            np.testing.assert_array_equal(
                np.asarray(fq), np.asarray(f),
                err_msg=f"wl={wl} ndev={ndev} overlap-vs-seq fired")
            for name in s.hcus._fields:
                np.testing.assert_array_equal(
                    np.asarray(getattr(sq.hcus, name)),
                    np.asarray(getattr(s.hcus, name)),
                    err_msg=f"wl={wl} ndev={ndev} overlap-vs-seq {name}")
        f1, s1 = results[(wl, 1)]
        for ndev in (2, 4):
            fN, sN = results[(wl, ndev)]
            np.testing.assert_array_equal(f1, fN,
                                          err_msg=f"wl={wl} fired 1-vs-{ndev}")
            for name in s1.hcus._fields:
                np.testing.assert_array_equal(
                    getattr(s1.hcus, name), getattr(sN.hcus, name),
                    err_msg=f"wl={wl} plane {name} 1-vs-{ndev}")
            np.testing.assert_array_equal(s1.delay_rows, sN.delay_rows)
            np.testing.assert_array_equal(s1.delay_count, sN.delay_count)
            assert int(sN.drops_route) == 0    # lossless: capacity never binds
    print("MESHINV_OK")

    # -- checkpoint on the 4-dev mesh, restore onto the 2-dev mesh, finish:
    #    equals the uninterrupted 1-device trajectory
    wl = True
    ck = tempfile.mkdtemp()
    s, c = DD.shard_network(m4, init_network(p, key), conn)
    fn4 = DD.make_dist_run(m4, p, DD.lossless_route_config(p, 2), worklist=wl)
    s, fA = fn4(s, c, exts[:10])
    save(ck, 10, s)
    template = jax.tree.map(np.array, init_network(p, key))
    restored = restore_network(ck, 10, template)
    sR, cR = DD.shard_network(m2, jax.tree.map(jnp.asarray, restored), conn)
    fn2 = DD.make_dist_run(m2, p, DD.lossless_route_config(p, 4), worklist=wl)
    sR, fB = fn2(sR, cR, exts[10:])
    f_ref, s_ref = results[(wl, 1)]
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(fA), np.asarray(fB)]), f_ref)
    for name in s_ref.hcus._fields:
        np.testing.assert_array_equal(np.asarray(getattr(sR.hcus, name)),
                                      getattr(s_ref.hcus, name),
                                      err_msg=f"xmesh plane {name}")
    print("RESTORE_XMESH_OK")
""")


def test_mesh_shape_invariance_and_restore_across_mesh():
    r = _run(MESH_INV_SCRIPT)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    for marker in ("REMESH_OK", "MESHINV_OK", "RESTORE_XMESH_OK"):
        assert marker in r.stdout


RUNNER_SCRIPT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import *
    from repro.runtime import ElasticRunner

    p = test_scale(n_hcu=8, rows=64, cols=16)
    T, CH = 24, 4
    rng = np.random.default_rng(11)
    ext = np.full((T, p.n_hcu, 8), p.rows, np.int32)
    for t in range(T):
        for h in range(p.n_hcu):
            n = min(8, rng.poisson(3))
            ext[t, h, :n] = rng.integers(0, p.rows, n)

    # uninterrupted local reference at the lossless 1-device fire cap
    ref = Simulator(p, key=0, cap_fire=p.n_hcu)
    f_ref = np.asarray(ref.run(jnp.asarray(ext)))

    # 1) injected device loss: crash -> restore -> remesh 4 -> 2 -> replay
    # (self-clearing injector: chunk 3 re-runs on replay and must not
    # re-kill — a persistent injector would correctly exhaust the fleet)
    sim = Simulator(p, key=0)
    fails = {3: 2}
    runner = ElasticRunner(sim, tempfile.mkdtemp(), chunk_ticks=CH,
                           fail_injector=lambda c: fails.pop(c, 0))
    fired, health = runner.run(ext)
    np.testing.assert_array_equal(fired, f_ref)
    assert runner.restarts == 1 and len(runner.recoveries) == 1
    rec = runner.recoveries[0]
    assert rec["kind"] == "device-loss" and rec["devices"] == 2
    assert rec["recovery_s"] >= 0.0
    assert len(runner.devices) == 2
    assert health["restarts"] == 1
    assert set(health["classes"]) == {"in", "fire", "route"}
    assert health["drops"]["route"] == 0
    assert health["status"] in ("ok", "deadline-missed")
    for name in ref.state.hcus._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(sim.state.hcus, name)),
            np.asarray(getattr(ref.state.hcus, name)),
            err_msg=f"post-loss plane {name}")
    print("LOSS_OK")

    # 2) graceful mid-run shrink then regrow: pure data movement, no replay
    sim2 = Simulator(p, key=0)
    sched = {1: 2, 3: 4}
    runner2 = ElasticRunner(sim2, tempfile.mkdtemp(), chunk_ticks=CH,
                            rescale=lambda c: sched.get(c))
    fired2, health2 = runner2.run(ext)
    np.testing.assert_array_equal(fired2, f_ref)
    assert runner2.restarts == 0
    for name in ref.state.hcus._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(sim2.state.hcus, name)),
            np.asarray(getattr(ref.state.hcus, name)),
            err_msg=f"rescale plane {name}")
    print("RESCALE_OK")
""")


def test_elastic_runner_device_loss_and_rescale():
    r = _run(RUNNER_SCRIPT)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    assert "LOSS_OK" in r.stdout
    assert "RESCALE_OK" in r.stdout
