"""System-level equivalence: lazy (paper) vs eager dense golden model.

This is the reproduction's central correctness claim (paper §VII.A.2: RTL
verified against golden C++ model): the lazily-evaluated, time-stamped,
queue-driven network must produce the SAME spikes and the SAME trace state
as the dense per-tick reference, up to float rounding.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (flush, hcu_view, init_network, make_connectivity,
                        network_tick, test_scale as tiny_scale)


def _ext_stream(p, seed, n_ticks, width=8, lam=3.0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_ticks):
        e = np.full((p.n_hcu, width), p.rows, np.int32)
        for h in range(p.n_hcu):
            n = min(width, rng.poisson(lam))
            e[h, :n] = rng.integers(0, p.rows, n)
        out.append(jnp.asarray(e))
    return out


def _run(p, exts, eager):
    key = jax.random.PRNGKey(0)
    conn = make_connectivity(p, jax.random.fold_in(key, 1))
    st = init_network(p, key)
    fired = []
    for e in exts:
        st, f = network_tick(st, conn, e, p, eager=eager, cap_fire=p.n_hcu)
        fired.append(np.asarray(f))
    return st, np.stack(fired)


@pytest.mark.parametrize("seed,n_ticks", [(0, 50), (1, 30)])
def test_lazy_matches_eager(seed, n_ticks):
    p = tiny_scale(n_hcu=4, rows=64, cols=16)
    exts = _ext_stream(p, seed, n_ticks)
    s_lazy, f_lazy = _run(p, exts, eager=False)
    s_eager, f_eager = _run(p, exts, eager=True)

    # identical spike trains (bit-exact decisions)
    np.testing.assert_array_equal(f_lazy, f_eager)
    assert (f_lazy >= 0).sum() > 0, "test must exercise output spikes"

    # identical trace state after a flush
    now = s_lazy.t
    a = jax.vmap(lambda s: flush(s, now, p))(hcu_view(s_lazy))
    b = jax.vmap(lambda s: flush(s, now, p))(hcu_view(s_eager))
    for name in ["zij", "eij", "pij", "wij", "zi", "ei", "pi", "zj", "ej",
                 "pj", "h"]:
        np.testing.assert_allclose(
            getattr(a, name), getattr(b, name), rtol=2e-4, atol=2e-4,
            err_msg=f"trace plane {name} diverged")


def test_lazy_matches_eager_pallas_backend():
    """Same equivalence with the Pallas kernel (interpret) in the loop."""
    p = tiny_scale(n_hcu=2, rows=32, cols=16)
    exts = _ext_stream(p, 3, 20)
    key = jax.random.PRNGKey(0)
    conn = make_connectivity(p, jax.random.fold_in(key, 1))

    st_p = init_network(p, key)
    st_e = init_network(p, key)
    for e in exts:
        st_p, fp = network_tick(st_p, conn, e, p, eager=False,
                                backend="pallas_interpret", cap_fire=p.n_hcu)
        st_e, fe = network_tick(st_e, conn, e, p, eager=True,
                                cap_fire=p.n_hcu)
        np.testing.assert_array_equal(np.asarray(fp), np.asarray(fe))
    now = st_p.t
    a = jax.vmap(lambda s: flush(s, now, p))(hcu_view(st_p))
    b = jax.vmap(lambda s: flush(s, now, p))(hcu_view(st_e))
    np.testing.assert_allclose(a.pij, b.pij, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(a.wij, b.wij, rtol=2e-3, atol=2e-3)


def test_drop_counters_zero_under_light_load():
    p = tiny_scale(n_hcu=4, rows=64, cols=16)
    exts = _ext_stream(p, 0, 30, lam=1.0)
    st, _ = _run(p, exts, eager=False)
    assert int(st.drops_in) == 0
