"""End-to-end training: loss decreases, checkpoint-resume is exact."""
import numpy as np
import pytest

from repro.launch.train import train


def test_loss_decreases_markov_lm(tmp_path):
    _, losses = train("qwen2-1.5b", steps=100, batch=16, seq=64, smoke=True,
                      lr=1e-2, log_every=1000)
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    # Markov stream entropy is log(branch)=log(4)~1.39; random init starts
    # near log(vocab)=log(512)~6.2 — training must close most of the gap
    # (measured: 6.22 -> ~2.0 in 100 steps)
    assert last < first - 2.0, f"no learning: {first:.3f} -> {last:.3f}"
    assert np.isfinite(losses).all()


def test_checkpoint_resume_exact(tmp_path):
    """Stop at 20 steps, resume to 30 == train straight to 30 (same data)."""
    d1 = str(tmp_path / "a")
    train("internlm2-1.8b", steps=20, batch=4, seq=16, smoke=True,
          ckpt_dir=None, lr=1e-3, log_every=1000)
    # straight run
    p_straight, l_straight = train("internlm2-1.8b", steps=30, batch=4,
                                   seq=16, smoke=True, lr=1e-3,
                                   log_every=1000)
    # interrupted run: 50-step save cadence won't fire at 20 — use explicit
    # two-phase with checkpointing every 50 replaced by final save
    from repro.checkpoint import save, restore
    import jax
    from repro.configs import get_smoke_config
    from repro.models.transformer import Model
    from repro.train import AdamW, make_train_step
    from repro.data import MarkovLM

    cfg = get_smoke_config("internlm2-1.8b")
    model = Model(cfg)
    opt = AdamW(lr=1e-3, warmup_steps=20)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, opt))
    data = MarkovLM(vocab=cfg.vocab, seed=0)
    for s in range(20):
        params, opt_state, m = step_fn(params, opt_state, data.batch(s, 4, 16))
    save(d1, 20, (params, opt_state))
    (params2, opt2) = restore(d1, 20, (params, opt_state))
    losses_resumed = []
    for s in range(20, 30):
        params2, opt2, m = step_fn(params2, opt2, data.batch(s, 4, 16))
        losses_resumed.append(float(m["loss"]))
    # non-interrupted reference from the same state
    losses_cont = []
    for s in range(20, 30):
        params, opt_state, m = step_fn(params, opt_state, data.batch(s, 4, 16))
        losses_cont.append(float(m["loss"]))
    np.testing.assert_allclose(losses_resumed, losses_cont, rtol=1e-5,
                               atol=1e-6)


def test_grad_clip_engages():
    import jax
    import jax.numpy as jnp
    from repro.train.optimizer import AdamW
    opt = AdamW(lr=1.0, grad_clip=1e-3, warmup_steps=1, weight_decay=0.0)
    params = {"w": jnp.ones((4,))}
    st = opt.init(params)
    big = {"w": jnp.full((4,), 1e6)}
    p2, st2, m = opt.update(big, st, params)
    assert float(m["grad_norm"]) > 1e5
    # clipped update magnitude ~ lr * unit vector
    assert float(jnp.max(jnp.abs(p2["w"] - params["w"]))) < 1.1
