"""TickEngine backend selection, the Simulator facade, and checkpoint
round-trips through the canonical flat layout.

Checkpoint contract (the paper's restartability requirement at 1000-node
scale): save -> load -> continue must be bitwise-identical to an
uninterrupted run — in lazy, merged and sharded modes — and pre-refactor
(H, R, C)-layout checkpoints must load through the migration shim
(`checkpoint.restore_network`) and continue bit-exactly too.
"""
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore, restore_network, save
from repro.core import (DenseBackend, Simulator, WorklistBackend, hcu_view,
                        init_network, make_connectivity, network_run,
                        select_backend,
                        test_scale as tiny_scale)
from repro.core import hcu as H
from repro.core.params import BCPNNParams

FIXTURES = pathlib.Path(__file__).resolve().parent / "fixtures"
SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")

LAZY_P = tiny_scale(n_hcu=4, rows=64, cols=16)
MERGED_P = BCPNNParams(n_hcu=4, rows=24, cols=16, fanout=4, active_queue=8,
                       max_delay=8, out_rate=0.6)


def _ext_tensor(p, seed, n_ticks, width=8, lam=3.0):
    rng = np.random.default_rng(seed)
    out = np.full((n_ticks, p.n_hcu, width), p.rows, np.int32)
    for t in range(n_ticks):
        for h in range(p.n_hcu):
            n = min(width, rng.poisson(lam))
            out[t, h, :n] = rng.integers(0, p.rows, n)
    return jnp.asarray(out)


def _assert_state_equal(sa, sb, merged=False):
    for name in sa.hcus._fields:
        np.testing.assert_array_equal(np.asarray(getattr(sa.hcus, name)),
                                      np.asarray(getattr(sb.hcus, name)),
                                      err_msg=f"plane {name}")
    np.testing.assert_array_equal(np.asarray(sa.delay_rows),
                                  np.asarray(sb.delay_rows))
    np.testing.assert_array_equal(np.asarray(sa.delay_count),
                                  np.asarray(sb.delay_count))
    assert int(sa.t) == int(sb.t)
    assert int(sa.drops_in) == int(sb.drops_in)
    assert int(sa.drops_fire) == int(sb.drops_fire)
    if merged:
        np.testing.assert_array_equal(np.asarray(sa.jring),
                                      np.asarray(sb.jring))


# ----------------------------- backend selection -----------------------------

def test_select_backend_mirrors_use_worklist_guard():
    assert isinstance(select_backend(LAZY_P), DenseBackend)
    big = BCPNNParams(n_hcu=2, rows=1200, cols=70)
    assert isinstance(select_backend(big), WorklistBackend)
    assert isinstance(select_backend(LAZY_P, worklist=True), WorklistBackend)
    assert isinstance(select_backend(big, worklist=False), DenseBackend)
    # the eager golden reference is dense by definition
    assert select_backend(big, eager=True) == DenseBackend(mode="eager")
    assert select_backend(big, merged=True) == WorklistBackend(mode="merged")
    assert select_backend(LAZY_P, merged=True) == DenseBackend(mode="merged")
    # backends are hashable value objects (static jit args)
    assert hash(select_backend(LAZY_P)) == hash(DenseBackend())


# ----------------------------- Simulator facade ------------------------------

def test_simulator_matches_hand_wired_runtime():
    """Simulator.run == init_network + make_connectivity + network_run."""
    ext = _ext_tensor(LAZY_P, seed=5, n_ticks=30)
    sim = Simulator(LAZY_P, key=0)
    f_sim = sim.run(ext)

    key = jax.random.PRNGKey(0)
    conn = make_connectivity(LAZY_P, jax.random.fold_in(key, 1))
    st, f_ref = network_run(init_network(LAZY_P, key), conn, ext, LAZY_P)
    np.testing.assert_array_equal(np.asarray(f_sim), np.asarray(f_ref))
    _assert_state_equal(sim.state, st)


def test_simulator_tick_and_views():
    sim = Simulator(LAZY_P, key=0)
    ext = np.full((LAZY_P.n_hcu, 4), LAZY_P.rows, np.int32)
    ext[0, 0] = 3
    fired = sim.tick(jnp.asarray(ext))
    assert fired.shape == (LAZY_P.n_hcu,)
    assert int(sim.state.t) == 1
    hb = sim.hcus()
    assert hb.zij.shape == (LAZY_P.n_hcu, LAZY_P.rows, LAZY_P.cols)
    fl = sim.flushed()
    assert bool(jnp.all(jnp.isfinite(fl.wij)))


# ----------------------------- checkpoint round-trips ------------------------

@pytest.mark.parametrize("mode", ["lazy", "merged"])
def test_checkpoint_roundtrip_continues_bitwise(mode, tmp_path):
    """save -> load -> continue == uninterrupted run, to the last bit."""
    merged = mode == "merged"
    p = MERGED_P if merged else LAZY_P
    ext = _ext_tensor(p, seed=9, n_ticks=40, lam=4.0)
    kw = dict(merged=merged, cap_fire=p.n_hcu if merged else None)
    key = jax.random.PRNGKey(0)
    conn = make_connectivity(p, jax.random.fold_in(key, 1))

    st = init_network(p, key, merged=merged)
    st, _ = network_run(st, conn, ext[:15], p, **kw)
    save(str(tmp_path), 15, st)
    st_a, fired_a = network_run(st, conn, ext[15:], p, **kw)

    st_b = restore_network(str(tmp_path), 15, init_network(p, key,
                                                           merged=merged))
    st_b, fired_b = network_run(st_b, conn, ext[15:], p, **kw)
    np.testing.assert_array_equal(np.asarray(fired_a), np.asarray(fired_b))
    assert (np.asarray(fired_a) >= 0).sum() > 0
    _assert_state_equal(st_a, st_b, merged=merged)


def test_checkpoint_roundtrip_sharded_bitwise(tmp_path):
    """Sharded run -> save (gathers shards) -> restore -> reshard ->
    continue == uninterrupted sharded run (subprocess: 4 host devices)."""
    script = textwrap.dedent("""
        import os, sys
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np, jax, jax.numpy as jnp
        from repro.checkpoint import restore_network, save
        from repro.core import init_network, make_connectivity, test_scale
        from repro.core import distributed as DD

        p = test_scale(n_hcu=8, rows=64, cols=16)
        key = jax.random.PRNGKey(0)
        conn = make_connectivity(p, jax.random.fold_in(key, 1))
        mesh = jax.make_mesh((4,), ("hcu",))
        rc = DD.default_route_config(p, 2)
        fn = DD.make_dist_run(mesh, p, rc, axis="hcu")
        rng = np.random.default_rng(13)
        ext = np.full((30, p.n_hcu, 8), p.rows, np.int32)
        for t in range(30):
            for h in range(p.n_hcu):
                n = min(8, rng.poisson(3))
                ext[t, h, :n] = rng.integers(0, p.rows, n)
        ext = jnp.asarray(ext)

        s, c = DD.shard_network(mesh, init_network(p, key), conn)
        s, _ = fn(s, c, ext[:12])
        save({ckpt!r}, 12, s)
        s_a, f_a = fn(s, c, ext[12:])

        s_b = restore_network({ckpt!r}, 12, init_network(p, key))
        s_b, c_b = DD.shard_network(mesh, s_b, conn)
        s_b, f_b = fn(s_b, c_b, ext[12:])
        np.testing.assert_array_equal(np.asarray(f_a), np.asarray(f_b))
        assert (np.asarray(f_a) >= 0).sum() > 0
        for name in s_a.hcus._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(s_a.hcus, name)),
                np.asarray(getattr(s_b.hcus, name)), err_msg=name)
        np.testing.assert_array_equal(np.asarray(s_a.delay_rows),
                                      np.asarray(s_b.delay_rows))
        print("SHARDED-CKPT-OK")
    """).format(ckpt=str(tmp_path / "ckpt"))
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": SRC})
    assert "SHARDED-CKPT-OK" in r.stdout, r.stdout + r.stderr[-3000:]


def test_legacy_layout_checkpoint_migrates_and_continues_bitwise():
    """A real pre-refactor checkpoint (tests/fixtures/legacy_ckpt, saved by
    the (H, R, C)-layout runtime at t=10) loads through the one-call shim
    and continues exactly like an uninterrupted run."""
    p = tiny_scale(n_hcu=2, rows=32, cols=16)
    key = jax.random.PRNGKey(0)
    conn = make_connectivity(p, jax.random.fold_in(key, 1))
    d = np.load(FIXTURES / "legacy_ckpt_ext.npz")
    ext = jnp.asarray(d["ext"])

    # the raw restore must refuse the layout mismatch...
    with pytest.raises(ValueError):
        restore(str(FIXTURES / "legacy_ckpt"), 10, init_network(p, key))
    # ...and the shim must fix it
    st = restore_network(str(FIXTURES / "legacy_ckpt"), 10,
                         init_network(p, key))
    assert st.hcus.zij.shape == (p.n_hcu * p.rows, p.cols)
    assert int(st.t) == 10
    st, fired = network_run(st, conn, ext[10:], p)

    st_ref = init_network(p, key)
    st_ref, fired_ref = network_run(st_ref, conn, ext, p)
    np.testing.assert_array_equal(np.asarray(fired),
                                  np.asarray(fired_ref)[10:])
    _assert_state_equal(st, st_ref)


def test_simulator_save_load_roundtrip(tmp_path):
    """The facade's save/load pair continues bitwise too."""
    ext = _ext_tensor(LAZY_P, seed=3, n_ticks=24)
    sim = Simulator(LAZY_P, key=0)
    sim.run(ext[:12])
    sim.save(str(tmp_path))
    f_a = sim.run(ext[12:])
    state_a = sim.state

    sim2 = Simulator(LAZY_P, key=0).load(str(tmp_path))
    assert int(sim2.state.t) == 12
    f_b = sim2.run(ext[12:])
    np.testing.assert_array_equal(np.asarray(f_a), np.asarray(f_b))
    _assert_state_equal(state_a, sim2.state)


def test_migrate_shim_passes_canonical_checkpoints_through(tmp_path):
    """restore_network on an already-flat checkpoint is a plain restore."""
    st = init_network(LAZY_P, jax.random.PRNGKey(0))
    save(str(tmp_path), 0, st)
    r = restore_network(str(tmp_path), 0, st)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_hcu_view_roundtrip():
    """flat_state(batched_state(x)) is the identity on canonical state."""
    from repro.core import batched_state, flat_state
    st = init_network(LAZY_P, jax.random.PRNGKey(0))
    hb = hcu_view(st)
    assert hb.zij.shape == (LAZY_P.n_hcu, LAZY_P.rows, LAZY_P.cols)
    assert hb.zi.shape == (LAZY_P.n_hcu, LAZY_P.rows)
    back = flat_state(hb)
    for a, b in zip(st.hcus, back):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # init matches the tiled per-HCU init exactly
    ref = flat_state(jax.vmap(lambda _: H.init_hcu_state(LAZY_P))(
        jnp.arange(LAZY_P.n_hcu)))
    for a, b in zip(st.hcus, ref):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
