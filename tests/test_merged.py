"""eBrainIII merged-column-update mode (core/merged.py) vs the golden model.

The paper's §IX roadmap eliminates column updates by reconstructing them at
the next row touch. These tests prove the reconstruction is exact (up to
ring truncation, which the test regimes keep un-exercised) against the
dense eager reference — same spikes, same trace state.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (flush, hcu_view, init_network, make_connectivity,
                        network_tick, test_scale as tiny_scale)
from repro.core import merged as M
from repro.core import hcu as H
from repro.core.params import BCPNNParams


def _ext_stream(p, seed, n_ticks, width=8, lam=5.0):
    rng = np.random.default_rng(seed)
    for _ in range(n_ticks):
        e = np.full((p.n_hcu, width), p.rows, np.int32)
        for h in range(p.n_hcu):
            n = min(width, rng.poisson(lam))
            e[h, :n] = rng.integers(0, p.rows, n)
        yield jnp.asarray(e)


@pytest.mark.parametrize("seed,n_ticks,out_rate", [(0, 40, 0.3), (7, 20, 0.5)])
def test_merged_matches_eager(seed, n_ticks, out_rate):
    # Exactness holds while no column receives more than RING_DEPTH output
    # spikes between consecutive touches of any row. The paper's regime
    # (rows touched every ~R/10 ms, per-column fire rate out_rate/C) gives
    # Poisson(~1) spikes per interval — overflow P < 1e-6 at depth 8. The
    # test uses few rows + high input rate so every row is touched every
    # ~5 ticks, scaling that ratio faithfully even with WTA concentration.
    p = BCPNNParams(n_hcu=4, rows=24, cols=16, fanout=4, active_queue=8,
                    max_delay=8, out_rate=out_rate)
    key = jax.random.PRNGKey(0)
    conn = make_connectivity(p, jax.random.fold_in(key, 1))
    s_m = init_network(p, key, merged=True)
    s_e = init_network(p, key)
    fired_m, fired_e = [], []
    for ext in _ext_stream(p, seed, n_ticks):
        s_m, fm = network_tick(s_m, conn, ext, p, merged=True,
                               cap_fire=p.n_hcu)
        s_e, fe = network_tick(s_e, conn, ext, p, eager=True,
                               cap_fire=p.n_hcu)
        fired_m.append(np.asarray(fm))
        fired_e.append(np.asarray(fe))
    np.testing.assert_array_equal(np.stack(fired_m), np.stack(fired_e))
    assert (np.stack(fired_m) >= 0).sum() > 0, "must exercise output spikes"

    now = s_m.t
    a = jax.vmap(lambda s, g: M.flush_merged(s, g, now, p))(hcu_view(s_m),
                                                            s_m.jring)
    b = jax.vmap(lambda s: flush(s, now, p))(hcu_view(s_e))
    for name in ["zij", "eij", "pij", "wij", "zi", "pi", "zj", "pj", "h"]:
        np.testing.assert_allclose(
            getattr(a, name), getattr(b, name), rtol=4e-4, atol=4e-4,
            err_msg=f"merged-mode trace {name} diverged")


def test_merged_exact_under_ring_overflow():
    """Pathological regime: out_rate=1.0 concentrates >RING_DEPTH fires on
    one column between row touches — the overflow-triggered column flush
    must keep the mode exact (this regime diverged before the flush)."""
    p = BCPNNParams(n_hcu=2, rows=64, cols=8, fanout=2, active_queue=8,
                    max_delay=8, out_rate=1.0)
    key = jax.random.PRNGKey(3)
    conn = make_connectivity(p, jax.random.fold_in(key, 1))
    s_m = init_network(p, key, merged=True)
    s_e = init_network(p, key)
    for ext in _ext_stream(p, 11, 50, lam=2.0):
        s_m, fm = network_tick(s_m, conn, ext, p, merged=True,
                               cap_fire=p.n_hcu)
        s_e, fe = network_tick(s_e, conn, ext, p, eager=True,
                               cap_fire=p.n_hcu)
        np.testing.assert_array_equal(np.asarray(fm), np.asarray(fe))
    now = s_m.t
    a = jax.vmap(lambda s, g: M.flush_merged(s, g, now, p))(hcu_view(s_m),
                                                            s_m.jring)
    b = jax.vmap(lambda s: flush(s, now, p))(hcu_view(s_e))
    np.testing.assert_allclose(a.pij, b.pij, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(a.eij, b.eij, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(a.zij, b.zij, rtol=5e-4, atol=5e-4)


def test_ring_push_and_overflow():
    p = tiny_scale(n_hcu=1, rows=32, cols=4)
    ring = M.init_ring(p)
    for t in (3, 5, 9, 11, 15):
        ring = M.push_ring(ring, jnp.asarray(2), jnp.asarray(t))
    # column 2 holds the LAST four times, sorted ascending
    np.testing.assert_array_equal(ring[2][-4:], [5, 9, 11, 15])
    assert int(ring[0, -1]) == M.RING_EMPTY
    # masked push (j = -1) is a no-op
    ring2 = M.push_ring(ring, jnp.asarray(-1), jnp.asarray(20))
    np.testing.assert_array_equal(ring, ring2)


def test_flush_merged_idempotent():
    p = tiny_scale(n_hcu=1, rows=32, cols=8)
    st = H.init_hcu_state(p)
    ring = M.init_ring(p)
    rows = jnp.full((4,), p.rows, jnp.int32).at[0].set(3)
    st, *_ = M.row_updates_merged(st, ring, rows, 2, p)
    ring = M.push_ring(ring, jnp.asarray(5), jnp.asarray(4))
    f1 = M.flush_merged(st, ring, 10, p)
    f2 = M.flush_merged(f1, ring, 10, p)
    for x, y in zip(f1, f2):
        np.testing.assert_allclose(x, y, rtol=1e-6, atol=1e-7)


def test_worst_case_budget_reduction():
    """EQ2 with merged columns: human scale loses the 10,000-cell term."""
    from repro.core.params import human_scale
    out = M.worst_case_cells_merged(human_scale())
    assert out["classic_cells"] == 36 * 100 + 10_000
    assert out["merged_cells"] == 3600
    assert 3.7 < out["reduction"] < 3.8
