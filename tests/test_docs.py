"""Docs link-consistency: every file path and dotted symbol named in
docs/*.md (and README.md) must actually exist.

Two mechanical conventions, enforced so the docs cannot silently rot:

  * path-like tokens (``a/b/c.py``, ``FOO.md``, ``x.json``, ...) must exist
    relative to the repo root, or — shorthand used by architecture diagrams
    — relative to ``src/repro/`` (``core/engine.py``);
  * dotted code references starting with a known top-level package
    (``repro.core.engine.tick``, ``benchmarks.bcpnn_tables.fig10_rowmerge``)
    must resolve: the longest importable module prefix is imported and the
    remaining attributes are getattr-walked.

When writing docs, reference code with exactly these two forms and this
test keeps them honest. Wired into tier-1 (`make verify` -> `make test`).
"""
from __future__ import annotations

import importlib
import re
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
DOCS = sorted((ROOT / "docs").glob("*.md")) + [ROOT / "README.md"]

_PATH_RE = re.compile(
    r"\.?[A-Za-z0-9_][A-Za-z0-9_./-]*\.(?:py|md|json|yml|yaml|npz|txt)\b")
_DOTTED_RE = re.compile(
    r"\b(?:repro|benchmarks)\.[A-Za-z_][A-Za-z0-9_.]*[A-Za-z0-9_]")

# glob-ish tokens used to describe families of files are checked as globs
_GLOBBABLE = ("*", "?")


def _path_candidates(tok: str):
    yield ROOT / tok
    yield ROOT / "src" / "repro" / tok


def _resolve_dotted(tok: str) -> bool:
    parts = tok.split(".")
    for k in range(len(parts), 0, -1):
        modname = ".".join(parts[:k])
        try:
            obj = importlib.import_module(modname)
        except ImportError:
            continue
        for attr in parts[k:]:
            if not hasattr(obj, attr):
                return False
            obj = getattr(obj, attr)
        return True
    return False


def _doc_ids():
    return [p.relative_to(ROOT).as_posix() for p in DOCS]


@pytest.fixture(scope="module", autouse=True)
def _repo_root_on_path():
    # `benchmarks.*` resolves when pytest runs from the repo root (tier-1);
    # make that explicit so the test is cwd-independent
    sys.path.insert(0, str(ROOT))
    yield
    sys.path.remove(str(ROOT))


def test_docs_tree_exists():
    for name in ("ARCHITECTURE.md", "PAPER_MAP.md", "NUMERICS.md",
                 "BENCHMARKING.md"):
        assert (ROOT / "docs" / name).is_file(), f"docs/{name} missing"


@pytest.mark.parametrize("doc", DOCS, ids=_doc_ids())
def test_doc_file_paths_exist(doc):
    text = doc.read_text()
    missing = []
    for tok in sorted(set(_PATH_RE.findall(text))):
        if any(ch in tok for ch in _GLOBBABLE):
            if not list(ROOT.glob(tok)):
                missing.append(tok)
            continue
        if not any(c.exists() for c in _path_candidates(tok)):
            missing.append(tok)
    assert not missing, (
        f"{doc.name} references nonexistent files: {missing}")


@pytest.mark.parametrize("doc", DOCS, ids=_doc_ids())
def test_doc_symbols_resolve(doc):
    text = doc.read_text()
    missing = []
    for tok in sorted(set(_DOTTED_RE.findall(text))):
        # path-like tokens with extensions are covered by the path check
        if _PATH_RE.fullmatch(tok):
            continue
        if not _resolve_dotted(tok):
            missing.append(tok)
    assert not missing, (
        f"{doc.name} references unresolvable symbols: {missing}")
