"""Scan-compiled runtime (network_run) vs the per-tick host loop (run).

The tentpole claim of the compiled tick runtime: `network_run` is a pure
dispatch-elimination — same single-tick body, same RNG stream, therefore
BITWISE-identical trajectories (fired history AND state planes) in all
three execution modes (lazy / eager / merged). Chunk sizes that do not
divide T exercise the full-chunk + remainder compilation path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (flush, hcu_view, init_network, make_connectivity,
                        network_run, run, stage_external,
                        test_scale as tiny_scale)
from repro.core import merged as M


def _ext_tensor(p, seed, n_ticks, width=8, lam=3.0):
    rng = np.random.default_rng(seed)
    out = np.full((n_ticks, p.n_hcu, width), p.rows, np.int32)
    for t in range(n_ticks):
        for h in range(p.n_hcu):
            n = min(width, rng.poisson(lam))
            out[t, h, :n] = rng.integers(0, p.rows, n)
    return jnp.asarray(out)


def _params():
    return tiny_scale(n_hcu=4, rows=64, cols=16)


@pytest.mark.parametrize("mode,chunk", [
    ("lazy", 7), ("lazy", 64), ("eager", 7), ("merged", 7)])
def test_scan_matches_host_loop_bitwise(mode, chunk):
    p = _params()
    n_ticks = 40
    key = jax.random.PRNGKey(0)
    conn = make_connectivity(p, jax.random.fold_in(key, 1))
    ext = _ext_tensor(p, seed=3, n_ticks=n_ticks)
    kw = dict(eager=(mode == "eager"), merged=(mode == "merged"))
    is_merged = mode == "merged"

    s_host = init_network(p, key, merged=is_merged)
    s_scan = init_network(p, key, merged=is_merged)
    s_host, f_host = run(s_host, conn, lambda t: ext[t - 1], n_ticks, p, **kw)
    s_scan, f_scan = network_run(s_scan, conn, ext, p, chunk=chunk, **kw)

    # bitwise-identical spike history (the acceptance criterion)
    np.testing.assert_array_equal(np.asarray(f_host), np.asarray(f_scan))
    assert (np.asarray(f_host) >= 0).sum() > 0, "must exercise output spikes"
    assert int(s_scan.t) == n_ticks

    # and bitwise-identical state down to every plane
    flat_h, _ = jax.tree.flatten(s_host)
    flat_s, _ = jax.tree.flatten(s_scan)
    for a, b in zip(flat_h, flat_s):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_scan_chunk_boundaries_are_invisible():
    """Trajectory must not depend on where chunk boundaries fall."""
    p = _params()
    key = jax.random.PRNGKey(2)
    conn = make_connectivity(p, jax.random.fold_in(key, 1))
    ext = _ext_tensor(p, seed=11, n_ticks=30)
    outs = []
    for chunk in (1, 4, 30, 128):
        s, f = network_run(init_network(p, key), conn, ext, p, chunk=chunk)
        outs.append((np.asarray(f), int(s.t)))
    for f, t in outs[1:]:
        np.testing.assert_array_equal(outs[0][0], f)
        assert t == outs[0][1]


def test_stage_external_matches_callable_protocol():
    p = _params()
    rng = np.random.default_rng(0)
    frames = [jnp.asarray(rng.integers(0, p.rows, (p.n_hcu, 4)), jnp.int32)
              for _ in range(5)]
    fn = lambda t: frames[t - 1]
    a = stage_external(fn, n_ticks=5)
    b = stage_external(frames)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (5, p.n_hcu, 4)


def test_network_run_empty_ext():
    p = _params()
    key = jax.random.PRNGKey(0)
    conn = make_connectivity(p, jax.random.fold_in(key, 1))
    st = init_network(p, key)
    st, f = network_run(st, conn, jnp.zeros((0, p.n_hcu, 4), jnp.int32), p)
    assert f.shape == (0, p.n_hcu)
    assert int(st.t) == 0


def test_merged_scan_state_matches_eager_flush():
    """End-to-end: merged mode driven entirely through the scan runtime still
    reconstructs the exact eager trace state (ring semantics survive scan)."""
    from repro.core.params import BCPNNParams
    p = BCPNNParams(n_hcu=4, rows=24, cols=16, fanout=4, active_queue=8,
                    max_delay=8, out_rate=0.3)
    key = jax.random.PRNGKey(0)
    conn = make_connectivity(p, jax.random.fold_in(key, 1))
    ext = _ext_tensor(p, seed=5, n_ticks=30, lam=5.0)
    s_m, f_m = network_run(init_network(p, key, merged=True), conn, ext, p,
                           chunk=9, merged=True, cap_fire=p.n_hcu)
    s_e, f_e = network_run(init_network(p, key), conn, ext, p,
                           chunk=9, eager=True, cap_fire=p.n_hcu)
    np.testing.assert_array_equal(np.asarray(f_m), np.asarray(f_e))
    now = s_m.t
    a = jax.vmap(lambda s, g: M.flush_merged(s, g, now, p))(hcu_view(s_m),
                                                            s_m.jring)
    b = jax.vmap(lambda s: flush(s, now, p))(hcu_view(s_e))
    for name in ["zij", "eij", "pij", "wij", "zi", "pi", "zj", "pj", "h"]:
        np.testing.assert_allclose(
            np.asarray(getattr(a, name)), np.asarray(getattr(b, name)),
            rtol=2e-4, atol=2e-4, err_msg=name)
