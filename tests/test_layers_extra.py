"""Tests for the §Perf optimization levers: chunked attention equivalence,
spike-word packing, FSDP spec validity, SP fallback plumbing."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_smoke_config
from repro.core import test_scale as tiny_scale
from repro.core.distributed import pack_spikes, unpack_spikes
from repro.models.transformer import Model


@pytest.mark.parametrize("arch_id", ["gemma2-9b", "internlm2-1.8b",
                                     "qwen2-1.5b", "llama-3.2-vision-11b"])
def test_chunked_attention_matches_dense(arch_id):
    """Flash-style online softmax == dense softmax (bf16 tolerance)."""
    cfg_d = get_smoke_config(arch_id)
    cfg_c = dataclasses.replace(cfg_d, attn_impl="chunked", attn_chunk=8)
    key = jax.random.PRNGKey(0)
    model_d, model_c = Model(cfg_d), Model(cfg_c)
    params = model_d.init(key)
    batch = {"tokens": jax.random.randint(key, (2, 33), 0, cfg_d.vocab)}
    if cfg_d.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (2, cfg_d.n_patches, cfg_d.vision_dim))
    ld, _ = jax.jit(model_d.forward)(params, batch)
    lc, _ = jax.jit(model_c.forward)(params, batch)
    err = float(jnp.max(jnp.abs(ld.astype(jnp.float32)
                                - lc.astype(jnp.float32))))
    scale = float(jnp.max(jnp.abs(ld.astype(jnp.float32)))) + 1e-6
    assert err / scale < 0.02, f"{arch_id}: rel err {err/scale}"


def test_chunked_attention_nondivisible_seq():
    """Sequence length not divisible by chunk: padding must not leak."""
    cfg = dataclasses.replace(get_smoke_config("internlm2-1.8b"),
                              attn_impl="chunked", attn_chunk=7)
    cfg_d = get_smoke_config("internlm2-1.8b")
    m, md = Model(cfg), Model(cfg_d)
    key = jax.random.PRNGKey(1)
    params = md.init(key)
    batch = {"tokens": jax.random.randint(key, (1, 29), 0, cfg.vocab)}
    lc, _ = jax.jit(m.forward)(params, batch)
    ld, _ = jax.jit(md.forward)(params, batch)
    np.testing.assert_allclose(np.asarray(lc, np.float32),
                               np.asarray(ld, np.float32), atol=0.1)


@settings(max_examples=100, deadline=None)
@given(loc=st.integers(0, 127), row=st.integers(0, 1200),
       dly=st.integers(1, 7), valid=st.booleans())
def test_spike_word_roundtrip(loc, row, dly, valid):
    """pack/unpack of the Fig-3 spike word is lossless."""
    p = tiny_scale(n_hcu=256, rows=1200, cols=16)
    w = pack_spikes(jnp.asarray(loc), jnp.asarray(row), jnp.asarray(dly),
                    jnp.asarray(valid), p, h_local=128)
    lo, ro, do, vo = unpack_spikes(w, p, h_local=128)
    assert (int(lo), int(ro), int(do), bool(vo)) == (loc, row, dly, valid)


def test_spike_word_capacity_guard():
    """Packing must refuse configurations that overflow 31 bits."""
    from repro.core.distributed import _pack_bits
    p_big = tiny_scale(n_hcu=2, rows=2**20, cols=16)
    with pytest.raises(AssertionError):
        _pack_bits(p_big, h_local=2**12)


def test_fsdp_specs_no_duplicate_axes():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    from repro.launch import shardings as SH
    from repro.launch.shapes import params_specs_abstract
    cfg = get_config("qwen3-moe-235b-a22b")
    p_abs = params_specs_abstract(cfg)
    specs = SH.param_specs(p_abs, cfg, FakeMesh(),
                           fsdp_threshold_bytes=1 << 25)
    o_specs = SH.opt_specs(specs, zero=True, mesh=FakeMesh(), params=p_abs)
    for tree in (specs, o_specs.mu):
        for s in jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, P)):
            axes = [a for e in s if e is not None
                    for a in (e if isinstance(e, tuple) else (e,))]
            assert len(axes) == len(set(axes)), f"duplicate axes in {s}"
    # the big expert stacks must actually be FSDP'd over data
    big = specs["stack"][0][0]["ffn"]["wi"]
    assert "data" in str(big) and "model" in str(big)


def test_mapped_size_outside_context():
    from repro.models.sharding import mapped_size
    assert mapped_size("heads") == 1   # no rules active -> no TP


def test_seq_mp_rule_exists():
    from repro.models.sharding import DEFAULT_RULES
    assert DEFAULT_RULES["seq_mp"] == ("model",)
    assert DEFAULT_RULES["expert"] == ("model",)
