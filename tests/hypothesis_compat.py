"""Optional-hypothesis shim for the property-based tests.

The tier-1 suite must collect and run on a bare interpreter (jax + pytest
only). When `hypothesis` is installed, this module re-exports the real
`given/settings/st`; when it is not, `@given(...)` turns the decorated test
into a skip and `st` becomes a chainable dummy so module-level strategy
definitions (`st.floats(...).filter(...)`) still evaluate.

Usage in test modules:  `from hypothesis_compat import given, settings, st`
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare CI images
    import pytest

    HAVE_HYPOTHESIS = False

    class _DummyStrategy:
        """Absorbs any chained strategy construction at module scope."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    st = _DummyStrategy()

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco
