"""Queue dimensioning (paper §IV, Fig 7) and runtime queue behaviour."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (enqueue_spikes, init_network, make_connectivity,
                        network_tick, test_scale as tiny_scale)
from repro.core.queues import (drop_probability_per_ms,
                               expected_drops_per_month,
                               min_queue_for_monthly_drop_budget, p_x_or_more)


def test_eq1_poisson_tail_paper_anchors():
    """Fig 7 anchor points: P(0+)=1, P(10+)~0.5 at lambda=10, ~0 after 22+."""
    assert p_x_or_more(0, 10.0) == 1.0
    assert abs(p_x_or_more(10, 10.0) - 0.542) < 0.02   # ~0.5 per the paper
    assert p_x_or_more(23, 10.0) < 3e-4                # "near 0 after 22+"


def test_queue_36_monthly_drop_budget():
    """Paper: queue of 36 => ~30% probability of one drop per month."""
    drops = expected_drops_per_month(36, 10.0)
    assert 0.05 < drops < 1.0, f"expected O(0.3)/month, got {drops}"
    # and the minimal queue for a <=1/month budget is in the mid-30s
    q = min_queue_for_monthly_drop_budget(10.0, budget=1.0)
    assert 30 <= q <= 36


def test_drop_probability_monotone_in_queue():
    probs = [drop_probability_per_ms(q, 10.0) for q in (5, 10, 22, 36)]
    assert all(a > b for a, b in zip(probs, probs[1:]))


def test_enqueue_respects_capacity_and_counts_drops():
    p = tiny_scale(n_hcu=2, rows=64, cols=16)      # active_queue == 8
    st = init_network(p, jax.random.PRNGKey(0))
    m = 3 * p.active_queue                          # oversubscribe one bucket
    dest_h = jnp.zeros((m,), jnp.int32)
    dest_r = jnp.arange(m, dtype=jnp.int32) % p.rows
    delay = jnp.full((m,), 2, jnp.int32)
    valid = jnp.ones((m,), bool)
    st2 = enqueue_spikes(st, dest_h, dest_r, delay, valid, p, p.n_hcu)
    b = int((st.t + 2) % p.max_delay)
    assert int(st2.delay_count[0, b]) == p.active_queue
    assert int(st2.drops_in) == m - p.active_queue
    # stored rows are a subset of the sent rows; no slot left empty
    rows = np.asarray(st2.delay_rows[0, b])
    assert (rows < p.rows).all()


def test_delayed_delivery_timing():
    """A spike with delay d must be consumed exactly d ticks later."""
    p = tiny_scale(n_hcu=1, rows=32, cols=16)
    st = init_network(p, jax.random.PRNGKey(0))
    d = 3
    st = enqueue_spikes(st, jnp.array([0]), jnp.array([5]),
                        jnp.array([d]), jnp.array([True]), p, 1)
    conn = make_connectivity(p, jax.random.PRNGKey(1), n_hcu=1)
    empty = jnp.full((1, 4), p.rows, jnp.int32)
    for i in range(1, d + 1):
        bucket = (st.t + 1) % p.max_delay
        pending = int(st.delay_count[0, bucket])
        st, _ = network_tick(st, conn, empty, p)
        if i == d:
            assert pending == 1, "spike must be in the consumed bucket at t+d"
        else:
            assert pending == 0
    # after consumption the bucket is recycled
    assert int(st.delay_count.sum()) == 0 or int(st.drops_in) == 0
