"""Pallas flash-attention kernel vs dense oracle (interpret mode), swept
over shapes, masks and softcap — per-kernel allclose as required."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels.flash_attention import flash_attention, flash_attention_ref


def _qkv(rng, BH, Sq, Skv, hd, dtype=jnp.float32):
    q = jnp.asarray(rng.normal(size=(BH, Sq, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(BH, Skv, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(BH, Skv, hd)), dtype)
    return q, k, v


@pytest.mark.parametrize("Sq,Skv,bq,bk", [
    (128, 128, 128, 128),
    (256, 256, 128, 128),
    (256, 512, 128, 128),
    (384, 384, 128, 128),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_dense(Sq, Skv, bq, bk, causal):
    if causal and Sq != Skv:
        pytest.skip("causal requires square")
    rng = np.random.default_rng(Sq + Skv)
    q, k, v = _qkv(rng, 3, Sq, Skv, 64)
    scale = 64 ** -0.5
    out = flash_attention(q, k, v, scale=scale, causal=causal, bq=bq, bk=bk,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, scale=scale, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_sliding_window():
    rng = np.random.default_rng(0)
    q, k, v = _qkv(rng, 2, 256, 256, 64)
    out = flash_attention(q, k, v, scale=0.125, causal=True, window=64,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, scale=0.125, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_softcap_gemma_style():
    rng = np.random.default_rng(1)
    q, k, v = _qkv(rng, 2, 128, 128, 64)
    out = flash_attention(q, k, v, scale=0.125, causal=True, softcap=50.0,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, scale=0.125, causal=True, softcap=50.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_bf16_io():
    rng = np.random.default_rng(2)
    q, k, v = _qkv(rng, 2, 128, 128, 64, jnp.bfloat16)
    out = flash_attention(q, k, v, scale=0.125, causal=True, interpret=True)
    ref = flash_attention_ref(q, k, v, scale=0.125, causal=True)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch_id", ["internlm2-1.8b", "gemma2-9b"])
def test_flash_in_full_model(arch_id):
    """End-to-end: cfg.attn_impl='pallas_flash' == dense through the whole
    forward (bf16 accumulation tolerance; gemma2 exercises softcap +
    alternating sliding windows through the kernel)."""
    import dataclasses
    from repro.configs import get_smoke_config
    from repro.models.transformer import Model
    cfg_d = get_smoke_config(arch_id)
    cfg_f = dataclasses.replace(cfg_d, attn_impl="pallas_flash")
    key = jax.random.PRNGKey(0)
    md, mf = Model(cfg_d), Model(cfg_f)
    params = md.init(key)
    batch = {"tokens": jax.random.randint(key, (2, 128), 0, cfg_d.vocab)}
    ld, _ = jax.jit(md.forward)(params, batch)
    lf, _ = jax.jit(mf.forward)(params, batch)
    err = float(jnp.max(jnp.abs(ld.astype(jnp.float32)
                                - lf.astype(jnp.float32))))
    scale = float(jnp.max(jnp.abs(ld.astype(jnp.float32)))) + 1e-6
    assert err / scale < 0.03, f"{arch_id}: rel err {err/scale}"


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), nq=st.integers(1, 3),
       nk=st.integers(1, 3))
def test_flash_property_blocks(seed, nq, nk):
    """Arbitrary multiples of the block size, non-causal (ragged kv)."""
    rng = np.random.default_rng(seed)
    q, k, v = _qkv(rng, 1, 128 * nq, 128 * nk, 64)
    out = flash_attention(q, k, v, scale=0.1, causal=False, interpret=True)
    ref = flash_attention_ref(q, k, v, scale=0.1, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)
