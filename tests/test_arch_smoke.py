"""Per-architecture smoke tests (deliverable f): reduced same-family configs,
one forward + one train step on CPU, asserting output shapes and no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.transformer import Model, build_stack_spec
from repro.train import AdamW, make_train_step

B, S = 2, 16


def _batch(cfg, key):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.n_patches, cfg.vision_dim), jnp.float32)
    if cfg.enc_dec:
        batch["frames"] = jax.random.normal(
            key, (B, cfg.n_enc_frames, cfg.vision_dim), jnp.float32)
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_shapes_and_finite(arch_id):
    cfg = get_smoke_config(arch_id)
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    logits, aux = jax.jit(model.forward)(params, _batch(cfg, key))
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_one_train_step_updates_params(arch_id):
    cfg = get_smoke_config(arch_id)
    model = Model(cfg)
    opt = AdamW(lr=1e-3, warmup_steps=1)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    p2, o2, metrics = step(params, opt_state, _batch(cfg, key))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # embeddings must actually move
    delta = float(jnp.max(jnp.abs(p2["embed"] - params["embed"])))
    assert delta > 0.0
    # no NaNs anywhere in the updated tree
    for leaf in jax.tree.leaves(p2):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), arch_id


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_matches_forward(arch_id):
    """Prefill + incremental decode must reproduce teacher-forced logits.
    (MoE: capacity raised so no tokens drop — drops differ by batch shape.)"""
    cfg = get_smoke_config(arch_id)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=16.0)
    model = Model(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    batch = _batch(cfg, key)
    toks = batch["tokens"]
    logits_full, _ = jax.jit(model.forward)(params, batch)

    pre = S // 2
    caches = model.init_cache(B, S + 2)
    pb = dict(batch)
    pb["tokens"] = toks[:, :pre]
    memory, mem_pos = model._encode_memory(params, batch)
    lp, caches = jax.jit(model.prefill)(params, pb, caches)
    errs = [float(jnp.max(jnp.abs(lp[:, 0] - logits_full[:, pre - 1])))]
    for i in range(pre, S):
        lo, caches = jax.jit(model.decode_step)(
            params, toks[:, i:i + 1], jnp.asarray(i, jnp.int32), caches,
            memory, mem_pos)
        errs.append(float(jnp.max(jnp.abs(lo[:, 0] - logits_full[:, i]))))
    scale = float(jnp.max(jnp.abs(logits_full))) + 1e-6
    rel = max(errs) / scale
    tol = 0.05 if cfg.family in ("ssm", "hybrid") else 1e-3
    assert rel < tol, f"{arch_id}: decode/fwd rel err {rel:.4f}"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_full_config_matches_assignment(arch_id):
    """The FULL configs carry the exact assigned dimensions."""
    cfg = get_config(arch_id)
    expect = {
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
    }[arch_id]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_ff,
           cfg.vocab)
    assert got == expect
    # stack spec covers exactly n_layers backbone blocks (zamba2's shared
    # attention block is an INSERTION between the 81 mamba layers, not one
    # of them — exclude it from the count)
    n = sum(sum(1 for k in pat if k != "shared_attn") * rep
            for pat, rep in build_stack_spec(cfg))
    assert n == cfg.n_layers, f"{arch_id}: stack covers {n} != {cfg.n_layers}"


def test_moe_assignment_details():
    cfg = get_config("qwen3-moe-235b-a22b")
    assert (cfg.n_experts, cfg.top_k) == (128, 8)
    cfg = get_config("llama4-maverick-400b-a17b")
    assert (cfg.n_experts, cfg.top_k) == (128, 1)


def test_param_counts_in_family_range():
    """Analytic param counts should land near the advertised sizes."""
    approx = {
        "xlstm-125m": (0.08e9, 0.3e9),
        "internlm2-1.8b": (1.2e9, 2.4e9),
        "qwen2-1.5b": (1.0e9, 2.1e9),
        "gemma2-9b": (8e9, 11e9),
        "qwen3-moe-235b-a22b": (180e9, 260e9),
        "llama4-maverick-400b-a17b": (300e9, 480e9),
        "zamba2-7b": (5e9, 9.5e9),
        "whisper-large-v3": (1.2e9, 2.2e9),
    }
    for aid, (lo, hi) in approx.items():
        n = get_config(aid).param_count()
        assert lo <= n <= hi, f"{aid}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"
