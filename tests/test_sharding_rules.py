"""Unit tests for the launch-layer sharding rules (no big meshes needed —
specs are pure functions of shapes + mesh topology)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import shardings as SH
from repro.launch.mesh import make_host_mesh
from repro.launch.shapes import (SHAPES, applicable, input_specs,
                                 params_specs_abstract)


@pytest.fixture(scope="module")
def mesh():
    # topology-only use: axis sizes (1,1) stand in for (16,16); divisibility
    # is exercised separately with a fake-size mesh below
    return make_host_mesh(shape=(1, 1), axes=("data", "model"))


def test_param_specs_congruent(mesh):
    cfg = get_config("qwen2-1.5b")
    p_abs = params_specs_abstract(cfg)
    specs = SH.param_specs(p_abs, cfg, mesh)
    assert jax.tree.structure(specs, is_leaf=lambda x: isinstance(x, P)) \
        == jax.tree.structure(p_abs)


def test_divisibility_drops_to_replication():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    m = FakeMesh()
    # kv=2 heads * 128 hd = 256 divides 16 -> sharded
    assert SH._checked(m, 256, ("model",)) == "model"
    # 100 does not divide 16 -> replicate
    assert SH._checked(m, 100, ("model",)) is None
    assert SH._checked(m, 8, ("pod", "data")) is None   # pod absent? present
    # only axes present in the mesh are used
    assert SH._checked(m, 32, ("pod", "data")) == "data"


def test_moe_expert_dim_sharded():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    cfg = get_config("qwen3-moe-235b-a22b")
    leaf = jax.ShapeDtypeStruct((94, 128, 4096, 1536), jnp.float32)
    spec = SH.param_spec("stack/0/0/ffn/wi", leaf, cfg, FakeMesh())
    assert spec == P(None, "model", None, None)
    # shared-expert MLP inside an MoE model is NOT expert-sharded
    leaf2 = jax.ShapeDtypeStruct((94, 4096, 1536), jnp.float32)
    spec2 = SH.param_spec("stack/0/0/ffn/shared/wi", leaf2, cfg, FakeMesh())
    assert spec2 == P(None, None, "model")


def test_cache_specs_kv_vs_state():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    cfg = get_config("internlm2-1.8b")
    caches = input_specs(cfg, "decode_32k")["caches"]
    specs = SH.cache_specs(caches, cfg, FakeMesh())
    flat, _ = jax.tree_util.tree_flatten_with_path(specs)
    k_specs = [s for kp, s in flat if any(
        getattr(k, "name", "") == "k" for k in kp)]
    assert k_specs, "KV cache specs must exist"
    for s in k_specs:
        # batch 128 over data; kv=8 doesn't divide 16 -> head_dim=128 sharded
        assert s == P(None, "data", None, None, "model")


def test_long_500k_seq_sharding():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    cfg = get_config("zamba2-7b")
    caches = input_specs(cfg, "long_500k")["caches"]
    specs = SH.cache_specs(caches, cfg, FakeMesh(), seq_shard=True)
    flat, _ = jax.tree_util.tree_flatten_with_path(specs)
    k_specs = [s for kp, s in flat if any(
        getattr(k, "name", "") == "k" for k in kp)]
    for s in k_specs:
        assert s[2] == "data", f"sequence dim must shard: {s}"


def test_applicability_matrix():
    longs = [a for a in
             ("xlstm-125m", "zamba2-7b", "gemma2-9b", "qwen2-1.5b",
              "whisper-large-v3")
             if applicable(a, "long_500k")]
    assert longs == ["xlstm-125m", "zamba2-7b"]
    assert all(applicable(a, s) for a in ("gemma2-9b",)
               for s in ("train_4k", "prefill_32k", "decode_32k"))


def test_input_specs_shapes():
    cfg = get_config("llama-3.2-vision-11b")
    sp = input_specs(cfg, "train_4k")
    assert sp["batch"]["tokens"].shape == (256, 4096)
    assert sp["batch"]["patch_embeds"].shape == (256, 1601, 1280)
    dec = input_specs(cfg, "decode_32k")
    assert dec["token"].shape == (128, 1)
    assert dec["memory"].shape[0] == 128
    # whisper decode carries encoder memory
    cfgw = get_config("whisper-large-v3")
    decw = input_specs(cfgw, "decode_32k")
    assert decw["memory"].shape == (128, 1500, 1280)


def test_zero_opt_specs_extend_over_data():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    cfg = get_config("internlm2-1.8b")
    p_abs = params_specs_abstract(cfg)
    p_specs = SH.param_specs(p_abs, cfg, FakeMesh())
    o_specs = SH.opt_specs(p_specs, zero=True, mesh=FakeMesh(), params=p_abs)
    # embed (V, D): vocab over model; ZeRO adds data on D (2048 % 16 == 0)
    assert o_specs.mu["embed"] == P("model", "data")
    assert o_specs.step == P()
