"""Property tests for the closed-form lazy trace algebra (repro.core.traces)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.traces import ZEP, decay_zep, euler_zep, make_coeffs

K = make_coeffs(2.5, 100.0, 1000.0)

pos = st.floats(min_value=0.0, max_value=5.0, allow_nan=False, width=32)
gap = st.floats(min_value=0.0, max_value=500.0, allow_nan=False, width=32)


@settings(max_examples=200, deadline=None)
@given(z=pos, e=pos, p=pos, d1=gap, d2=gap)
def test_semigroup(z, e, p, d1, d2):
    """decay(d1+d2) == decay(d2) o decay(d1) — the correctness basis of lazy
    evaluation (skipping N ticks == N per-tick decays)."""
    zep0 = ZEP(jnp.float32(z), jnp.float32(e), jnp.float32(p))
    a = decay_zep(decay_zep(zep0, d1, K), d2, K)
    b = decay_zep(zep0, d1 + d2, K)
    for x, y in zip(a, b):
        np.testing.assert_allclose(x, y, rtol=2e-4, atol=2e-5)


@settings(max_examples=50, deadline=None)
@given(z=pos, e=pos, p=pos)
def test_identity_at_zero_gap(z, e, p):
    zep0 = ZEP(jnp.float32(z), jnp.float32(e), jnp.float32(p))
    out = decay_zep(zep0, 0.0, K)
    for x, y in zip(out, zep0):
        np.testing.assert_allclose(x, y, rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("dt", [1.0, 5.0, 25.0])
def test_matches_euler_ode(dt):
    """Closed form must agree with fine-step Euler integration of the ODEs."""
    zep0 = ZEP(jnp.float32(1.0), jnp.float32(0.3), jnp.float32(0.05))
    exact = decay_zep(zep0, dt, K)
    approx = euler_zep(zep0, dt, n_steps=20000, K=None) if False else \
        euler_zep(zep0, dt, 20000, K)
    for x, y in zip(exact, approx):
        np.testing.assert_allclose(x, y, rtol=3e-3, atol=1e-5)


def test_monotone_decay_to_zero():
    zep = ZEP(jnp.float32(1.0), jnp.float32(1.0), jnp.float32(1.0))
    prev = 3.0
    for d in [10.0, 100.0, 1000.0, 10000.0]:
        out = decay_zep(zep, d, K)
        total = float(out.z + out.e + out.p)
        assert total < prev
        prev = total
    assert total < 1e-3


def test_decay_vectorized_matches_scalar():
    rng = np.random.default_rng(0)
    z, e, p = (jnp.asarray(rng.uniform(0, 2, (7, 11)), jnp.float32)
               for _ in range(3))
    d = jnp.asarray(rng.uniform(0, 50, (7, 11)), jnp.float32)
    out = decay_zep(ZEP(z, e, p), d, K)
    for i in range(7):
        for j in range(0, 11, 3):
            ref = decay_zep(ZEP(z[i, j], e[i, j], p[i, j]), d[i, j], K)
            for a, b in zip((out.z[i, j], out.e[i, j], out.p[i, j]), ref):
                np.testing.assert_allclose(a, b, rtol=1e-6)
