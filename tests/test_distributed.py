"""Sharded runtime equivalence — runs in a subprocess with 4 host devices
(XLA device count must be set before jax initializes, so it cannot be done
inside the main pytest process)."""
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    # the forced host-device count only means anything on the CPU platform;
    # pin it so a machine with an accelerator plugin (e.g. a baked-in libtpu)
    # doesn't spend minutes probing hardware this test never uses
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import *
    from repro.core import distributed as DD

    p = test_scale(n_hcu=8, rows=64, cols=16)
    key = jax.random.PRNGKey(0)
    conn = make_connectivity(p, jax.random.fold_in(key, 1))
    # two independent (identical) states: ticks donate their buffers, and
    # device_put may alias the host copy, so dist/single must not share
    s0 = init_network(p, key)
    s_s = init_network(p, key)

    mesh = jax.make_mesh((4,), ("hcu",))
    rc = DD.default_route_config(p, 2)
    tick = DD.make_dist_tick(mesh, p, rc, axis="hcu")
    s_d, conn_d = DD.shard_network(mesh, s0, conn)

    rng = np.random.default_rng(7)
    def ext():
        out = np.full((p.n_hcu, 8), p.rows, np.int32)
        for h in range(p.n_hcu):
            n = min(8, rng.poisson(3))
            out[h, :n] = rng.integers(0, p.rows, n)
        return jnp.asarray(out)

    exts = [ext() for _ in range(25)]
    fired_d = []
    for e in exts:
        s_d, fd = tick(s_d, conn_d, e)
        fired_d.append(np.asarray(fd))
    # single-device trajectory with matching per-device fire cap semantics
    for e in exts:
        s_s, fs = network_tick(s_s, conn, e, p, cap_fire=8)

    # scan-compiled sharded driver: bitwise the same trajectory as the
    # per-tick sharded loop, in ONE compiled computation
    s_r = init_network(p, key)
    s_r, conn_r = DD.shard_network(mesh, s_r, conn)
    run_fn = DD.make_dist_run(mesh, p, rc, axis="hcu")
    s_r, f_r = run_fn(s_r, conn_r, jnp.stack(exts))
    np.testing.assert_array_equal(np.asarray(f_r), np.stack(fired_d))
    assert int(s_r.t) == 25
    for name in ["zij", "eij", "pij", "wij", "tij", "zi", "pi", "zj"]:
        np.testing.assert_array_equal(
            np.asarray(getattr(s_r.hcus, name)),
            np.asarray(getattr(s_d.hcus, name)), err_msg=name)

    now = s_d.t
    a = jax.vmap(lambda s: flush(s, now, p))(hcu_view(s_d))
    b = jax.vmap(lambda s: flush(s, now, p))(hcu_view(s_s))
    for name in ["zij", "eij", "pij", "wij", "zi", "pi", "zj", "pj", "h"]:
        np.testing.assert_allclose(getattr(a, name), getattr(b, name),
                                   rtol=3e-4, atol=3e-4, err_msg=name)
    assert int(s_d.t) == 25
    print("DIST_OK")
""")


def test_distributed_matches_single_device():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                                       "HOME": "/root"})
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    assert "DIST_OK" in r.stdout


# The permanent guard against `_local_tick` divergence: the sharded tick is
# `engine.tick` with a spike-exchange route, so on an equivalent single-host
# layout (1-device mesh: same local batch, gid_base 0, all_to_all identity,
# exchange preserving relative message order) its per-tick trajectory must
# equal `network_tick` BITWISE — for the dense AND the worklist backend.
# (The historical `_local_tick` duplicated the tick body and was only
# allclose-checked on the lazy path.)
ONE_DEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ.setdefault("JAX_PLATFORMS", "cpu")  # see SCRIPT above
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import *
    from repro.core import distributed as DD

    p = test_scale(n_hcu=4, rows=64, cols=16)
    key = jax.random.PRNGKey(0)
    conn = make_connectivity(p, jax.random.fold_in(key, 1))
    mesh = jax.make_mesh((1,), ("hcu",))
    rc = DD.default_route_config(p, p.n_hcu)

    rng = np.random.default_rng(3)
    exts = []
    for _ in range(15):
        e = np.full((p.n_hcu, 8), p.rows, np.int32)
        for h in range(p.n_hcu):
            n = min(8, rng.poisson(3))
            e[h, :n] = rng.integers(0, p.rows, n)
        exts.append(jnp.asarray(e))

    for wl in (False, True):
        tick = DD.make_dist_tick(mesh, p, rc, axis="hcu", worklist=wl)
        s_d, c_d = DD.shard_network(mesh, init_network(p, key), conn)
        s_s = init_network(p, key)
        for k, e in enumerate(exts):
            s_d, f_d = tick(s_d, c_d, e)
            s_s, f_s = network_tick(s_s, conn, e, p, cap_fire=rc.cap_fire,
                                    worklist=wl)
            np.testing.assert_array_equal(np.asarray(f_d), np.asarray(f_s),
                                          err_msg=f"wl={wl} tick {k}")
        for name in s_d.hcus._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(s_d.hcus, name)),
                np.asarray(getattr(s_s.hcus, name)),
                err_msg=f"wl={wl} plane {name}")
        np.testing.assert_array_equal(np.asarray(s_d.delay_rows),
                                      np.asarray(s_s.delay_rows))
        np.testing.assert_array_equal(np.asarray(s_d.delay_count),
                                      np.asarray(s_s.delay_count))
        assert int(s_d.drops_in) == int(s_s.drops_in)
        print(f"worklist={wl} bitwise OK")
    print("ONEDEV_OK")
""")


def test_sharded_tick_equals_network_tick_both_backends():
    r = subprocess.run([sys.executable, "-c", ONE_DEV_SCRIPT],
                       capture_output=True, text=True,
                       env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    assert "ONEDEV_OK" in r.stdout
