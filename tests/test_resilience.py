"""Resilience layer: crash-restore-replay bitwise identity vs the head
fixtures, DRAM-retention bit-flip injection, drop-budget health accounting,
and restart-budget guards.

The crash tests re-run the exact trajectories pinned by
tests/test_engine_fixtures.py (same params, connectivity, staged input, RNG
key) through `ResilientRunner` with injected failures; restore-and-replay
must land bit-for-bit on the uninterrupted fixtures in every combination of
lazy/merged x dense/worklist.
"""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Connectivity, Simulator, test_scale as tiny_scale
from repro.core.params import BCPNNParams
from repro.runtime import (HealthMonitor, InjectedFailure, ResilientRunner,
                           RestartableLoop, RestartBudgetExceeded, flip_bits,
                           inject_retention_faults)

FIXTURES = pathlib.Path(__file__).resolve().parent / "fixtures"

# must match tests/fixtures/capture_head.py
LAZY_P = tiny_scale(n_hcu=4, rows=64, cols=16)
MERGED_P = BCPNNParams(n_hcu=4, rows=24, cols=16, fanout=4, active_queue=8,
                       max_delay=8, out_rate=0.6)

CASES = {
    "lazy_dense": (LAZY_P, dict(worklist=False)),
    "lazy_worklist": (LAZY_P, dict(worklist=True)),
    "merged_dense": (MERGED_P, dict(merged=True, worklist=False,
                                    cap_fire=MERGED_P.n_hcu)),
    "merged_worklist": (MERGED_P, dict(merged=True, worklist=True,
                                       cap_fire=MERGED_P.n_hcu)),
}


def _fixture_sim(name):
    p, kw = CASES[name]
    d = np.load(FIXTURES / f"head_{name}.npz")
    sim = Simulator(p, key=0, chunk=13, **kw)
    sim.conn = Connectivity(jnp.asarray(d["conn_dest_hcu"]),
                            jnp.asarray(d["conn_dest_row"]),
                            jnp.asarray(d["conn_delay"]))
    return sim, d


def _assert_matches(state, fired, d, name):
    np.testing.assert_array_equal(np.asarray(fired), d["fired"],
                                  err_msg=f"{name}: fired history")
    for f in state.hcus._fields:
        np.testing.assert_array_equal(np.asarray(getattr(state.hcus, f)),
                                      d[f"hcus_{f}"],
                                      err_msg=f"{name}: plane {f}")
    np.testing.assert_array_equal(np.asarray(state.delay_rows),
                                  d["delay_rows"], err_msg=name)
    np.testing.assert_array_equal(np.asarray(state.delay_count),
                                  d["delay_count"], err_msg=name)
    assert int(state.t) == int(d["t"])
    assert int(state.drops_in) == int(d["drops_in"])
    assert int(state.drops_fire) == int(d["drops_fire"])
    if "jring" in d:
        np.testing.assert_array_equal(np.asarray(state.jring), d["jring"],
                                      err_msg=name)


# ---------------------------------------------------------------------------
# fault class 1: crash-restore-replay is bitwise identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(CASES))
def test_crash_restore_replay_bitwise(name, tmp_path):
    """Two injected crashes; with save_every=2 the first hits before any
    checkpoint (scratch restart) and the second restores a checkpoint OLDER
    than the crash point (true replay of already-computed ticks). The
    recovered trajectory must be bit-for-bit the uninterrupted fixture."""
    sim, d = _fixture_sim(name)
    fails = {1, 2}

    def injector(chunk):
        if chunk in fails:
            fails.discard(chunk)
            return True
        return False

    runner = ResilientRunner(sim, str(tmp_path), chunk_ticks=13,
                             save_every=2, fail_injector=injector)
    fired, health = runner.run(jnp.asarray(d["ext"]))
    assert runner.restarts == 2 and not fails
    assert health["restarts"] == 2
    _assert_matches(sim.state, fired, d, name)


def test_crash_before_first_checkpoint_restarts_from_scratch(tmp_path):
    """A failure before any checkpoint lands must replay from the initial
    state (not the half-mutated live state) — still bitwise identical."""
    name = "lazy_worklist"
    sim, d = _fixture_sim(name)
    fails = {1}

    def injector(chunk):
        if chunk in fails:
            fails.discard(chunk)
            return True
        return False

    runner = ResilientRunner(sim, str(tmp_path), chunk_ticks=13,
                             save_every=1000, fail_injector=injector)
    fired, _ = runner.run(jnp.asarray(d["ext"]))
    assert runner.restarts == 1
    _assert_matches(sim.state, fired, d, name)


def test_resilient_runner_restart_budget(tmp_path):
    sim, d = _fixture_sim("lazy_dense")
    runner = ResilientRunner(sim, str(tmp_path), chunk_ticks=13,
                             save_every=1000, max_restarts=3,
                             fail_injector=lambda c: c == 0)
    with pytest.raises(RestartBudgetExceeded):
        runner.run(jnp.asarray(d["ext"]))
    assert runner.restarts == 4


def test_restartable_loop_budget_and_real_errors(tmp_path):
    """Always-failing injector with no checkpoint exhausts max_restarts;
    a real exception from step_fn propagates instead of being retried."""
    loop = RestartableLoop(str(tmp_path / "a"), save_every=1000,
                           fail_injector=lambda s: True, max_restarts=5)
    with pytest.raises(RestartBudgetExceeded):
        loop.run({"x": jnp.zeros(())}, lambda s, i: s, 10)
    assert loop.restarts == 6

    def bad_step(state, step):
        raise RuntimeError("real failure")

    loop2 = RestartableLoop(str(tmp_path / "b"), save_every=1000)
    with pytest.raises(RuntimeError, match="real failure"):
        loop2.run({"x": jnp.zeros(())}, bad_step, 10)
    assert loop2.restarts == 0


# ---------------------------------------------------------------------------
# fault class 2: retention bit flips
# ---------------------------------------------------------------------------

def test_flip_bits_rate_zero_is_bitwise_noop():
    x = jnp.linspace(-3.0, 7.0, 64).reshape(8, 8)
    for mode in ("flip", "clear", "set"):
        y = flip_bits(x, jax.random.PRNGKey(0), 0.0, mode=mode)
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_flip_bits_deterministic_and_modes():
    x = jnp.linspace(0.5, 9.5, 64).reshape(8, 8)
    k = jax.random.PRNGKey(3)
    a = flip_bits(x, k, 0.1)
    b = flip_bits(x, k, 0.1)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert (np.asarray(a) != np.asarray(x)).any()
    # clear only removes bits; set only adds them
    xb = np.asarray(jax.lax.bitcast_convert_type(x, jnp.uint32))
    cb = np.asarray(jax.lax.bitcast_convert_type(
        flip_bits(x, k, 0.5, mode="clear"), jnp.uint32))
    sb = np.asarray(jax.lax.bitcast_convert_type(
        flip_bits(x, k, 0.5, mode="set"), jnp.uint32))
    assert (cb & ~xb).sum() == 0
    assert (~sb & xb).sum() == 0
    with pytest.raises(ValueError):
        flip_bits(x, k, 0.1, mode="zap")


def test_flip_bits_bit_mask_sign_only():
    """rate=1 with a sign-bit mask negates every float exactly."""
    x = jnp.linspace(1.0, 4.0, 16)
    y = flip_bits(x, jax.random.PRNGKey(0), 1.0, bit_mask=1 << 31)
    np.testing.assert_array_equal(np.asarray(y), -np.asarray(x))


def test_inject_retention_faults_scope():
    """Only the named ij planes are corrupted; SRAM-resident state (queues,
    j-vectors, RNG key) stays bit-exact; rate 0 is a full no-op."""
    sim = Simulator(tiny_scale(n_hcu=2, rows=32, cols=16), key=0)
    st = sim.state
    z = inject_retention_faults(st, jax.random.PRNGKey(0), 0.0)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(z)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = inject_retention_faults(st, jax.random.PRNGKey(0), 0.05,
                                planes=("wij",))
    assert (np.asarray(c.hcus.wij) != np.asarray(st.hcus.wij)).any()
    for f in ("zij", "eij", "pij", "tij", "zi", "zj", "pj"):
        np.testing.assert_array_equal(np.asarray(getattr(c.hcus, f)),
                                      np.asarray(getattr(st.hcus, f)),
                                      err_msg=f)
    np.testing.assert_array_equal(np.asarray(c.delay_rows),
                                  np.asarray(st.delay_rows))
    with pytest.raises(ValueError):
        inject_retention_faults(st, jax.random.PRNGKey(0), 0.1,
                                planes=("zj",))


def test_corrupted_tij_timestamps_do_not_crash_engine():
    """The engine must keep running on a state whose timestamps were hit —
    graceful degradation, not a crash."""
    p = tiny_scale(n_hcu=2, rows=32, cols=16)
    sim = Simulator(p, key=0)
    ext = jnp.full((8, 2, p.active_queue), p.rows, jnp.int32)
    ext = ext.at[:, :, 0].set(3)
    sim.run(ext)
    sim.state = inject_retention_faults(sim.state, jax.random.PRNGKey(7),
                                        0.01)
    fired = sim.run(ext)
    assert fired.shape == (8, 2)


# ---------------------------------------------------------------------------
# fault class 3: health accounting
# ---------------------------------------------------------------------------

def _p():
    return tiny_scale(n_hcu=4, rows=32, cols=16)


def test_health_monitor_ok():
    mon = HealthMonitor(_p(), target_us_per_tick=1e9)
    mon.begin({"in": 5, "fire": 1})
    mon.chunk_start(10)
    mon.chunk_end(10, {"in": 5, "fire": 1})
    rep = mon.report()
    assert rep["status"] == "ok"
    assert rep["ticks"] == 10
    assert rep["drops"]["total"] == 0
    for key in ("budget", "deadline", "drops", "restarts"):
        assert key in rep
    assert rep["budget"]["expected_drops_run"] == pytest.approx(
        mon.expected_drops())


def test_health_monitor_over_budget():
    mon = HealthMonitor(_p(), target_us_per_tick=1e9)
    mon.begin({"in": 0, "fire": 0})
    mon.chunk_start(10)
    mon.chunk_end(10, {"in": 10_000_000, "fire": 0})
    rep = mon.report()
    assert rep["status"] == "over-budget"
    assert rep["budget"]["over_budget"] is True
    assert rep["drops"]["in"] == 10_000_000


def test_health_monitor_deadline_missed():
    mon = HealthMonitor(_p(), target_us_per_tick=0.0)
    mon.begin({"in": 0, "fire": 0})
    mon.chunk_start(10)
    mon.chunk_end(10, {"in": 0, "fire": 0})
    rep = mon.report()
    assert rep["status"] == "deadline-missed"
    assert rep["deadline"]["chunks_missed"] == 1
    # over-budget outranks deadline-missed
    mon.chunk_start(10)
    mon.chunk_end(10, {"in": 10_000_000, "fire": 0})
    assert mon.report()["status"] == "over-budget"


def test_health_monitor_per_class_budgets():
    from repro.core.distributed import RouteConfig
    mon = HealthMonitor(_p(), target_us_per_tick=1e9)
    mon.set_mesh(2, RouteConfig(cap_fire=2, cap_route=32))
    mon.begin({"in": 0, "fire": 0, "route": 0})
    mon.chunk_start(10)
    mon.chunk_end(10, {"in": 0, "fire": 0, "route": 0})
    b = mon.class_budgets()
    assert set(b) == {"in", "fire", "route"}
    assert all(v >= 0.0 for v in b.values())
    rep = mon.report()
    assert rep["status"] == "ok"
    assert set(rep["classes"]) == {"in", "fire", "route"}
    assert rep["budget"]["expected_drops_run"] == pytest.approx(
        sum(b.values()))
    # a single class blowing ITS budget flips the verdict
    mon.chunk_start(10)
    mon.chunk_end(10, {"in": 0, "fire": 0, "route": 10_000_000})
    rep = mon.report()
    assert rep["status"] == "over-budget"
    assert rep["classes"]["route"]["over"] is True
    assert rep["classes"]["in"]["over"] is False


def test_health_monitor_local_runs_budget_in_only():
    mon = HealthMonitor(_p(), target_us_per_tick=1e9)
    mon.begin({"in": 0, "fire": 0, "route": 0})
    mon.chunk_start(10)
    mon.chunk_end(10, {"in": 0, "fire": 0, "route": 0})
    assert set(mon.class_budgets()) == {"in"}


def test_simulator_drops_accessor():
    sim = Simulator(_p(), key=0)
    d = sim.drops()
    assert d == {"in": 0, "fire": 0, "route": 0}
    assert isinstance(d["in"], int)
