"""The assoc-memory protocol (`repro.experiments`) tested independently of
`benchmarks/resilience.py`: train/cue/recall round-trip at toy size, and the
`sram_loss` contract — recall from an sram_loss state must be carried by the
DRAM-resident ij planes (it dies under a full plane wipe), while WITHOUT
sram_loss the trained pj bias recalls part of the attractor regardless of
plane damage (which is exactly why the fault experiments always apply it).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BCPNNParams, Simulator
from repro.data import make_patterns
from repro.experiments import (assoc_params, drive_frame, recall_accuracy,
                               sram_loss, train_assoc, winners_from_fired)

# a faster sibling of `assoc_params` (8 HCUs, 6 MCUs, smaller planes) —
# trains in a few seconds at reps=10 and recalls at 1.0 from sram_loss
TOY = BCPNNParams(n_hcu=8, rows=48, cols=6, fanout=8, active_queue=16,
                  max_delay=4, mean_delay=1.5, out_rate=1.0,
                  wta_temp=0.25, tau_p=400.0)
N_PATTERNS = 3
CHANCE = 1.0 / TOY.cols


def _wipe_planes(state, p):
    """Full ij-plane wipe: every DRAM-resident synaptic plane back to its
    init values (the limit case of total retention loss)."""
    h = state.hcus
    return state._replace(hcus=h._replace(
        zij=jnp.zeros_like(h.zij), eij=jnp.zeros_like(h.eij),
        pij=jnp.full_like(h.pij, p.p_init * p.p_init),
        wij=jnp.zeros_like(h.wij), tij=jnp.zeros_like(h.tij)))


@pytest.fixture(scope="module")
def trained():
    """(sim, patterns, attractor, trained-state host copy) — trained once
    for the whole module."""
    sim = Simulator(TOY, key=0, cap_fire=TOY.n_hcu)
    patterns = make_patterns(TOY, N_PATTERNS, seed=3)
    attractor = train_assoc(sim, patterns, reps=10)
    return sim, patterns, attractor, jax.tree.map(np.array, sim.state)


def _acc(trained, corrupt):
    sim, patterns, attractor, state = trained
    correct, total = recall_accuracy(sim, state, patterns, attractor,
                                     rng=np.random.default_rng(0),
                                     corrupt=corrupt)
    assert total > 0
    return correct / total


def test_train_recall_roundtrip(trained):
    """Partial cues complete to the trained attractor far above chance."""
    _, _, attractor, _ = trained
    assert attractor.shape == (N_PATTERNS, TOY.n_hcu)
    assert (attractor >= 0).all() and (attractor < TOY.cols).all()
    assert _acc(trained, corrupt=None) >= 0.6 > 2 * CHANCE


def test_recall_survives_sram_loss(trained):
    """After the volatile j-side reset, the DRAM planes alone complete the
    patterns — the paper's memory-split claim."""
    acc = _acc(trained, corrupt=lambda s: sram_loss(s, TOY))
    assert acc >= 0.6


def test_sram_loss_recall_dies_under_plane_wipe(trained):
    """sram_loss + full ij-plane wipe leaves nothing to recall from: the
    protocol really does measure the planes."""
    acc = _acc(trained, corrupt=lambda s: _wipe_planes(sram_loss(s, TOY),
                                                       TOY))
    assert acc <= 0.25


def test_wipe_without_sram_loss_overstates_recall(trained):
    """WITHOUT sram_loss the trained pj bias keeps recalling above chance
    even with every plane wiped — the contract's reason to exist."""
    acc_bias = _acc(trained, corrupt=lambda s: _wipe_planes(s, TOY))
    acc_planes_gone = _acc(trained,
                           corrupt=lambda s: _wipe_planes(sram_loss(s, TOY),
                                                          TOY))
    assert acc_bias >= 1.5 * CHANCE
    assert acc_bias > acc_planes_gone


def test_assoc_params_protocol_shape():
    p = assoc_params()
    assert p.n_hcu == 12 and p.cols == 8
    assert p.tau_p > p.tau_e > p.tau_zi  # slow P traces hold the memory


def test_drive_frame_padding_semantics():
    p = TOY
    rows = np.arange(p.n_hcu, dtype=np.int64)
    mask = np.zeros(p.n_hcu, bool)
    mask[::2] = True
    frame = np.asarray(drive_frame(p, rows, mask))
    assert frame.shape[0] == p.n_hcu
    assert (frame[~mask] == p.rows).all()          # padding everywhere else
    assert (frame[mask, 0] == rows[mask]).all()    # cue row in slot 0
    assert (frame[mask, 1:] == p.rows).all()


def test_winners_from_fired_last_wins():
    fired = np.array([[1, -1], [-1, 3], [2, -1], [-1, -1]])
    assert winners_from_fired(fired).tolist() == [2, 3]
    assert winners_from_fired(np.full((4, 2), -1)).tolist() == [-1, -1]
