"""Property tests for the serving admission queue (`RequestQueue`),
mirroring the spike-queue tests in tests/test_queues.py: fixed capacity,
counted overflow, FIFO order.

Invariants under any offer/take interleaving:
  * conservation  — admitted + rejected + waiting == submitted
                    (no request lost or duplicated);
  * drop-on-full  — an offer is rejected exactly when the queue is at
                    capacity at offer time, never otherwise;
  * FIFO          — requests are admitted in submission order.

Runs under the optional-hypothesis shim (tests/hypothesis_compat.py): with
hypothesis installed these are property tests; without it they skip and the
deterministic `test_queue_basic_*` cases still cover the invariants.
"""
import numpy as np

from hypothesis_compat import given, settings, st
from repro.launch.serve_bcpnn import RecallRequest, RequestQueue


def _req(rid: int) -> RecallRequest:
    return RecallRequest(rid, np.zeros(2, np.int32), np.ones(2, bool))


def _drive(capacity: int, ops) -> tuple[RequestQueue, list, list]:
    """Apply an op sequence; return (queue, admitted rids, rejected rids)."""
    q = RequestQueue(capacity)
    admitted, rejected = [], []
    rid = 0
    for op in ops:
        if op < 0:                       # offer
            r = _req(rid)
            rid += 1
            was_full = len(q) >= q.capacity
            ok = q.offer(r)
            assert ok == (not was_full), "drop iff at capacity at offer time"
            assert r.status == ("queued" if ok else "rejected")
            if not ok:
                rejected.append(r.rid)
        else:                            # take up to `op` requests
            admitted.extend(r.rid for r in q.take(op))
    return q, admitted, rejected


@settings(max_examples=200, deadline=None)
@given(st.integers(min_value=1, max_value=5),
       st.lists(st.integers(min_value=-1, max_value=4), max_size=80))
def test_queue_invariants(capacity, ops):
    q, admitted, rejected = _drive(capacity, ops)
    # conservation: every submitted request is admitted, rejected or waiting
    assert q.admitted + q.rejected + len(q) == q.submitted
    assert len(admitted) == q.admitted and len(rejected) == q.rejected
    assert len(set(admitted)) == len(admitted), "no duplicates"
    assert not set(admitted) & set(rejected), "no request in two buckets"
    # FIFO: offers carry increasing rids, so admission order is increasing
    assert admitted == sorted(admitted)
    # capacity is never exceeded
    assert len(q) <= q.capacity


def test_queue_basic_conservation():
    q, admitted, rejected = _drive(2, [-1, -1, -1, 2, -1, -1, -1, 4])
    assert q.submitted == 6
    assert q.admitted + q.rejected + len(q) == 6
    assert admitted == sorted(admitted)


def test_queue_basic_fifo_and_free():
    q = RequestQueue(3)
    for rid in range(3):
        assert q.offer(_req(rid))
    assert q.free == 0
    assert not q.offer(_req(3))
    assert [r.rid for r in q.take(2)] == [0, 1]
    assert q.free == 2
    assert q.offer(_req(4))
    assert [r.rid for r in q.take(5)] == [2, 4]
    assert q.counters() == {"submitted": 5, "admitted": 4, "rejected": 1,
                            "waiting": 0, "capacity": 3}
