"""BCPNN recall serving engine: bitwise contract, slot recycling, queue
drop accounting (`repro.launch.serve_bcpnn`).

The load-bearing test is the bitwise one: every session served out of the
batched (S,)-lane stack must reproduce, bit for bit, the trajectory of an
independent single-session `Simulator.run` from the same template state —
the serving analogue of the head-fixture discipline (`_serve_step` runs
`jax.lax.map` over lanes so the per-lane graph IS `network._run_chunk`).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Simulator, test_scale as tiny_scale
from repro.launch.serve_bcpnn import BCPNNRecallServer, RecallRequest


def _toy_params():
    return tiny_scale(n_hcu=4, rows=48, cols=8)


def _warmed_sim(p, warm_ticks=8):
    """A Simulator with nontrivial planes/queues (random external drive)."""
    sim = Simulator(p, key=0, cap_fire=p.n_hcu)
    rng = np.random.default_rng(7)
    warm = rng.integers(0, p.rows, (warm_ticks, p.n_hcu, 4)).astype(np.int32)
    sim.run(jnp.asarray(warm))
    return sim


def _requests(p, n, rng, budget=15):
    return [RecallRequest(rid, rng.integers(0, p.rows, p.n_hcu),
                          rng.random(p.n_hcu) < 0.7, budget_ticks=budget)
            for rid in range(n)]


def _cue_ext(p, req, n_ticks, width=4):
    frame = np.full((p.n_hcu, width), p.rows, np.int32)
    mask = np.asarray(req.cue_mask, bool)
    frame[mask, 0] = np.asarray(req.cue_rows, np.int32)[mask]
    return np.broadcast_to(frame, (n_ticks,) + frame.shape)


def test_batched_sessions_bitwise_match_single_runs():
    """Acceptance criterion: batched multi-session recall trajectories ==
    N independent single-session Simulator runs, bitwise."""
    p = _toy_params()
    sim = _warmed_sim(p)
    srv = BCPNNRecallServer(sim, slots=3, queue_capacity=8, step_ticks=5)
    rng = np.random.default_rng(0)
    done = srv.run(_requests(p, 7, rng))
    assert len(done) == 7
    template = jax.tree.map(np.array, srv.template)
    for req in done:
        assert req.ticks % srv.step_ticks == 0 and req.ticks > 0
        ref = Simulator(p, key=0, cap_fire=p.n_hcu)   # same key -> same conn
        ref.state = jax.tree.map(jnp.asarray, template)
        f_ref = np.asarray(ref.run(
            jnp.asarray(_cue_ext(p, req, req.ticks)),
            chunk=srv.step_ticks))
        assert req.fired.shape == f_ref.shape
        assert (req.fired == f_ref).all(), \
            f"session {req.rid} diverged from its solo run"


def test_slot_recycling_serves_every_request_once():
    """Queue deeper than the slot count drains fully: every rid completed
    exactly once, lanes reused across waves."""
    p = _toy_params()
    sim = _warmed_sim(p)
    srv = BCPNNRecallServer(sim, slots=2, queue_capacity=16, step_ticks=5)
    rng = np.random.default_rng(1)
    n = 9
    done = srv.run(_requests(p, n, rng, budget=10))
    assert sorted(r.rid for r in done) == list(range(n))
    assert srv.queue.counters()["admitted"] == n
    assert srv.queue.counters()["rejected"] == 0
    assert len(srv.queue) == 0
    assert all(r.status in ("done", "expired") for r in done)
    # more admissions than slots forces recycling
    assert n > srv.slots


def test_budget_expiry_and_convergence_statuses():
    p = _toy_params()
    sim = _warmed_sim(p)
    srv = BCPNNRecallServer(sim, slots=2, queue_capacity=4, step_ticks=5)
    rng = np.random.default_rng(2)
    done = srv.run(_requests(p, 4, rng, budget=15))
    for r in done:
        if r.status == "expired":
            assert r.ticks >= r.budget_ticks
        else:
            assert r.status == "done"
            assert (r.winners >= 0).all()
        assert r.service_ms is not None and r.service_ms >= 0
        assert r.sojourn_ms >= r.service_ms
        assert set(r.drops) == {"in", "fire", "route"}
        assert all(v >= 0 for v in r.drops.values())


def test_queue_overflow_rejects_and_counts():
    p = _toy_params()
    sim = _warmed_sim(p)
    srv = BCPNNRecallServer(sim, slots=2, queue_capacity=2, step_ticks=5,
                            req_rate=1.0)
    rng = np.random.default_rng(3)
    reqs = _requests(p, 5, rng, budget=10)
    accepted = [srv.submit(r) for r in reqs]
    assert accepted == [True, True, False, False, False]
    assert [r.status for r in reqs] == \
        ["queued", "queued", "rejected", "rejected", "rejected"]
    c = srv.queue.counters()
    assert c["submitted"] == 5 and c["rejected"] == 3 and c["waiting"] == 2
    while srv.busy:
        srv.step()
    # rejections surface as the 'reject' drop class in the health report
    rep = srv.monitor.report()
    assert rep["drops"]["reject"] == 3
    assert "reject" in srv.monitor.class_budgets()


def test_health_monitor_prices_sessions_at_capacity():
    """The drop budget scales with n_hcu * slots (all lanes tick)."""
    p = _toy_params()
    sim = _warmed_sim(p)
    srv = BCPNNRecallServer(sim, slots=3, queue_capacity=4, step_ticks=5)
    rng = np.random.default_rng(4)
    srv.run(_requests(p, 3, rng, budget=10))
    assert srv.monitor.n_hcu == p.n_hcu * 3
    rep = srv.monitor.report()
    assert rep["ticks"] == srv.steps * srv.step_ticks
    assert {"in", "fire", "route", "reject"} <= set(rep["drops"])


def test_stats_schema_and_slo():
    p = _toy_params()
    sim = _warmed_sim(p)
    srv = BCPNNRecallServer(sim, slots=2, queue_capacity=4, step_ticks=5)
    rng = np.random.default_rng(5)
    srv.run(_requests(p, 3, rng, budget=10))
    s = srv.stats(slo_ms=1e9)
    assert s["completed"] == 3 == s["done"] + s["expired"]
    assert s["p95_service_ms"] > 0 and s["p95_sojourn_ms"] > 0
    assert s["slo_met"] is True
    assert s["health"]["status"] in ("ok", "over-budget", "deadline-missed")
    s2 = srv.stats(slo_ms=1e-9)
    assert s2["slo_met"] is False


def test_worklist_backend_sessions_bitwise_match():
    """The lane contract holds on the worklist backend too (forced — the
    toy size would select dense by the size guard)."""
    p = _toy_params()
    sim = Simulator(p, key=0, cap_fire=p.n_hcu, worklist=True)
    rng0 = np.random.default_rng(7)
    warm = rng0.integers(0, p.rows, (6, p.n_hcu, 4)).astype(np.int32)
    sim.run(jnp.asarray(warm))
    srv = BCPNNRecallServer(sim, slots=2, queue_capacity=4, step_ticks=5)
    rng = np.random.default_rng(6)
    done = srv.run(_requests(p, 3, rng, budget=10))
    template = jax.tree.map(np.array, srv.template)
    for req in done:
        ref = Simulator(p, key=0, cap_fire=p.n_hcu, worklist=True)
        ref.state = jax.tree.map(jnp.asarray, template)
        f_ref = np.asarray(ref.run(jnp.asarray(_cue_ext(p, req, req.ticks)),
                                   chunk=srv.step_ticks))
        assert (req.fired == f_ref).all()


def test_merged_mode_rejected():
    p = _toy_params()
    sim = Simulator(p, key=0, merged=True)
    with pytest.raises(NotImplementedError):
        BCPNNRecallServer(sim)
