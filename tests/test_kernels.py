"""Pallas kernel vs pure-jnp oracle, swept over shapes/dtypes (interpret mode).

Per-kernel allclose against ref.py as required: the kernel body executes in
Python on CPU via interpret=True; on a real TPU the same pallas_call lowers
to Mosaic.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.traces import make_coeffs
from repro.kernels import ops

K = make_coeffs(2.5, 100.0, 1000.0)
EPS = 1e-4


def _row_args(rng, S, C, tmax=100):
    return dict(
        zij=jnp.asarray(rng.uniform(0, 2, (S, C)), jnp.float32),
        eij=jnp.asarray(rng.uniform(0, 2, (S, C)), jnp.float32),
        pij=jnp.asarray(rng.uniform(1e-3, 1, (S, C)), jnp.float32),
        tij=jnp.asarray(rng.integers(0, tmax, (S, C)), jnp.int32),
        now=tmax,
        counts=jnp.asarray(rng.integers(0, 4, (S,)), jnp.float32),
        zj=jnp.asarray(rng.uniform(0, 2, (C,)), jnp.float32),
        p_i=jnp.asarray(rng.uniform(1e-3, 1, (S,)), jnp.float32),
        p_j=jnp.asarray(rng.uniform(1e-3, 1, (C,)), jnp.float32),
    )


@pytest.mark.parametrize("S,C", [(1, 1), (3, 17), (8, 100), (36, 100),
                                 (5, 128), (16, 256), (40, 100)])
def test_row_kernel_matches_ref_shapes(S, C):
    rng = np.random.default_rng(S * 1000 + C)
    a = _row_args(rng, S, C)
    ref = ops.row_update(**a, coeffs=K, eps=EPS, backend="ref")
    pal = ops.row_update(**a, coeffs=K, eps=EPS, backend="pallas_interpret")
    for r, p_, name in zip(ref, pal, "zepwt"):
        np.testing.assert_allclose(r, p_, rtol=3e-6, atol=3e-6,
                                   err_msg=f"plane {name} S={S} C={C}")


@pytest.mark.parametrize("R", [1, 100, 300, 1024, 1200, 2048])
def test_col_kernel_matches_ref_shapes(R):
    rng = np.random.default_rng(R)
    args = dict(
        z_col=jnp.asarray(rng.uniform(0, 2, (R,)), jnp.float32),
        e_col=jnp.asarray(rng.uniform(0, 2, (R,)), jnp.float32),
        p_col=jnp.asarray(rng.uniform(1e-3, 1, (R,)), jnp.float32),
        t_col=jnp.asarray(rng.integers(0, 60, (R,)), jnp.int32),
        now=60,
        zi_t=jnp.asarray(rng.uniform(0, 2, (R,)), jnp.float32),
        p_i=jnp.asarray(rng.uniform(1e-3, 1, (R,)), jnp.float32),
        p_j_scalar=0.37,
    )
    ref = ops.col_update(**args, coeffs=K, eps=EPS, backend="ref")
    pal = ops.col_update(**args, coeffs=K, eps=EPS, backend="pallas_interpret")
    for r, p_, name in zip(ref, pal, "zepwt"):
        np.testing.assert_allclose(r, p_, rtol=3e-6, atol=3e-6,
                                   err_msg=f"plane {name} R={R}")


@settings(max_examples=25, deadline=None)
@given(s=st.integers(1, 12), c=st.integers(1, 40),
       seed=st.integers(0, 2**31 - 1), now=st.integers(1, 10_000))
def test_row_kernel_property_sweep(s, c, seed, now):
    rng = np.random.default_rng(seed)
    a = _row_args(rng, s, c, tmax=now)
    ref = ops.row_update(**a, coeffs=K, eps=EPS, backend="ref")
    pal = ops.row_update(**a, coeffs=K, eps=EPS, backend="pallas_interpret")
    for r, p_ in zip(ref, pal):
        np.testing.assert_allclose(r, p_, rtol=1e-5, atol=1e-5)


def test_kernel_coeff_variants():
    """Different tau triplets (e.g. rodent vs human presets) stay correct."""
    for taus in [(2.5, 100.0, 1000.0), (5.0, 50.0, 500.0), (1.0, 20.0, 5000.0)]:
        k = make_coeffs(*taus)
        rng = np.random.default_rng(hash(taus) % 2**31)
        a = _row_args(rng, 8, 100)
        ref = ops.row_update(**a, coeffs=k, eps=EPS, backend="ref")
        pal = ops.row_update(**a, coeffs=k, eps=EPS,
                             backend="pallas_interpret")
        for r, p_ in zip(ref, pal):
            np.testing.assert_allclose(r, p_, rtol=3e-6, atol=3e-6)


def test_padding_cells_do_not_leak():
    """Padded lanes/rows must not alter logical outputs: results for a
    (S, C) block must be independent of the padding added to reach tiles."""
    rng = np.random.default_rng(0)
    a = _row_args(rng, 9, 37)          # forces both-dim padding
    out_a = ops.row_update(**a, coeffs=K, eps=EPS,
                           backend="pallas_interpret")
    # same logical content embedded in a bigger call via ref on exact shapes
    out_b = ops.row_update(**a, coeffs=K, eps=EPS, backend="ref")
    for x, y in zip(out_a, out_b):
        assert x.shape == y.shape == (9, 37)
        np.testing.assert_allclose(x, y, rtol=3e-6, atol=3e-6)


def _worklist_args(rng, HR, C, W, rows_list, nv, tmax=100):
    rows = jnp.asarray(list(rows_list) + [HR] * (W - len(rows_list)),
                       jnp.int32)
    return dict(
        zij=jnp.asarray(rng.uniform(0, 2, (HR, C)), jnp.float32),
        eij=jnp.asarray(rng.uniform(0, 2, (HR, C)), jnp.float32),
        pij=jnp.asarray(rng.uniform(1e-3, 1, (HR, C)), jnp.float32),
        wij=jnp.asarray(rng.uniform(-1, 1, (HR, C)), jnp.float32),
        tij=jnp.asarray(rng.integers(0, tmax, (HR, C)), jnp.int32),
        rows=rows, nv=nv, now=tmax,
        counts=jnp.asarray(rng.integers(0, 4, (W,)), jnp.float32),
        zj=jnp.asarray(rng.uniform(0, 2, (W, C)), jnp.float32),
        p_i=jnp.asarray(rng.uniform(1e-3, 1, (W,)), jnp.float32),
        pj=jnp.asarray(rng.uniform(1e-3, 1, (W, C)), jnp.float32),
    )


def _worklist_expected(a, HR, C, nv):
    """Per-entry bcpnn_ref oracle applied to the touched rows only."""
    from repro.kernels import bcpnn_ref
    exp = [np.array(a[k]) for k in ("zij", "eij", "pij", "wij", "tij")]
    for e in range(nv):
        r = int(a["rows"][e])
        z1, e1, p1, w1, t1 = bcpnn_ref.row_update_ref(
            a["zij"][r:r + 1], a["eij"][r:r + 1], a["pij"][r:r + 1],
            a["tij"][r:r + 1], a["now"], a["counts"][e:e + 1], a["zj"][e],
            a["p_i"][e:e + 1], a["pj"][e], K, EPS)
        for plane, val in zip(exp, (z1, e1, p1, w1, t1)):
            plane[r] = np.asarray(val)[0]
    return exp


@pytest.mark.parametrize("HR,C,W,rows,nv", [
    (32, 128, 8, (3, 7, 11, 30), 4),       # aligned, no padding
    (256, 16, 24, (1, 4, 66, 89, 128, 199, 255), 7),   # lane padding
    (40, 100, 8, (0, 39), 2),              # both-dim padding
    (32, 128, 8, (), 0),                   # empty worklist
])
def test_worklist_kernel_matches_ref(HR, C, W, rows, nv):
    """Scalar-prefetch worklist kernel (interpret mode) vs per-row oracle:
    touched rows update, untouched rows (and rows aliased by padding
    entries) stay bit-identical."""
    rng = np.random.default_rng(HR * 1000 + C)
    a = _worklist_args(rng, HR, C, W, rows, nv)
    out = ops.worklist_row_update(**a, coeffs=K, eps=EPS,
                                  backend="pallas_interpret")
    exp = _worklist_expected(a, HR, C, nv)
    untouched = np.setdiff1d(np.arange(HR), np.asarray(rows[:nv], int))
    for o, ex, name in zip(out, exp, "zepwt"):
        o = np.asarray(o)
        np.testing.assert_allclose(o, ex, rtol=3e-6, atol=3e-6,
                                   err_msg=f"plane {name}")
        # untouched rows must be EXACTLY preserved (in-place contract)
        np.testing.assert_array_equal(o[untouched], ex[untouched],
                                      err_msg=f"untouched rows, plane {name}")


def test_worklist_kernel_padding_entries_are_noops():
    """Entries at/past nv (incl. the H*R sentinel) must not perturb any row
    even when clipped onto real row indices."""
    rng = np.random.default_rng(0)
    a = _worklist_args(rng, 32, 128, 8, (1, 4), 2)
    # poison the padding entries with in-range rows that are also touched
    a["rows"] = jnp.asarray([1, 4, 1, 4, 0, 31, 32, 32], jnp.int32)
    out = ops.worklist_row_update(**a, coeffs=K, eps=EPS,
                                  backend="pallas_interpret")
    exp = _worklist_expected(a, 32, 128, 2)
    for o, ex, name in zip(out, exp, "zepwt"):
        np.testing.assert_allclose(np.asarray(o), ex, rtol=3e-6, atol=3e-6,
                                   err_msg=f"plane {name}")


def _fused_args(rng, HR, C, W, rows_list, tmax=100):
    """Slot-ordered args for the fused megakernel: `rows` carries the HR
    sentinel on invalid slots (no compaction)."""
    rows = jnp.asarray(list(rows_list) + [HR] * (W - len(rows_list)),
                       jnp.int32)
    return dict(
        zij=jnp.asarray(rng.uniform(0, 2, (HR, C)), jnp.float32),
        eij=jnp.asarray(rng.uniform(0, 2, (HR, C)), jnp.float32),
        pij=jnp.asarray(rng.uniform(1e-3, 1, (HR, C)), jnp.float32),
        wij=jnp.asarray(rng.uniform(-1, 1, (HR, C)), jnp.float32),
        tij=jnp.asarray(rng.integers(0, tmax, (HR, C)), jnp.int32),
        zi=jnp.asarray(rng.uniform(0, 2, (HR,)), jnp.float32),
        ei=jnp.asarray(rng.uniform(0, 2, (HR,)), jnp.float32),
        pi=jnp.asarray(rng.uniform(1e-3, 1, (HR,)), jnp.float32),
        ti=jnp.asarray(rng.integers(0, tmax, (HR,)), jnp.int32),
        rows=rows, now=tmax,
        counts=jnp.asarray(rng.integers(0, 4, (W,)), jnp.float32),
        zj=jnp.asarray(rng.uniform(0, 2, (W, C)), jnp.float32),
        p_i=jnp.asarray(rng.uniform(1e-3, 1, (W,)), jnp.float32),
        pj=jnp.asarray(rng.uniform(1e-3, 1, (W, C)), jnp.float32),
        zi_new=jnp.asarray(rng.uniform(0, 3, (W,)), jnp.float32),
        ei_new=jnp.asarray(rng.uniform(0, 2, (W,)), jnp.float32),
        pi_new=jnp.asarray(rng.uniform(1e-3, 1, (W,)), jnp.float32),
    )


def _fused_expected(a, HR, C, W):
    """Per-entry bcpnn_ref oracle for the fused megakernel: planes, the
    in-place i-vector rewrite and the per-slot weight-row output."""
    from repro.kernels import bcpnn_ref
    exp = [np.array(a[k]) for k in ("zij", "eij", "pij", "wij", "tij")]
    iv = [np.array(a[k]) for k in ("zi", "ei", "pi", "ti")]
    w_rows = np.zeros((W, C), np.float32)
    for e in range(W):
        r = int(a["rows"][e])
        if r >= HR:
            continue
        z1, e1, p1, w1, t1 = bcpnn_ref.row_update_ref(
            a["zij"][r:r + 1], a["eij"][r:r + 1], a["pij"][r:r + 1],
            a["tij"][r:r + 1], a["now"], a["counts"][e:e + 1], a["zj"][e],
            a["p_i"][e:e + 1], a["pj"][e], K, EPS)
        for plane, val in zip(exp, (z1, e1, p1, w1, t1)):
            plane[r] = np.asarray(val)[0]
        iv[0][r] = float(a["zi_new"][e])
        iv[1][r] = float(a["ei_new"][e])
        iv[2][r] = float(a["pi_new"][e])
        iv[3][r] = a["now"]
        w_rows[e] = np.asarray(w1)[0]
    return exp, iv, w_rows


@pytest.mark.parametrize("HR,C,W,rows", [
    (32, 128, 8, (3, 7, 11, 30)),          # aligned, no padding
    (256, 16, 24, (1, 4, 66, 89, 128, 199, 255)),      # lane padding
    (40, 100, 8, (0, 39)),                 # both-dim padding
    (32, 128, 8, ()),                      # empty worklist
])
def test_fused_megakernel_matches_ref(HR, C, W, rows):
    """The fused row-phase megakernel (interpret mode) vs the per-row
    oracle: ij planes, i-vectors and the per-slot weight rows all match;
    untouched rows / i-vector cells stay EXACTLY preserved (in-place
    aliasing contract)."""
    rng = np.random.default_rng(HR * 1000 + C)
    a = _fused_args(rng, HR, C, W, rows)
    flats, ivecs, w_out = ops.fused_row_update(
        **a, coeffs=K, eps=EPS, backend="pallas_interpret")
    exp, iv_exp, w_exp = _fused_expected(a, HR, C, W)
    untouched = np.setdiff1d(np.arange(HR), np.asarray(rows, int))
    for o, ex, name in zip(flats, exp, "zepwt"):
        o = np.asarray(o)
        np.testing.assert_allclose(o, ex, rtol=3e-6, atol=3e-6,
                                   err_msg=f"plane {name}")
        np.testing.assert_array_equal(o[untouched], ex[untouched],
                                      err_msg=f"untouched rows, plane {name}")
    for o, ex, name in zip(ivecs, iv_exp, ("zi", "ei", "pi", "ti")):
        # i-vector writes are pure data movement -> exact everywhere
        np.testing.assert_array_equal(np.asarray(o), ex,
                                      err_msg=f"i-vector {name}")
    np.testing.assert_allclose(np.asarray(w_out), w_exp, rtol=3e-6,
                               atol=3e-6, err_msg="weight rows")


def _fused_col_args(rng, H_, R, C, cap, fired, tmax=100):
    """Fired-batch args for the fused column megakernel: `fired` is a list
    of (h, j) pairs; padding slots carry h == H_ (the select_fired
    sentinel)."""
    HR = H_ * R
    h_idx = jnp.asarray([h for h, _ in fired] + [H_] * (cap - len(fired)),
                        jnp.int32)
    j_idx = jnp.asarray([j for _, j in fired] + [0] * (cap - len(fired)),
                        jnp.int32)
    return dict(
        zij=jnp.asarray(rng.uniform(0, 2, (HR, C)), jnp.float32),
        eij=jnp.asarray(rng.uniform(0, 2, (HR, C)), jnp.float32),
        pij=jnp.asarray(rng.uniform(1e-3, 1, (HR, C)), jnp.float32),
        wij=jnp.asarray(rng.uniform(-1, 1, (HR, C)), jnp.float32),
        tij=jnp.asarray(rng.integers(0, tmax, (HR, C)), jnp.int32),
        h_idx=h_idx, j_idx=j_idx, now=tmax,
        zi_t=jnp.asarray(rng.uniform(0, 2, (cap, R)), jnp.float32),
        p_i=jnp.asarray(rng.uniform(1e-3, 1, (cap, R)), jnp.float32),
        pj_sc=jnp.asarray(rng.uniform(1e-3, 1, (cap,)), jnp.float32),
    )


def _fused_col_expected(a, H_, R, cap):
    """Per-entry bcpnn_ref column oracle applied to the fired (R, 1) column
    blocks of the flat planes only."""
    from repro.kernels import bcpnn_ref
    exp = [np.array(a[k]) for k in ("zij", "eij", "pij", "wij", "tij")]
    for e in range(cap):
        h, j = int(a["h_idx"][e]), int(a["j_idx"][e])
        if h >= H_:
            continue
        sl = slice(h * R, (h + 1) * R)
        z1, e1, p1, w1, t1 = bcpnn_ref.col_update_ref(
            a["zij"][sl, j], a["eij"][sl, j], a["pij"][sl, j],
            a["tij"][sl, j], a["now"], a["zi_t"][e], a["p_i"][e],
            a["pj_sc"][e], K, EPS)
        for plane, val in zip(exp, (z1, e1, p1, w1, t1)):
            plane[sl, j] = np.asarray(val)
    return exp


@pytest.mark.parametrize("H_,R,C,fired", [
    (4, 32, 128, [(0, 3), (2, 100), (3, 127)]),   # lane-aligned C
    (3, 40, 100, [(1, 0), (2, 99)]),              # lane padding (junk col)
    (2, 64, 16, []),                              # nothing fired
])
def test_fused_col_megakernel_matches_ref(H_, R, C, fired):
    """The fused column-phase megakernel (interpret mode) vs the per-column
    oracle: fired (R, 1) column blocks update (Tij stamped in-kernel),
    every untouched cell stays EXACTLY preserved (in-place aliasing
    contract)."""
    rng = np.random.default_rng(H_ * 1000 + R)
    cap = 6
    a = _fused_col_args(rng, H_, R, C, cap, fired)
    out = ops.fused_col_update(
        a["zij"], a["eij"], a["pij"], a["wij"], a["tij"],
        h_idx=a["h_idx"], j_idx=a["j_idx"], now=a["now"],
        zi_t=a["zi_t"], p_i=a["p_i"], pj_sc=a["pj_sc"],
        coeffs=K, eps=EPS, n_hcu=H_, rows=R,
        backend="pallas_interpret")
    exp = _fused_col_expected(a, H_, R, cap)
    touched = np.zeros((H_ * R, C), bool)
    for h, j in fired:
        touched[h * R:(h + 1) * R, j] = True
    for o, ex, name in zip(out, exp, "zepwt"):
        o = np.asarray(o)
        np.testing.assert_allclose(o, ex, rtol=3e-6, atol=3e-6,
                                   err_msg=f"plane {name}")
        np.testing.assert_array_equal(o[~touched], ex[~touched],
                                      err_msg=f"untouched cells, plane {name}")


def test_fused_col_megakernel_padding_entries_are_noops():
    """Padding fired-batch entries (h_idx == n_hcu, the select_fired
    sentinel) must not perturb ANY cell even when their j_idx aliases a
    genuinely fired column — the junk-lane rerouting plus the in-kernel
    valid gate make them pass-throughs."""
    rng = np.random.default_rng(2)
    H_, R, C, cap = 3, 32, 100, 6
    a = _fused_col_args(rng, H_, R, C, cap, [(0, 7), (2, 50)])
    # poison the padding entries: in-range (h, j) pairs that alias fired and
    # unfired columns alike — only the h_idx == H_ sentinel marks them
    a["h_idx"] = jnp.asarray([0, 2, H_, H_, H_, H_], jnp.int32)
    a["j_idx"] = jnp.asarray([7, 50, 7, 50, 0, 99], jnp.int32)
    out = ops.fused_col_update(
        a["zij"], a["eij"], a["pij"], a["wij"], a["tij"],
        h_idx=a["h_idx"], j_idx=a["j_idx"], now=a["now"],
        zi_t=a["zi_t"], p_i=a["p_i"], pj_sc=a["pj_sc"],
        coeffs=K, eps=EPS, n_hcu=H_, rows=R,
        backend="pallas_interpret")
    exp = _fused_col_expected(a, H_, R, cap)
    for o, ex, name in zip(out, exp, "zepwt"):
        np.testing.assert_allclose(np.asarray(o), ex, rtol=3e-6, atol=3e-6,
                                   err_msg=f"plane {name}")


def test_fused_megakernel_sentinel_slots_are_noops():
    """Interleaved sentinel slots (slot order, no compaction) must leave
    every plane row and i-vector cell untouched, and emit zero weight rows
    for those slots."""
    rng = np.random.default_rng(1)
    HR, C, W = 32, 128, 8
    a = _fused_args(rng, HR, C, W, ())
    # valid slots 1 and 5; everything else the HR sentinel
    a["rows"] = jnp.asarray([HR, 3, HR, HR, HR, 17, HR, HR], jnp.int32)
    flats, ivecs, w_out = ops.fused_row_update(
        **a, coeffs=K, eps=EPS, backend="pallas_interpret")
    exp, iv_exp, w_exp = _fused_expected(a, HR, C, W)
    for o, ex, name in zip(flats, exp, "zepwt"):
        np.testing.assert_allclose(np.asarray(o), ex, rtol=3e-6, atol=3e-6,
                                   err_msg=f"plane {name}")
    for o, ex, name in zip(ivecs, iv_exp, ("zi", "ei", "pi", "ti")):
        np.testing.assert_array_equal(np.asarray(o), ex,
                                      err_msg=f"i-vector {name}")
    assert np.all(np.asarray(w_out)[[0, 2, 3, 4, 6, 7]] == 0.0), \
        "sentinel slots must emit zero weight rows"
    np.testing.assert_allclose(np.asarray(w_out), w_exp, rtol=3e-6, atol=3e-6)
