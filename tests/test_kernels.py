"""Pallas kernel vs pure-jnp oracle, swept over shapes/dtypes (interpret mode).

Per-kernel allclose against ref.py as required: the kernel body executes in
Python on CPU via interpret=True; on a real TPU the same pallas_call lowers
to Mosaic.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.traces import make_coeffs
from repro.kernels import ops

K = make_coeffs(2.5, 100.0, 1000.0)
EPS = 1e-4


def _row_args(rng, S, C, tmax=100):
    return dict(
        zij=jnp.asarray(rng.uniform(0, 2, (S, C)), jnp.float32),
        eij=jnp.asarray(rng.uniform(0, 2, (S, C)), jnp.float32),
        pij=jnp.asarray(rng.uniform(1e-3, 1, (S, C)), jnp.float32),
        tij=jnp.asarray(rng.integers(0, tmax, (S, C)), jnp.int32),
        now=tmax,
        counts=jnp.asarray(rng.integers(0, 4, (S,)), jnp.float32),
        zj=jnp.asarray(rng.uniform(0, 2, (C,)), jnp.float32),
        p_i=jnp.asarray(rng.uniform(1e-3, 1, (S,)), jnp.float32),
        p_j=jnp.asarray(rng.uniform(1e-3, 1, (C,)), jnp.float32),
    )


@pytest.mark.parametrize("S,C", [(1, 1), (3, 17), (8, 100), (36, 100),
                                 (5, 128), (16, 256), (40, 100)])
def test_row_kernel_matches_ref_shapes(S, C):
    rng = np.random.default_rng(S * 1000 + C)
    a = _row_args(rng, S, C)
    ref = ops.row_update(**a, coeffs=K, eps=EPS, backend="ref")
    pal = ops.row_update(**a, coeffs=K, eps=EPS, backend="pallas_interpret")
    for r, p_, name in zip(ref, pal, "zepwt"):
        np.testing.assert_allclose(r, p_, rtol=3e-6, atol=3e-6,
                                   err_msg=f"plane {name} S={S} C={C}")


@pytest.mark.parametrize("R", [1, 100, 300, 1024, 1200, 2048])
def test_col_kernel_matches_ref_shapes(R):
    rng = np.random.default_rng(R)
    args = dict(
        z_col=jnp.asarray(rng.uniform(0, 2, (R,)), jnp.float32),
        e_col=jnp.asarray(rng.uniform(0, 2, (R,)), jnp.float32),
        p_col=jnp.asarray(rng.uniform(1e-3, 1, (R,)), jnp.float32),
        t_col=jnp.asarray(rng.integers(0, 60, (R,)), jnp.int32),
        now=60,
        zi_t=jnp.asarray(rng.uniform(0, 2, (R,)), jnp.float32),
        p_i=jnp.asarray(rng.uniform(1e-3, 1, (R,)), jnp.float32),
        p_j_scalar=0.37,
    )
    ref = ops.col_update(**args, coeffs=K, eps=EPS, backend="ref")
    pal = ops.col_update(**args, coeffs=K, eps=EPS, backend="pallas_interpret")
    for r, p_, name in zip(ref, pal, "zepwt"):
        np.testing.assert_allclose(r, p_, rtol=3e-6, atol=3e-6,
                                   err_msg=f"plane {name} R={R}")


@settings(max_examples=25, deadline=None)
@given(s=st.integers(1, 12), c=st.integers(1, 40),
       seed=st.integers(0, 2**31 - 1), now=st.integers(1, 10_000))
def test_row_kernel_property_sweep(s, c, seed, now):
    rng = np.random.default_rng(seed)
    a = _row_args(rng, s, c, tmax=now)
    ref = ops.row_update(**a, coeffs=K, eps=EPS, backend="ref")
    pal = ops.row_update(**a, coeffs=K, eps=EPS, backend="pallas_interpret")
    for r, p_ in zip(ref, pal):
        np.testing.assert_allclose(r, p_, rtol=1e-5, atol=1e-5)


def test_kernel_coeff_variants():
    """Different tau triplets (e.g. rodent vs human presets) stay correct."""
    for taus in [(2.5, 100.0, 1000.0), (5.0, 50.0, 500.0), (1.0, 20.0, 5000.0)]:
        k = make_coeffs(*taus)
        rng = np.random.default_rng(hash(taus) % 2**31)
        a = _row_args(rng, 8, 100)
        ref = ops.row_update(**a, coeffs=k, eps=EPS, backend="ref")
        pal = ops.row_update(**a, coeffs=k, eps=EPS,
                             backend="pallas_interpret")
        for r, p_ in zip(ref, pal):
            np.testing.assert_allclose(r, p_, rtol=3e-6, atol=3e-6)


def test_padding_cells_do_not_leak():
    """Padded lanes/rows must not alter logical outputs: results for a
    (S, C) block must be independent of the padding added to reach tiles."""
    rng = np.random.default_rng(0)
    a = _row_args(rng, 9, 37)          # forces both-dim padding
    out_a = ops.row_update(**a, coeffs=K, eps=EPS,
                           backend="pallas_interpret")
    # same logical content embedded in a bigger call via ref on exact shapes
    out_b = ops.row_update(**a, coeffs=K, eps=EPS, backend="ref")
    for x, y in zip(out_a, out_b):
        assert x.shape == y.shape == (9, 37)
        np.testing.assert_allclose(x, y, rtol=3e-6, atol=3e-6)
