# Tier-1 verification (mirrors .github/workflows/ci.yml)
PY ?= python

.PHONY: verify test bench bench-json profile resilience weak-scaling \
	check-pycache ci-local

verify: test bench

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run --fast

# full wall-clock benchmarks + BENCH_tick_loop.json (perf trajectory);
# --legacy-cpu pins the XLA CPU runtime the committed numbers use; the
# README bench table is regenerated from the fresh JSON (same bytes)
bench-json:
	PYTHONPATH=src $(PY) -m benchmarks.run --fast --json --legacy-cpu
	PYTHONPATH=src $(PY) -m benchmarks.render_bench_table

# tick-loop numbers (default + rodent16 + human_col) plus the per-phase
# scan-context ablation (queue / row / WTA / column, measured as deltas on
# the scan path itself) written to BENCH_phase_breakdown.json — read
# docs/BENCHMARKING.md before trusting the isolated numbers also printed
profile: bench-json
	PYTHONPATH=src $(PY) -m benchmarks.profile_phases --legacy-cpu

# fault-injection suite (incl. the multi-device elastic smoke — forced
# host-platform device count, subprocess-isolated) + resilience telemetry
# (BENCH_resilience.json: recall-vs-bit-flip-rate curves, rodent16
# drop-budget health report, device-loss recovery scenario) + the sanity
# gate on the fault-free recall path and the device-loss bitwise contract;
# mirrors the CI `resilience` job (see docs/RESILIENCE.md)
resilience:
	PYTHONPATH=src $(PY) -m pytest -x -q tests/test_resilience.py tests/test_checkpoint.py tests/test_elastic.py
	PYTHONPATH=src $(PY) -m benchmarks.resilience --legacy-cpu
	PYTHONPATH=src $(PY) -m benchmarks.check_resilience

# weak-scaling sweep of the sharded runtime (BENCH_weak_scaling.json):
# forced host-platform device counts 1/2/4 at fixed HCUs/device, sparse
# capacity-bounded spike exchange overlapped with the column phase, plus a
# mid-sweep elastic remesh leg; mirrors the CI `weak-scaling` job (see
# docs/BENCHMARKING.md for the JSON schema and the gated contract)
weak-scaling:
	PYTHONPATH=src $(PY) -m benchmarks.weak_scaling --legacy-cpu

# fail if bytecode artifacts ever get committed (nested __pycache__ dirs
# included); CI runs this in the `tests` job
check-pycache:
	@if git ls-files | grep -E '(^|/)__pycache__(/|$$)|\.py[co]$$'; then \
		echo "ERROR: tracked bytecode artifacts (see above)"; exit 1; \
	else echo "no tracked bytecode"; fi

# the exact CI sequence (tests + bench-gate + weak-scaling + resilience
# jobs), runnable locally so a gate failure can be reproduced without
# pushing: pycache guard -> README bench-table drift guard (BEFORE any
# bench regeneration — the table must match the COMMITTED JSON, and a
# fresh measurement would make it spuriously stale) -> tier-1 tests (incl.
# the flat-vs-blocked layout A/B fixture tests and the sparse-route
# capacity/drop tests) -> fast benchmarks -> tick-loop regression gate vs
# the COMMITTED JSON (taken from HEAD, not the working tree, so repeated
# runs cannot compound a slow drift past the gate; note the fresh
# measurement is left in BENCH_tick_loop.json afterwards, same as `make
# bench-json`) -> per-phase ablation artifact + the human_col column-phase
# gate (the phase the PR 8 column-blocked layout targets) -> the Fig 10
# layout benchmark (BENCH_layout.json: paper DRAM model + tile models +
# measured CPU flat/blocked A/B) + its layout-model gate -> the serving
# benchmark (BENCH_serving.json: continuous-batching recall QPS at
# rodent16) + its QPS-at-SLO gate -> the weak-scaling sweep
# (BENCH_weak_scaling.json) + its ratio/route-drop gate -> resilience
# telemetry + gate (the fault-injection tests already ran inside `test`)
ci-local: check-pycache
	PYTHONPATH=src $(PY) -m benchmarks.render_bench_table
	git diff --exit-code README.md
	$(MAKE) test bench
	git show HEAD:BENCH_tick_loop.json > /tmp/BENCH_committed.json
	git show HEAD:BENCH_phase_breakdown.json > /tmp/BENCH_phase_committed.json
	git show HEAD:BENCH_serving.json > /tmp/BENCH_serving_committed.json
	git show HEAD:BENCH_layout.json > /tmp/BENCH_layout_committed.json
	git show HEAD:BENCH_weak_scaling.json > /tmp/BENCH_weak_committed.json
	PYTHONPATH=src $(PY) -m benchmarks.run --fast --json --legacy-cpu
	PYTHONPATH=src $(PY) -m benchmarks.check_regression \
		--committed /tmp/BENCH_committed.json
	PYTHONPATH=src $(PY) -m benchmarks.profile_phases --legacy-cpu
	PYTHONPATH=src $(PY) -m benchmarks.check_regression \
		--committed /tmp/BENCH_committed.json \
		--phase-committed /tmp/BENCH_phase_committed.json
	PYTHONPATH=src $(PY) -m benchmarks.fig10_rowmerge --legacy-cpu
	PYTHONPATH=src $(PY) -m benchmarks.check_regression \
		--layout-committed /tmp/BENCH_layout_committed.json
	PYTHONPATH=src $(PY) -m benchmarks.serve_bcpnn --legacy-cpu
	PYTHONPATH=src $(PY) -m benchmarks.check_regression \
		--committed /tmp/BENCH_committed.json \
		--serving-committed /tmp/BENCH_serving_committed.json
	PYTHONPATH=src $(PY) -m benchmarks.weak_scaling --legacy-cpu
	PYTHONPATH=src $(PY) -m benchmarks.check_regression \
		--weak-scaling-committed /tmp/BENCH_weak_committed.json
	PYTHONPATH=src $(PY) -m benchmarks.resilience --legacy-cpu
	PYTHONPATH=src $(PY) -m benchmarks.check_resilience
