# Tier-1 verification (mirrors .github/workflows/ci.yml)
PY ?= python

.PHONY: verify test bench bench-json profile

verify: test bench

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run --fast

# full wall-clock benchmarks + BENCH_tick_loop.json (perf trajectory);
# --legacy-cpu pins the XLA CPU runtime the committed numbers use; the
# README bench table is regenerated from the fresh JSON (same bytes)
bench-json:
	PYTHONPATH=src $(PY) -m benchmarks.run --fast --json --legacy-cpu
	PYTHONPATH=src $(PY) -m benchmarks.render_bench_table

# tick-loop numbers (default + rodent16 + human_col) plus the per-phase
# breakdown (row-update / column-update / WTA / queue) that guides the next
# perf PR — read docs/BENCHMARKING.md before trusting the isolated numbers
profile: bench-json
	PYTHONPATH=src $(PY) -m benchmarks.profile_phases --legacy-cpu
