# Tier-1 verification (mirrors .github/workflows/ci.yml)
PY ?= python

.PHONY: verify test bench bench-json

verify: test bench

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run --fast

# full wall-clock benchmarks + BENCH_tick_loop.json (perf trajectory);
# --legacy-cpu pins the XLA CPU runtime the committed numbers use
bench-json:
	PYTHONPATH=src $(PY) -m benchmarks.run --fast --json --legacy-cpu
