"""End-to-end LM training driver (deliverable b): train a ~100M-class model
for a few hundred steps on synthetic data with the production loop
(sharded params, jit step, async checkpoints, straggler monitor).

  PYTHONPATH=src python examples/train_lm.py [--arch xlstm-125m] [--steps 200]

On CPU this uses the reduced config; on a pod, drop --smoke for the full
config and production mesh. Loss target: the Markov stream's entropy floor
is log(4) ~ 1.39 nats; anything approaching it from log(vocab) ~ 6.2 shows
the whole substrate (model, optimizer, data, checkpointing) learning.
"""
import argparse

import numpy as np

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    _, losses = train(args.arch, steps=args.steps, batch=args.batch,
                      seq=args.seq, smoke=True, ckpt_dir=args.ckpt,
                      lr=args.lr, log_every=20)
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"\nloss: {first:.3f} -> {last:.3f} "
          f"(entropy floor ~1.386; started near log(512)=6.24)")
    assert last < first, "training must reduce loss"


if __name__ == "__main__":
    main()
