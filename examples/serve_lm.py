"""Batched serving example: continuous batching over the ServingEngine.

  PYTHONPATH=src python examples/serve_lm.py [--arch qwen2-1.5b]

Submits a queue of requests with random prompts, serves them in fixed-slot
waves (prefill + step-synchronous decode with KV caches), and verifies that
greedy engine output matches the reference generate() path token-for-token.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.serve import Request, ServingEngine
from repro.models.transformer import Model
from repro.train.serve_step import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--n-requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, args.prompt_len)
               for _ in range(args.n_requests)]

    eng = ServingEngine(model, params, args.batch,
                        args.prompt_len + args.max_new + 8)
    for rid, pr in enumerate(prompts):
        eng.submit(Request(rid, pr, args.max_new))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    ntok = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests / {ntok} tokens in {dt:.2f}s "
          f"({ntok / dt:.1f} tok/s on CPU smoke config)")

    # verify against the single-request reference path (greedy)
    import jax.numpy as jnp
    r0 = next(r for r in done if r.rid == 0)
    ref = generate(model, params,
                   {"tokens": jnp.asarray(prompts[0][None, :], jnp.int32)},
                   max_new=args.max_new,
                   max_len=args.prompt_len + args.max_new + 8)
    ref_toks = [int(t) for t in np.asarray(ref[0])]
    assert r0.out == ref_toks, f"engine {r0.out} != reference {ref_toks}"
    print("OK — engine output matches the reference decode path.")


if __name__ == "__main__":
    main()
