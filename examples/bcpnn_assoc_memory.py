"""Associative memory with BCPNN — the paper's functional claim, end to end.

  PYTHONPATH=src python examples/bcpnn_assoc_memory.py

BCPNN's purpose (paper §I-II) is biologically plausible cortical
associative memory. This example demonstrates exactly that function on the
lazily-evaluated implementation, driven through the `Simulator` facade:

  1. TRAIN: present P random patterns (one active input row per HCU,
     repeated with the WTA firing so Hebbian-Bayesian weights bind each
     pattern's rows to the MCUs that won);
  2. RECORD the attractor (winning MCU per HCU per pattern);
  3. CUE with a PARTIAL pattern (only 60% of HCUs driven, the rest silent);
  4. RECALL: report how often the undriven HCUs' WTA picks the same MCU the
     full pattern produced — pattern completion from partial input.

Chance level is 1/C (C = MCUs per HCU). A working associative memory scores
far above it.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BCPNNParams, Simulator
from repro.data import make_patterns

P_ = BCPNNParams(n_hcu=12, rows=64, cols=8, fanout=12, active_queue=16,
                 max_delay=4, mean_delay=1.5, out_rate=1.0, wta_temp=0.25,
                 tau_p=400.0)
N_PATTERNS = 3
TRAIN_REPS = 30
PRESENT_MS = 6
CUE_FRACTION = 0.6

sim = Simulator(P_, key=0, cap_fire=P_.n_hcu)
patterns = make_patterns(P_, N_PATTERNS, seed=3)


def drive(pattern_rows, active_mask):
    ext = np.full((P_.n_hcu, 4), P_.rows, np.int32)
    for h in range(P_.n_hcu):
        if active_mask[h]:
            ext[h, 0] = pattern_rows[h]
    return jnp.asarray(ext)


def run_ticks(ext, n):
    winners = np.full((P_.n_hcu,), -1, np.int64)
    for _ in range(n):
        f = np.asarray(sim.tick(ext))
        upd = f >= 0
        winners[upd] = f[upd]
    return winners


# ---------------------------------- train -----------------------------------
all_on = np.ones(P_.n_hcu, bool)
attractor = np.zeros((N_PATTERNS, P_.n_hcu), np.int64)
for rep in range(TRAIN_REPS):
    for pid in range(N_PATTERNS):
        winners = run_ticks(drive(patterns[pid], all_on), PRESENT_MS)
        if rep == TRAIN_REPS - 1:
            attractor[pid] = winners
    # short silence between presentations lets Z traces decay
    run_ticks(drive(patterns[0], np.zeros(P_.n_hcu, bool)), 2)

print("trained", N_PATTERNS, "patterns,", TRAIN_REPS, "reps each")

# ---------------------------------- recall ----------------------------------
rng = np.random.default_rng(0)
correct = total = 0
trained_state = sim.state
for pid in range(N_PATTERNS):
    cue_mask = rng.random(P_.n_hcu) < CUE_FRACTION
    ext = drive(patterns[pid], cue_mask)
    # each recall runs on a fresh copy of the trained state (the tick
    # drivers donate their input buffers, so the original must be kept
    # aside; after the loop the sim holds the last recall trajectory)
    sim.state = jax.tree.map(jnp.copy, trained_state)
    winners = run_ticks(ext, 12)
    probe = ~cue_mask & (winners >= 0) & (attractor[pid] >= 0)
    correct += int((winners[probe] == attractor[pid][probe]).sum())
    total += int(probe.sum())

chance = 1.0 / P_.cols
acc = correct / max(total, 1)
print(f"pattern completion: {correct}/{total} undriven HCUs recalled "
      f"their attractor MCU (acc={acc:.2f}, chance={chance:.2f})")
assert total > 0, "recall must probe some undriven HCUs"
if acc > 2 * chance:
    print("OK — associative recall well above chance.")
else:
    print("WARN — recall near chance; try more TRAIN_REPS.")
