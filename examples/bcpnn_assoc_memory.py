"""Associative memory with BCPNN — the paper's functional claim, end to end.

  PYTHONPATH=src python examples/bcpnn_assoc_memory.py

BCPNN's purpose (paper §I-II) is biologically plausible cortical
associative memory. This example demonstrates exactly that function on the
lazily-evaluated implementation, driven through the `Simulator` facade:

  1. TRAIN: present P random patterns (one active input row per HCU,
     repeated with the WTA firing so Hebbian-Bayesian weights bind each
     pattern's rows to the MCUs that won);
  2. RECORD the attractor (winning MCU per HCU per pattern);
  3. CUE with a PARTIAL pattern (only 60% of HCUs driven, the rest silent);
  4. RECALL: report how often the undriven HCUs' WTA picks the same MCU the
     full pattern produced — pattern completion from partial input.

Chance level is 1/C (C = MCUs per HCU). A working associative memory scores
far above it.

The train/cue/recall protocol itself lives in `repro.experiments` so the
resilience benchmark (`benchmarks/resilience.py`) can re-run recall under
injected DRAM-retention bit flips; this script is the plain, fault-free run.
"""
import jax
import numpy as np

from repro.core import Simulator
from repro.data import make_patterns
from repro.experiments import assoc_params, recall_accuracy, train_assoc

P_ = assoc_params()
N_PATTERNS = 3
TRAIN_REPS = 30
PRESENT_MS = 6
CUE_FRACTION = 0.6

sim = Simulator(P_, key=0, cap_fire=P_.n_hcu)
patterns = make_patterns(P_, N_PATTERNS, seed=3)

attractor = train_assoc(sim, patterns, reps=TRAIN_REPS,
                        present_ms=PRESENT_MS)
print("trained", N_PATTERNS, "patterns,", TRAIN_REPS, "reps each")

trained_state = jax.tree.map(np.array, sim.state)
correct, total = recall_accuracy(sim, trained_state, patterns, attractor,
                                 cue_fraction=CUE_FRACTION,
                                 rng=np.random.default_rng(0))

chance = 1.0 / P_.cols
acc = correct / max(total, 1)
print(f"pattern completion: {correct}/{total} undriven HCUs recalled "
      f"their attractor MCU (acc={acc:.2f}, chance={chance:.2f})")
assert total > 0, "recall must probe some undriven HCUs"
if acc > 2 * chance:
    print("OK — associative recall well above chance.")
else:
    print("WARN — recall near chance; try more TRAIN_REPS.")
