"""Quickstart: a small BCPNN cortex network, end to end, on CPU.

  PYTHONPATH=src python examples/quickstart.py

Builds an 8-HCU network behind the `Simulator` facade (one object wires up
connectivity, the canonical flat network state and the TickEngine), stages
200 ms of Poisson input spikes (the paper's specified arrival process), runs
them through the scan-compiled runtime (one compiled dispatch per 128-tick
chunk, no per-tick host round-trips), and prints spike/queue/drop statistics
plus a verification pass against invariants of the dense golden model — the
whole paper pipeline in ~20 lines of user code.
"""
import jax.numpy as jnp

from repro.core import BCPNNParams, Simulator
from repro.data import poisson_external_drive

p = BCPNNParams(n_hcu=8, rows=256, cols=32, fanout=8, active_queue=16,
                max_delay=8, out_rate=0.3)
sim = Simulator(p, key=0)

fired = sim.run(poisson_external_drive(p, n_ticks=200, seed=42, lam=4.0))
fired_total = int((fired >= 0).sum())

print(f"ticks simulated     : {int(sim.state.t)} ms")
print(f"output spikes fired : {fired_total}")
print(f"input-queue drops   : {int(sim.state.drops_in)}")
print(f"fire-batch drops    : {int(sim.state.drops_fire)}")

# lazy state is exact: flush and verify a few invariants
st = sim.flushed()
assert bool(jnp.all(jnp.isfinite(st.wij))), "weights must stay finite"
assert bool(jnp.all(st.pij >= 0)), "P traces are probabilities"
print(f"mean |w_ij|         : {float(jnp.mean(jnp.abs(st.wij))):.4f}")
print(f"mean P_i            : {float(jnp.mean(st.pi)):.5f}")
print("OK — lazy BCPNN network ran and stayed consistent.")
