"""Quickstart: a small BCPNN cortex network, end to end, on CPU.

  PYTHONPATH=src python examples/quickstart.py

Builds an 8-HCU network, stages 200 ms of Poisson input spikes (the paper's
specified arrival process), runs them through the scan-compiled runtime
(`network_run`: one compiled dispatch per 128-tick chunk, no per-tick host
round-trips), and prints spike/queue/drop statistics plus a verification
pass against the dense golden model — the whole paper pipeline in ~30 lines
of user code.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (BCPNNParams, flush, init_network, make_connectivity,
                        network_run, stage_external)
from repro.data import poisson_external_drive

p = BCPNNParams(n_hcu=8, rows=256, cols=32, fanout=8, active_queue=16,
                max_delay=8, out_rate=0.3)
key = jax.random.PRNGKey(0)
conn = make_connectivity(p, jax.random.fold_in(key, 1))
state = init_network(p, key)

ext = stage_external(poisson_external_drive(p, n_ticks=200, seed=42, lam=4.0))
state, fired = network_run(state, conn, ext, p)
fired_total = int((fired >= 0).sum())

print(f"ticks simulated     : {int(state.t)} ms")
print(f"output spikes fired : {fired_total}")
print(f"input-queue drops   : {int(state.drops_in)}")
print(f"fire-batch drops    : {int(state.drops_fire)}")

# lazy state is exact: flush and verify a few invariants
st = jax.vmap(lambda s: flush(s, state.t, p))(state.hcus)
assert bool(jnp.all(jnp.isfinite(st.wij))), "weights must stay finite"
assert bool(jnp.all(st.pij >= 0)), "P traces are probabilities"
print(f"mean |w_ij|         : {float(jnp.mean(jnp.abs(st.wij))):.4f}")
print(f"mean P_i            : {float(jnp.mean(st.pi)):.5f}")
print("OK — lazy BCPNN network ran and stayed consistent.")
