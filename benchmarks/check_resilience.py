"""Resilience benchmark sanity gate (shared by CI and `make ci-local`).

  PYTHONPATH=src python -m benchmarks.check_resilience \
      [--fresh BENCH_resilience.json]

Validates a freshly generated BENCH_resilience.json:
  * every fault-pattern curve ("clear", "flip") has a zero-rate recall
    point that probed at least one undriven HCU and scored > 2x chance —
    the functional gate: the fault-injection machinery must not have
    perturbed the fault-FREE path;
  * each curve covers a nonzero rate too (it is a curve, not a point);
  * the rodent16 health report is structurally complete (status /
    drops / budget / deadline) with a known status and nonzero ticks;
  * the device-loss recovery scenario restored onto a strictly smaller
    mesh, actually restarted, reported its recovery wall time, and — the
    elasticity contract — completed BITWISE identical to the uninterrupted
    run, with a structurally complete post-recovery health report
    (per-class drop budgets included).

Wall-clock fields (us/tick, deadline status, recovery_s) are deliberately
NOT gated beyond presence — CI runners throttle; the deadline half of the
report is trend data, the drop-budget and bitwise halves are deterministic.
"""
from __future__ import annotations

import argparse
import json
import sys

KNOWN_STATUS = ("ok", "over-budget", "deadline-missed")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", default="BENCH_resilience.json",
                    help="path to the freshly generated JSON")
    args = ap.parse_args()

    d = json.load(open(args.fresh))
    failures = []

    curves = d.get("recall_vs_flip_rate", {})
    chance = d.get("chance", 0.0)
    if not curves:
        failures.append("no recall curves")
    for mode, curve in curves.items():
        zero = [r for r in curve if r["rate"] == 0.0]
        if not zero:
            failures.append(f"{mode}: no zero-rate recall point")
        else:
            r = zero[0]
            print(f"recall@{mode}/0: {r['correct']}/{r['total']} "
                  f"(acc={r['acc']:.2f}, chance={chance:.2f})")
            if r["total"] <= 0:
                failures.append(f"{mode}: zero-rate recall probed no "
                                "undriven HCUs")
            elif r["acc"] <= 2 * chance:
                failures.append(f"{mode}: zero-rate recall acc "
                                f"{r['acc']:.2f} is not > 2x chance "
                                f"({chance:.2f})")
        if not any(r["rate"] > 0 for r in curve):
            failures.append(f"{mode}: curve has no nonzero rate")

    h = d.get("rodent16_health", {})
    print(f"rodent16: status={h.get('status')} ticks={h.get('ticks')} "
          f"drops={h.get('drops', {}).get('total')} "
          f"restarts={h.get('restarts')}")
    if h.get("status") not in KNOWN_STATUS:
        failures.append(f"unknown health status {h.get('status')!r}")
    if not h.get("ticks", 0) > 0:
        failures.append("health report covers zero ticks")
    for key in ("drops", "budget", "deadline"):
        if key not in h:
            failures.append(f"health report missing {key!r}")

    dl = d.get("device_loss")
    if not dl:
        failures.append("no device_loss scenario")
    else:
        print(f"device_loss: {dl.get('devices_before')} -> "
              f"{dl.get('devices_after')} devices "
              f"restarts={dl.get('restarts')} "
              f"recovery_s={dl.get('recovery_s')} "
              f"bitwise={dl.get('bitwise_identical_to_uninterrupted')}")
        if not dl.get("bitwise_identical_to_uninterrupted"):
            failures.append("device-loss trajectory diverged from the "
                            "uninterrupted run")
        if not dl.get("restarts", 0) >= 1:
            failures.append("device-loss scenario never restarted")
        before, after = dl.get("devices_before"), dl.get("devices_after")
        if not (isinstance(before, int) and isinstance(after, int)
                and after < before):
            failures.append(f"device-loss mesh did not shrink "
                            f"({before} -> {after})")
        if not isinstance(dl.get("recovery_s"), (int, float)):
            failures.append("device-loss scenario missing recovery_s")
        dh = dl.get("health", {})
        if dh.get("status") not in KNOWN_STATUS:
            failures.append(f"unknown device-loss health status "
                            f"{dh.get('status')!r}")
        if set(dh.get("classes", {})) != {"in", "fire", "route"}:
            failures.append("device-loss health lacks per-class budgets "
                            "(in/fire/route)")

    if failures:
        sys.exit("resilience gate: " + "; ".join(failures))
    print("resilience gate: OK")


if __name__ == "__main__":
    main()
