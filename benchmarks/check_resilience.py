"""Resilience benchmark sanity gate (shared by CI and `make ci-local`).

  PYTHONPATH=src python -m benchmarks.check_resilience \
      [--fresh BENCH_resilience.json]

Validates a freshly generated BENCH_resilience.json:
  * every fault-pattern curve ("clear", "flip") has a zero-rate recall
    point that probed at least one undriven HCU and scored > 2x chance —
    the functional gate: the fault-injection machinery must not have
    perturbed the fault-FREE path;
  * each curve covers a nonzero rate too (it is a curve, not a point);
  * the rodent16 health report is structurally complete (status /
    drops / budget / deadline) with a known status and nonzero ticks.

Wall-clock fields (us/tick, deadline status) are deliberately NOT gated —
CI runners throttle; the deadline half of the report is trend data, the
drop-budget half is deterministic.
"""
from __future__ import annotations

import argparse
import json
import sys

KNOWN_STATUS = ("ok", "over-budget", "deadline-missed")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", default="BENCH_resilience.json",
                    help="path to the freshly generated JSON")
    args = ap.parse_args()

    d = json.load(open(args.fresh))
    failures = []

    curves = d.get("recall_vs_flip_rate", {})
    chance = d.get("chance", 0.0)
    if not curves:
        failures.append("no recall curves")
    for mode, curve in curves.items():
        zero = [r for r in curve if r["rate"] == 0.0]
        if not zero:
            failures.append(f"{mode}: no zero-rate recall point")
        else:
            r = zero[0]
            print(f"recall@{mode}/0: {r['correct']}/{r['total']} "
                  f"(acc={r['acc']:.2f}, chance={chance:.2f})")
            if r["total"] <= 0:
                failures.append(f"{mode}: zero-rate recall probed no "
                                "undriven HCUs")
            elif r["acc"] <= 2 * chance:
                failures.append(f"{mode}: zero-rate recall acc "
                                f"{r['acc']:.2f} is not > 2x chance "
                                f"({chance:.2f})")
        if not any(r["rate"] > 0 for r in curve):
            failures.append(f"{mode}: curve has no nonzero rate")

    h = d.get("rodent16_health", {})
    print(f"rodent16: status={h.get('status')} ticks={h.get('ticks')} "
          f"drops={h.get('drops', {}).get('total')} "
          f"restarts={h.get('restarts')}")
    if h.get("status") not in KNOWN_STATUS:
        failures.append(f"unknown health status {h.get('status')!r}")
    if not h.get("ticks", 0) > 0:
        failures.append("health report covers zero ticks")
    for key in ("drops", "budget", "deadline"):
        if key not in h:
            failures.append(f"health report missing {key!r}")

    if failures:
        sys.exit("resilience gate: " + "; ".join(failures))
    print("resilience gate: OK")


if __name__ == "__main__":
    main()
