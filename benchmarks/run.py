"""Benchmark harness: one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--fast]

Prints ``name,us_per_call,derived`` CSV rows. The dry-run roofline tables
(EXPERIMENTS.md §Roofline) are produced separately by repro.launch.dryrun +
benchmarks.roofline_report, since they need the 512-device environment.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the measured (wall-clock) benchmarks")
    args = ap.parse_args()

    from benchmarks import bcpnn_tables, fig14_lazy_vs_eager

    suites = [
        bcpnn_tables.table1_requirements,
        bcpnn_tables.fig7_queue_dimensioning,
        bcpnn_tables.fig10_rowmerge,
        bcpnn_tables.eq2_worst_case_ms,
        bcpnn_tables.table3_bandwidth_utilization,
        bcpnn_tables.rodent_vs_human,
    ]
    if not args.fast:
        suites += [
            fig14_lazy_vs_eager.lazy_vs_eager,
            fig14_lazy_vs_eager.kernel_row_update,
        ]

    print("name,us_per_call,derived")
    failed = 0
    for fn in suites:
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.3f},{derived:.6g}")
        except Exception:
            traceback.print_exc()
            failed += 1
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
