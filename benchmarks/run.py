"""Benchmark harness: one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--json] [--legacy-cpu]

Prints ``name,us_per_call,derived`` CSV rows. ``--json`` additionally runs
the tick-loop runtime benchmark (host loop vs scan-compiled network_run,
benchmarks/tick_loop.py) and writes BENCH_tick_loop.json so the perf
trajectory is tracked across PRs. The dry-run roofline tables
(EXPERIMENTS.md §Roofline) are produced separately by repro.launch.dryrun +
benchmarks.roofline_report, since they need the 512-device environment.

``--legacy-cpu`` pins XLA's legacy CPU runtime
(--xla_cpu_use_thunk_runtime=false) for this benchmark process. The thunk
runtime (default since jax 0.4.3x) has a high fixed per-op dispatch cost on
CPU that dominates the many-small-op BCPNN tick graph; the legacy runtime
executes the same HLO ~3-4x faster at these sizes, and the committed
BENCH_tick_loop.json numbers are measured with it. It is an explicit
opt-in flag — NOT an import side effect — so merely importing this module
(e.g. from a notebook or an embedding process) never mutates the
environment of the host process.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import traceback


def pin_legacy_cpu_runtime() -> None:
    """Opt into the legacy XLA CPU runtime for this process. Must run before
    jax initializes (main() calls it before importing any jax-using
    module); applied identically to every measured pipeline."""
    if "xla_cpu_use_thunk_runtime" not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + " --xla_cpu_use_thunk_runtime=false"
                                   ).strip()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the measured (wall-clock) benchmarks")
    ap.add_argument("--json", action="store_true",
                    help="run the tick-loop benchmark (even with --fast) and "
                         "write BENCH_tick_loop.json")
    ap.add_argument("--legacy-cpu", action="store_true",
                    help="pin the legacy XLA CPU runtime (the configuration "
                         "the committed BENCH_tick_loop.json was measured "
                         "with); off by default")
    args = ap.parse_args()
    if args.legacy_cpu:
        pin_legacy_cpu_runtime()

    from benchmarks import bcpnn_tables, fig14_lazy_vs_eager, tick_loop

    suites = [
        bcpnn_tables.table1_requirements,
        bcpnn_tables.fig7_queue_dimensioning,
        bcpnn_tables.fig10_rowmerge,
        bcpnn_tables.eq2_worst_case_ms,
        bcpnn_tables.table3_bandwidth_utilization,
        bcpnn_tables.rodent_vs_human,
    ]
    if not args.fast:
        suites += [
            fig14_lazy_vs_eager.lazy_vs_eager,
            fig14_lazy_vs_eager.kernel_row_update,
        ]
        if not args.json:
            suites += [tick_loop.tick_loop]

    print("name,us_per_call,derived")
    failed = 0
    for fn in suites:
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.3f},{derived:.6g}")
        except Exception:
            traceback.print_exc()
            failed += 1

    if args.json:
        try:
            results = tick_loop.measure_sizes()
            for name, us, derived in tick_loop.tick_loop(results):
                print(f"{name},{us:.3f},{derived:.6g}")
            out = pathlib.Path(__file__).resolve().parent.parent \
                / "BENCH_tick_loop.json"
            out.write_text(json.dumps(results, indent=2) + "\n")
            print(f"# wrote {out}", file=sys.stderr)
        except Exception:
            traceback.print_exc()
            failed += 1

    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
