"""Per-phase tick timing breakdown: row-update / column-update / WTA / queue.

  PYTHONPATH=src python -m benchmarks.profile_phases [--legacy-cpu] [--json]

`make profile` runs this after the tick-loop benchmark to show WHERE the
tick budget goes at each size, so the next perf PR aims at the right phase
(the paper's EQ2 budget analysis, applied to our own runtime). Each phase is
timed as its own jitted computation on realistic inputs:

  * queue       — consume_bucket + enqueue_spikes for a full fanout batch
  * row-update  — the engine's row phase (worklist or dense per-HCU form,
                  whichever `select_backend` would pick at that size)
  * wta         — support integration + soft winner-take-all
  * column      — the fired-batch column update (worklist or dense form)

Isolated-phase timings exclude cross-phase fusion AND — because each phase
is its own non-donated jit — pay a one-time copy of every written plane at
call entry that the scan runtime (donated carry, in-place loops) never
pays. Their sum therefore brackets the fused full-tick loosely and
OVERSTATES plane-writing phases at large sizes; treat the ratios as a hint
and confirm with a scan-path ablation before optimizing (see
docs/BENCHMARKING.md).
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--legacy-cpu", action="store_true",
                    help="pin the legacy XLA CPU runtime (matches the "
                         "committed BENCH_tick_loop.json configuration)")
    ap.add_argument("--json", action="store_true",
                    help="print a JSON blob instead of CSV rows")
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--inner", type=int, default=20,
                    help="calls per timed repeat")
    args = ap.parse_args()
    if args.legacy_cpu:
        from benchmarks.run import pin_legacy_cpu_runtime
        pin_legacy_cpu_runtime()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.tick_loop import DEFAULT, HUMAN_COL, RODENT
    from repro.core import engine as E
    from repro.core import hcu as H
    from repro.core import layout as L
    from repro.core import network as N

    def timed(fn, *operands, repeats=args.repeats, inner=args.inner):
        out = fn(*operands)                       # compile
        jax.block_until_ready(out)
        meas = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            for _ in range(inner):
                out = fn(*operands)
            jax.block_until_ready(out)
            meas.append((time.perf_counter() - t0) / inner)
        return statistics.median(meas) * 1e6      # us per call

    def profile_size(name, p):
        key = jax.random.PRNGKey(0)
        state = N.init_network(p, key)
        n = p.n_hcu
        t = jnp.asarray(1, jnp.int32)
        rng = np.random.default_rng(0)
        A = p.active_queue + 8
        rows = np.full((n, A), p.rows, np.int32)
        for h in range(n):
            k = min(A, rng.poisson(6.0))
            rows[h, :k] = rng.integers(0, p.rows, k)
        rows = jnp.asarray(rows)
        keys = jax.vmap(lambda h: jax.random.fold_in(key, h))(jnp.arange(n))
        cap = max(2, int(0.35 * n) + 1)
        # a half-full fired batch (worst realistic column load)
        h_idx = jnp.asarray([i if i % 2 == 0 else n for i in range(cap)],
                            jnp.int32)
        j_idx = jnp.asarray(rng.integers(0, p.cols, cap), jnp.int32)
        worklist = H.use_worklist(p)
        be = E.select_backend(p)

        # --- queue: consume + full-fanout enqueue ---------------------------
        dest_h = jnp.asarray(rng.integers(0, n, cap * p.fanout), jnp.int32)
        dest_r = jnp.asarray(rng.integers(0, p.rows, cap * p.fanout),
                             jnp.int32)
        dly = jnp.asarray(rng.integers(1, p.max_delay, cap * p.fanout),
                          jnp.int32)
        valid = jnp.asarray(rng.random(cap * p.fanout) < 0.5)

        @jax.jit
        def queue_phase(st):
            st, bucket = N.consume_bucket(st, t, p, n)
            st = N.enqueue_spikes(st, dest_h, dest_r, dly, valid, p, n)
            return st.delay_rows, bucket

        # --- row update -----------------------------------------------------
        if worklist:
            @jax.jit
            def row_phase(hcus):
                hcus, w_rows, c = E.worklist_lazy_rows(hcus, rows, t, p)
                return hcus.zij, w_rows, c["counts"]
        else:
            @jax.jit
            def row_phase(hcus):
                hb = L.batched_state(hcus, n)
                hb, w_rows, counts, _ = jax.vmap(
                    lambda s, r: H.row_updates(H._decay_jvec(s, p), r, t, p)
                )(hb, rows)
                return hb.zij, w_rows, counts

        _, w_rows, counts = row_phase(state.hcus)

        # --- WTA ------------------------------------------------------------
        @jax.jit
        def wta_phase(hcus, w, cnt):
            hcus, fired = E._wta(hcus, w, cnt, t, keys, p)
            return hcus.h, fired

        # --- column update --------------------------------------------------
        if worklist:
            @jax.jit
            def col_phase(hcus):
                return E._column_worklist(hcus, h_idx, j_idx, t, p).zij
        else:
            @jax.jit
            def col_phase(hcus):
                hb = L.batched_state(hcus, n)
                return E.column_updates_batched(hb, h_idx, j_idx, t, p).zij

        # --- whole fused tick for reference ---------------------------------
        conn = N.make_connectivity(p, jax.random.fold_in(key, 1))
        ext = jnp.asarray(rows[:, :8])

        @jax.jit
        def full_tick(st):
            st, fired = E.tick(be.carry_in(st, p), conn, ext, p, be)
            return be.carry_out(st, p).hcus.zij, fired

        phases = {
            "queue": timed(queue_phase, state),
            "row_update": timed(row_phase, state.hcus),
            "wta": timed(wta_phase, state.hcus, w_rows, counts),
            "column_update": timed(col_phase, state.hcus),
            "full_tick": timed(full_tick, state),
        }
        phases["backend"] = type(be).__name__
        return phases

    results = {}
    for name, p in (DEFAULT, RODENT, HUMAN_COL):
        results[name] = profile_size(name, p)

    if args.json:
        json.dump(results, sys.stdout, indent=2)
        print()
        return
    print("size,phase,us_per_call,share_of_sum")
    for name, phases in results.items():
        total = sum(v for k, v in phases.items()
                    if k not in ("full_tick", "backend"))
        for phase in ("queue", "row_update", "wta", "column_update"):
            us = phases[phase]
            print(f"{name},{phase},{us:.1f},{us / total:.2f}")
        print(f"{name},full_tick,{phases['full_tick']:.1f},-  "
              f"# {phases['backend']}, isolated-phase sum {total:.1f}")


if __name__ == "__main__":
    main()
