"""Per-phase tick timing: SCAN-CONTEXT ABLATION + isolated-phase breakdown.

  PYTHONPATH=src python -m benchmarks.profile_phases [--legacy-cpu] [--json]

`make profile` runs this after the tick-loop benchmark to show WHERE the
tick budget goes at each size, so the next perf PR aims at the right phase
(the paper's EQ2 budget analysis, applied to our own runtime). Always
writes ``BENCH_phase_breakdown.json`` at the repo root (uploaded as a CI
artifact next to BENCH_tick_loop.json, so the "what's the next bottleneck"
ablation is regenerated on every PR instead of by hand).

Two measurements per size:

  * scan ablation (the trustworthy one) — the full `network_run`-style
    scan (donated carry, `engine.tick`, one compiled chunk) is re-measured
    with ONE phase replaced by a cheap stand-in, and the phase cost is the
    DELTA against the unmodified scan. This is measured in the exact
    compilation context the production runtime pays for — cross-phase
    fusion, in-place carries and all. Caveats: ablating a phase perturbs
    the spike trajectory downstream (zero WTA drive changes winners, a
    no-op enqueue empties future buckets), so deltas are O(phase) accurate,
    not exact; and deltas need not sum to the full-tick time.
  * isolated phases (kept for continuity) — each phase as its own jitted
    computation on realistic inputs. Because each is a non-donated jit, a
    plane-writing phase pays a one-time copy of every written plane at call
    entry that the scan runtime never pays: isolated numbers OVERSTATE
    plane-writing phases at large sizes (measured in PR 4: the row phase
    looked ~2x its scan-context cost). Trust the ablation column; treat
    isolated numbers as a fusion-free upper bracket (docs/BENCHMARKING.md).

Phases: enqueue (the fanout spike-enqueue side of the queue; the bucket
CONSUME side runs inside `engine.tick` and cannot be ablated through the
route hook, so it is not part of this delta — the isolated `queue` timing
covers both), row_update (the engine's row phase), wta (support
integration + soft winner-take-all), column_update (the fired-batch
column phase).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import statistics
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--legacy-cpu", action="store_true",
                    help="pin the legacy XLA CPU runtime (matches the "
                         "committed BENCH_tick_loop.json configuration)")
    ap.add_argument("--json", action="store_true",
                    help="print the JSON blob instead of CSV rows (the "
                         "file is written either way)")
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--inner", type=int, default=20,
                    help="calls per timed repeat (isolated phases)")
    ap.add_argument("--ticks", type=int, default=128,
                    help="ticks per measured scan chunk (ablation)")
    args = ap.parse_args()
    if args.legacy_cpu:
        from benchmarks.run import pin_legacy_cpu_runtime
        pin_legacy_cpu_runtime()

    import functools
    from typing import NamedTuple

    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.tick_loop import DEFAULT, HUMAN_COL, RODENT, _ext_tensor
    from repro.core import engine as E
    from repro.core import hcu as H
    from repro.core import layout as L
    from repro.core import network as N

    def timed(fn, *operands, repeats=args.repeats, inner=args.inner):
        out = fn(*operands)                       # compile
        jax.block_until_ready(out)
        meas = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            for _ in range(inner):
                out = fn(*operands)
            jax.block_until_ready(out)
            meas.append((time.perf_counter() - t0) / inner)
        return statistics.median(meas) * 1e6      # us per call

    # ---------------- scan-context ablation --------------------------------
    # `base` is the REAL backend `select_backend` picks at this size; the
    # "full" variant runs it untouched (so the baseline is the production
    # graph), and each ablated variant swaps ONE phase for a cheap stand-in
    # by re-composing the same engine functions the backend calls. The
    # plane-update recomposition here must track engine.{Dense,Worklist}
    # Backend.plane_update — it is benchmark-only code, so drift skews the
    # ablation deltas, never the product runtime.
    def cheap_fire(keys, p):
        """Drive-independent stand-in for the WTA: keeps the gate (same
        firing RATE, so downstream column/fanout load stays realistic),
        drops the support integration + categorical winner."""
        def one(k):
            gate = jax.random.uniform(jax.random.split(k)[0])
            return jnp.where(gate < p.out_rate * p.dt_ms, 0, -1)
        return jax.vmap(one)(keys).astype(jnp.int32)

    class AblatedBackend(NamedTuple):
        base: object   # hashable TickBackend
        skip: str      # "row_update" | "wta" | "column_update" |
                       # "plane_update" (the whole block at once)

        def carry_in(self, state, p):
            return self.base.carry_in(state, p)

        def carry_out(self, state, p):
            return self.base.carry_out(state, p)

        def plane_update(self, state, rows, t, keys, p, cap, cond_columns):
            n = state.delay_rows.shape[0]
            A = rows.shape[1]
            wl = isinstance(self.base, E.WorklistBackend)
            kernel = self.base.kernel

            lay = getattr(self.base, "layout", None)

            if self.skip == "plane_update":
                # whole block skipped: its delta vs `full` is the plane
                # update's TOTAL scan cost, including loop-interaction
                # overhead the per-phase deltas miss
                fired = cheap_fire(keys, p)
                h_idx, j_idx, n_drop = N.select_fired(fired, cap)
                return state, fired, h_idx, j_idx, n_drop

            # --- row phase ------------------------------------------------
            if self.skip == "row_update":
                # zero drive, zero counts: planes untouched; the firing
                # rate is unaffected (the WTA gate is drive-independent)
                counts = jnp.zeros((n, A), jnp.float32)
                w_rows = jnp.zeros((n, A, p.cols), jnp.float32)
                hcus = state.hcus
            elif wl:
                hcus, w_rows, c = E.worklist_lazy_rows(
                    state.hcus, rows, t, p, kernel=kernel,
                    fused=self.base.fused, layout=lay)
                counts = c["counts"]
            else:
                hb, w_rows, counts, _ = jax.vmap(
                    lambda s, r: H.row_updates(H._decay_jvec(s, p), r, t, p,
                                               backend=kernel)
                )(state.hcus, rows)
                hcus = hb

            # --- WTA ------------------------------------------------------
            if self.skip == "wta":
                fired = cheap_fire(keys, p)
            else:
                hcus, fired = E._wta(hcus, w_rows, counts, t, keys, p)
            h_idx, j_idx, n_drop = N.select_fired(fired, cap)

            # --- column phase (the engine's own dispatch) -----------------
            if self.skip == "column_update":
                col = lambda hc: hc
            elif wl:
                col = E.worklist_col_dispatch(
                    kernel, self.base.fused_cols, h_idx, j_idx, t, p, n,
                    layout=lay)
            else:
                col = lambda hc: E.column_updates_batched(hc, h_idx, j_idx,
                                                          t, p,
                                                          backend=kernel)
            if cond_columns:
                hcus = jax.lax.cond(jnp.any(h_idx < n), col,
                                    lambda hc: hc, hcus)
            else:
                hcus = col(hcus)
            return state._replace(hcus=hcus), fired, h_idx, j_idx, n_drop

    def scan_ablation(p, conn, ext, key, layout=None):
        T = ext.shape[0]
        base = E.select_backend(p, layout=layout)
        noop_route = lambda state, dh, dr, dly, valid, p_, n_: state

        def make_run(be, route):
            @functools.partial(jax.jit, donate_argnums=(0,))
            def run(state, ext):
                def body(s, e):
                    return E.tick(s, conn, e, p, be, route=route)
                s, f = jax.lax.scan(body, be.carry_in(state, p), ext)
                return be.carry_out(s, p), f
            return run

        variants = {
            "full": make_run(base, None),
            "enqueue": make_run(base, noop_route),
            "row_update": make_run(AblatedBackend(base, "row_update"), None),
            "wta": make_run(AblatedBackend(base, "wta"), None),
            "column_update": make_run(AblatedBackend(base, "column_update"),
                                      None),
            "plane_update": make_run(AblatedBackend(base, "plane_update"),
                                     None),
        }
        for fn in variants.values():              # compile + warm all first
            s, f = fn(N.init_network(p, key, layout=layout), ext)
            jax.block_until_ready(f)
        # interleave rounds across variants and keep the MIN round: this
        # benchmark must survive noisy shared CI runners, and a burst of
        # contention hitting one variant's consecutive repeats would
        # otherwise masquerade as a phase cost
        meas = {k: [] for k in variants}
        for _ in range(args.repeats):
            for name, fn in variants.items():
                state = N.init_network(p, key, layout=layout)
                t0 = time.perf_counter()
                s, f = fn(state, ext)
                jax.block_until_ready(f)
                meas[name].append((time.perf_counter() - t0) / T)
        us = {k: min(v) * 1e6 for k, v in meas.items()}
        full = us.pop("full")
        return full, {k: full - v for k, v in us.items()}

    # ---------------- isolated phases (the PR 3 breakdown) -----------------
    def profile_size(name, p, layout=None):
        key = jax.random.PRNGKey(0)
        state = N.init_network(p, key, layout=layout)
        n = p.n_hcu
        t = jnp.asarray(1, jnp.int32)
        rng = np.random.default_rng(0)
        A = p.active_queue + 8
        rows = np.full((n, A), p.rows, np.int32)
        for h in range(n):
            k = min(A, rng.poisson(6.0))
            rows[h, :k] = rng.integers(0, p.rows, k)
        rows = jnp.asarray(rows)
        keys = jax.vmap(lambda h: jax.random.fold_in(key, h))(jnp.arange(n))
        cap = max(2, int(0.35 * n) + 1)
        # a half-full fired batch (worst realistic column load)
        h_idx = jnp.asarray([i if i % 2 == 0 else n for i in range(cap)],
                            jnp.int32)
        j_idx = jnp.asarray(rng.integers(0, p.cols, cap), jnp.int32)
        worklist = H.use_worklist(p)
        be = E.select_backend(p, layout=layout)

        # --- queue: consume + full-fanout enqueue ---------------------------
        dest_h = jnp.asarray(rng.integers(0, n, cap * p.fanout), jnp.int32)
        dest_r = jnp.asarray(rng.integers(0, p.rows, cap * p.fanout),
                             jnp.int32)
        dly = jnp.asarray(rng.integers(1, p.max_delay, cap * p.fanout),
                          jnp.int32)
        valid = jnp.asarray(rng.random(cap * p.fanout) < 0.5)

        @jax.jit
        def queue_phase(st):
            st, bucket = N.consume_bucket(st, t, p, n)
            st = N.enqueue_spikes(st, dest_h, dest_r, dly, valid, p, n)
            return st.delay_rows, bucket

        # --- row update -----------------------------------------------------
        if worklist:
            @jax.jit
            def row_phase(hcus):
                hcus, w_rows, c = E.worklist_lazy_rows(hcus, rows, t, p,
                                                       layout=layout)
                return hcus.zij, w_rows, c["counts"]
        else:
            @jax.jit
            def row_phase(hcus):
                hb = L.batched_state(hcus, n)
                hb, w_rows, counts, _ = jax.vmap(
                    lambda s, r: H.row_updates(H._decay_jvec(s, p), r, t, p)
                )(hb, rows)
                return hb.zij, w_rows, counts

        _, w_rows, counts = row_phase(state.hcus)

        # --- WTA ------------------------------------------------------------
        @jax.jit
        def wta_phase(hcus, w, cnt):
            hcus, fired = E._wta(hcus, w, cnt, t, keys, p)
            return hcus.h, fired

        # --- column update --------------------------------------------------
        if worklist:
            @jax.jit
            def col_phase(hcus):
                return E._column_worklist(hcus, h_idx, j_idx, t, p,
                                          layout=layout).zij
        else:
            @jax.jit
            def col_phase(hcus):
                hb = L.batched_state(hcus, n)
                return E.column_updates_batched(hb, h_idx, j_idx, t, p).zij

        # --- whole fused tick for reference ---------------------------------
        conn = N.make_connectivity(p, jax.random.fold_in(key, 1))
        ext = jnp.asarray(rows[:, :8])

        @jax.jit
        def full_tick(st):
            st, fired = E.tick(be.carry_in(st, p), conn, ext, p, be)
            return be.carry_out(st, p).hcus.zij, fired

        isolated = {
            "queue": timed(queue_phase, state),
            "row_update": timed(row_phase, state.hcus),
            "wta": timed(wta_phase, state.hcus, w_rows, counts),
            "column_update": timed(col_phase, state.hcus),
            "full_tick": timed(full_tick, state),
        }

        # --- scan-context ablation ------------------------------------------
        ext_t = _ext_tensor(p, args.ticks)
        scan_full, ablation = scan_ablation(
            p, conn, ext_t, jax.random.PRNGKey(0), layout=layout)

        return {
            "backend": type(be).__name__,
            "layout": L.layout_tag(layout),
            "n_hcu": p.n_hcu, "rows": p.rows, "cols": p.cols,
            "scan_us_per_tick": scan_full,
            "scan_ablation_us": ablation,
            "isolated_us": isolated,
        }

    # human_col is profiled twice — canonical flat AND the Row-Merge
    # column-blocked CPU tile — in the same process, so the committed JSON
    # carries a same-machine-window layout A/B at the size the paper's Fig
    # 9-10 DRAM argument is about (the column phase is the blocked layout's
    # target; see benchmarks/fig10_rowmerge.py for the model-side numbers).
    sizes = [(DEFAULT[0], DEFAULT[1], None), (RODENT[0], RODENT[1], None),
             (HUMAN_COL[0], HUMAN_COL[1], None),
             ("human_col_blocked", HUMAN_COL[1], L.cpu_blocked(HUMAN_COL[1]))]
    results = {}
    for name, p, lay in sizes:
        results[name] = profile_size(name, p, layout=lay)

    out = pathlib.Path(__file__).resolve().parent.parent \
        / "BENCH_phase_breakdown.json"
    out.write_text(json.dumps(results, indent=2) + "\n")
    print(f"# wrote {out}", file=sys.stderr)

    if args.json:
        json.dump(results, sys.stdout, indent=2)
        print()
        return
    print("size,phase,scan_ablation_us,share_of_scan,isolated_us")
    for name, r in results.items():
        full = r["scan_us_per_tick"]
        for phase in ("enqueue", "row_update", "wta", "column_update"):
            ab = r["scan_ablation_us"][phase]
            # the isolated 'queue' timing covers consume+enqueue; it is the
            # closest isolated analogue of the enqueue ablation
            iso = r["isolated_us"]["queue" if phase == "enqueue" else phase]
            print(f"{name},{phase},{ab:.1f},{ab / full:.2f},{iso:.1f}")
        all_pl = r["scan_ablation_us"]["plane_update"]
        print(f"{name},plane_update_all,{all_pl:.1f},{all_pl / full:.2f},-")
        print(f"{name},full_scan_tick,{full:.1f},1.00,"
              f"{r['isolated_us']['full_tick']:.1f}"
              f"  # {r['backend']}")


if __name__ == "__main__":
    main()
