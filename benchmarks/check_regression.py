"""Benchmark regression gates (shared by CI and `make ci-local`).

  PYTHONPATH=src python -m benchmarks.check_regression \
      [--committed /tmp/BENCH_committed.json --fresh BENCH_tick_loop.json] \
      [--phase-committed /tmp/BENCH_phase_committed.json \
       --phase-fresh BENCH_phase_breakdown.json] \
      [--serving-committed /tmp/BENCH_serving_committed.json \
       --serving-fresh BENCH_serving.json] \
      [--weak-scaling-committed /tmp/BENCH_weak_committed.json \
       --weak-scaling-fresh BENCH_weak_scaling.json] \
      [--layout-committed /tmp/BENCH_layout_committed.json \
       --layout-fresh BENCH_layout.json]

Five gates, all optional and all with the same headroom philosophy —
headroom absorbs CI-runner noise while still catching the step-function
regressions that matter (a lost in-place alias or an accidental full-plane
copy is 2x+, never 1.1x):

  * tick loop — any gated size's `scan_us_per_tick` in BENCH_tick_loop.json
    vs the committed baseline (1.25x headroom);
  * column phase (optional, when --phase-committed is given) — the
    human_col `column_update` scan-context ablation delta in
    BENCH_phase_breakdown.json. This is the phase the PR 8 column-blocked
    layout targets, gated so a later change can't silently hand the
    Row-Merge win back (docs/BENCHMARKING.md);
  * serving throughput (optional, when --serving-committed is given) — the
    rodent16 `qps_at_slo` in BENCH_serving.json. This gate is INVERTED
    (higher is better): it fails when the fresh throughput drops below
    committed/headroom, and unconditionally when qps_at_slo == 0 (the p95
    sojourn missed the SLO — a latency blow-up, not just slowness).
    Throughput on shared runners is noisier than the min-estimator tick
    numbers, hence the wider 2x headroom;
  * weak scaling (--weak-scaling-committed) — the sharded runtime's
    N_max-device / 1-device us/tick ratio in BENCH_weak_scaling.json (a
    same-window self-relative number, robust to machine speed) plus the
    per-device-count `drops_route` counters, which are DETERMINISTIC (the
    trajectory is bitwise reproducible) and held to
    max(committed, ceil(Fig 7 route budget)) — a broken sparse exchange
    either shifts the ratio by integer factors or starts dropping spikes;
  * layout model (--layout-committed) — BENCH_layout.json: the closed-form
    Fig 10 model sections must be unchanged (deterministic math: best_x,
    the default CPU tile, the modelled gains within 1%), and the measured
    human_col column-ablation flat/blocked win must not shrink below
    committed/headroom — the same-window interleaved A/B the PR 8 layout
    claim rests on.

Fails (exit 1) on any regression beyond the headroom factor.
"""
from __future__ import annotations

import argparse
import json
import math
import sys

GATED_SIZES = ("default", "rodent16", "human_col")
METRIC = "scan_us_per_tick"
# (size, ablated phase) pairs gated when a phase baseline is supplied
GATED_PHASES = (("human_col", "column_update"),)
HEADROOM = 1.25
SERVING_METRIC = "qps_at_slo"
SERVING_HEADROOM = 2.0
WEAK_HEADROOM = 1.5          # ratio-of-ratios on a 2-core shared runner
MODEL_RTOL = 0.01            # closed-form model drift tolerance


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--committed", default=None,
                    help="path to the committed (baseline) tick-loop JSON; "
                         "enables the tick-loop gate")
    ap.add_argument("--fresh", default="BENCH_tick_loop.json",
                    help="path to the freshly measured tick-loop JSON")
    ap.add_argument("--phase-committed", default=None,
                    help="committed (baseline) phase-breakdown JSON; "
                         "enables the column-phase gate")
    ap.add_argument("--phase-fresh", default="BENCH_phase_breakdown.json",
                    help="freshly measured phase-breakdown JSON")
    ap.add_argument("--serving-committed", default=None,
                    help="committed (baseline) serving JSON; enables the "
                         "rodent16 QPS-at-SLO gate")
    ap.add_argument("--serving-fresh", default="BENCH_serving.json",
                    help="freshly measured serving JSON")
    ap.add_argument("--weak-scaling-committed", default=None,
                    help="committed (baseline) weak-scaling JSON; enables "
                         "the weak-scaling ratio + route-drop gate")
    ap.add_argument("--weak-scaling-fresh", default="BENCH_weak_scaling.json",
                    help="freshly measured weak-scaling JSON")
    ap.add_argument("--layout-committed", default=None,
                    help="committed (baseline) Fig 10 layout JSON; enables "
                         "the layout-model gate")
    ap.add_argument("--layout-fresh", default="BENCH_layout.json",
                    help="freshly measured Fig 10 layout JSON")
    ap.add_argument("--headroom", type=float, default=HEADROOM)
    ap.add_argument("--serving-headroom", type=float,
                    default=SERVING_HEADROOM)
    ap.add_argument("--weak-headroom", type=float, default=WEAK_HEADROOM)
    args = ap.parse_args()

    failures = []
    if args.committed:
        committed = json.load(open(args.committed))
        fresh = json.load(open(args.fresh))
        for name in GATED_SIZES:
            old, new = committed[name][METRIC], fresh[name][METRIC]
            print(f"{name}/{METRIC}: committed {old:.1f} us, fresh "
                  f"{new:.1f} us ({new / old:.2f}x, "
                  f"limit {args.headroom:.2f}x)")
            if new > old * args.headroom:
                failures.append(f"{name}/{METRIC} {new:.1f} us exceeds "
                                f"committed {old:.1f} us by "
                                f">{args.headroom:.2f}x")

    if args.phase_committed:
        pc = json.load(open(args.phase_committed))
        pf = json.load(open(args.phase_fresh))
        for name, phase in GATED_PHASES:
            old = pc[name]["scan_ablation_us"][phase]
            new = pf[name]["scan_ablation_us"][phase]
            print(f"{name}/ablation/{phase}: committed {old:.1f} us, fresh "
                  f"{new:.1f} us ({new / old:.2f}x, "
                  f"limit {args.headroom:.2f}x)")
            if new > old * args.headroom:
                failures.append(
                    f"{name}/ablation/{phase} {new:.1f} us exceeds committed "
                    f"{old:.1f} us by >{args.headroom:.2f}x")

    if args.serving_committed:
        sc = json.load(open(args.serving_committed))
        sf = json.load(open(args.serving_fresh))
        old = sc["rodent16"][SERVING_METRIC]
        new = sf["rodent16"][SERVING_METRIC]
        hr = args.serving_headroom
        print(f"rodent16/{SERVING_METRIC}: committed {old:.2f} qps, fresh "
              f"{new:.2f} qps (floor {old / hr:.2f} qps at "
              f"{hr:.2f}x headroom)")
        if new == 0:
            failures.append(
                f"rodent16/{SERVING_METRIC} is 0 — p95 sojourn "
                f"{sf['rodent16']['p95_sojourn_ms']:.0f} ms missed the "
                f"{sf['rodent16']['slo_ms']:.0f} ms SLO")
        elif new < old / hr:
            failures.append(
                f"rodent16/{SERVING_METRIC} {new:.2f} qps below committed "
                f"{old:.2f} qps by >{hr:.2f}x")

    if args.weak_scaling_committed:
        wc = json.load(open(args.weak_scaling_committed))
        wf = json.load(open(args.weak_scaling_fresh))
        key = "us_per_tick_ratio_max_over_1"
        old, new = wc["scaling"][key], wf["scaling"][key]
        hr = args.weak_headroom
        print(f"weak_scaling/{key}: committed {old:.3f}, fresh {new:.3f} "
              f"({new / old:.2f}x, limit {hr:.2f}x)")
        if new > old * hr:
            failures.append(
                f"weak_scaling/{key} {new:.3f} exceeds committed {old:.3f} "
                f"by >{hr:.2f}x")
        for n, entry in sorted(wf["devices"].items(), key=lambda kv: int(kv[0])):
            got = entry["drops"]["route"]
            budget = math.ceil(entry["fig7_budget"]["route"])
            base = wc["devices"].get(n, {}).get("drops", {}).get("route", 0)
            allowed = max(budget, base)
            print(f"weak_scaling/{n}dev/drops_route: {got} "
                  f"(allowed {allowed}: max(committed {base}, "
                  f"fig7 budget {budget}))")
            if got > allowed:
                failures.append(
                    f"weak_scaling/{n}dev drops_route {got} exceeds "
                    f"max(committed {base}, Fig 7 budget {budget})")

    if args.layout_committed:
        lc = json.load(open(args.layout_committed))
        lf = json.load(open(args.layout_fresh))
        checks = [
            ("paper_dram_model/best_x",
             lc["paper_dram_model"]["best_x"],
             lf["paper_dram_model"]["best_x"], "exact"),
            ("paper_dram_model/gain_vs_direct",
             lc["paper_dram_model"]["gain_vs_direct"],
             lf["paper_dram_model"]["gain_vs_direct"], "rtol"),
            ("cpu_cache_line_model/default_tile",
             lc["cpu_cache_line_model"]["default_tile"],
             lf["cpu_cache_line_model"]["default_tile"], "exact"),
            ("cpu_cache_line_model/flat_over_default",
             lc["cpu_cache_line_model"]["flat_over_default"],
             lf["cpu_cache_line_model"]["flat_over_default"], "rtol"),
        ]
        for name, old, new, kind in checks:
            print(f"layout/{name}: committed {old}, fresh {new}")
            bad = (old != new if kind == "exact"
                   else abs(new - old) > MODEL_RTOL * abs(old))
            if bad:
                failures.append(f"layout/{name} changed: committed {old}, "
                                f"fresh {new} (model regression)")
        if "measured_human_col" in lc:
            if "measured_human_col" not in lf:
                failures.append("layout/measured_human_col missing from "
                                "fresh BENCH_layout.json")
            else:
                k = "column_ablation_flat_over_blocked"
                old = lc["measured_human_col"][k]
                new = lf["measured_human_col"][k]
                hr = args.headroom
                print(f"layout/{k}: committed {old:.2f}x, fresh {new:.2f}x "
                      f"(floor {old / hr:.2f}x at {hr:.2f}x headroom)")
                if new < old / hr:
                    failures.append(
                        f"layout/{k} {new:.2f}x below committed {old:.2f}x "
                        f"by >{hr:.2f}x — the Row-Merge column win shrank")

    if failures:
        sys.exit("perf regression: " + "; ".join(failures))


if __name__ == "__main__":
    main()
