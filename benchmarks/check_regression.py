"""Tick-loop benchmark regression gate (shared by CI and `make ci-local`).

  PYTHONPATH=src python -m benchmarks.check_regression \
      --committed /tmp/BENCH_committed.json [--fresh BENCH_tick_loop.json]

Compares a freshly measured BENCH_tick_loop.json against the committed one
and fails (exit 1) if any gated size's `scan_us_per_tick` regresses beyond
the headroom factor. The headroom (1.25x) absorbs CI-runner noise while
still catching the step-function regressions that matter (a lost in-place
alias or an accidental full-plane copy is 2x+, never 1.1x). See
docs/BENCHMARKING.md.
"""
from __future__ import annotations

import argparse
import json
import sys

GATED_SIZES = ("default", "rodent16", "human_col")
METRIC = "scan_us_per_tick"
HEADROOM = 1.25


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--committed", required=True,
                    help="path to the committed (baseline) JSON")
    ap.add_argument("--fresh", default="BENCH_tick_loop.json",
                    help="path to the freshly measured JSON")
    ap.add_argument("--headroom", type=float, default=HEADROOM)
    args = ap.parse_args()

    committed = json.load(open(args.committed))
    fresh = json.load(open(args.fresh))
    failures = []
    for name in GATED_SIZES:
        old, new = committed[name][METRIC], fresh[name][METRIC]
        print(f"{name}/{METRIC}: committed {old:.1f} us, fresh {new:.1f} us "
              f"({new / old:.2f}x, limit {args.headroom:.2f}x)")
        if new > old * args.headroom:
            failures.append(f"{name}/{METRIC} {new:.1f} us exceeds committed "
                            f"{old:.1f} us by >{args.headroom:.2f}x")
    if failures:
        sys.exit("perf regression: " + "; ".join(failures))


if __name__ == "__main__":
    main()
