"""Benchmark regression gates (shared by CI and `make ci-local`).

  PYTHONPATH=src python -m benchmarks.check_regression \
      --committed /tmp/BENCH_committed.json [--fresh BENCH_tick_loop.json] \
      [--phase-committed /tmp/BENCH_phase_committed.json \
       --phase-fresh BENCH_phase_breakdown.json] \
      [--serving-committed /tmp/BENCH_serving_committed.json \
       --serving-fresh BENCH_serving.json]

Three gates, all with the same headroom philosophy — headroom absorbs
CI-runner noise while still catching the step-function regressions that
matter (a lost in-place alias or an accidental full-plane copy is 2x+,
never 1.1x):

  * tick loop — any gated size's `scan_us_per_tick` in BENCH_tick_loop.json
    vs the committed baseline (1.25x headroom);
  * column phase (optional, when --phase-committed is given) — the
    human_col `column_update` scan-context ablation delta in
    BENCH_phase_breakdown.json. This is the phase the PR 8 column-blocked
    layout targets, gated so a later change can't silently hand the
    Row-Merge win back (docs/BENCHMARKING.md);
  * serving throughput (optional, when --serving-committed is given) — the
    rodent16 `qps_at_slo` in BENCH_serving.json. This gate is INVERTED
    (higher is better): it fails when the fresh throughput drops below
    committed/headroom, and unconditionally when qps_at_slo == 0 (the p95
    sojourn missed the SLO — a latency blow-up, not just slowness).
    Throughput on shared runners is noisier than the min-estimator tick
    numbers, hence the wider 2x headroom.

Fails (exit 1) on any regression beyond the headroom factor.
"""
from __future__ import annotations

import argparse
import json
import sys

GATED_SIZES = ("default", "rodent16", "human_col")
METRIC = "scan_us_per_tick"
# (size, ablated phase) pairs gated when a phase baseline is supplied
GATED_PHASES = (("human_col", "column_update"),)
HEADROOM = 1.25
SERVING_METRIC = "qps_at_slo"
SERVING_HEADROOM = 2.0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--committed", required=True,
                    help="path to the committed (baseline) tick-loop JSON")
    ap.add_argument("--fresh", default="BENCH_tick_loop.json",
                    help="path to the freshly measured tick-loop JSON")
    ap.add_argument("--phase-committed", default=None,
                    help="committed (baseline) phase-breakdown JSON; "
                         "enables the column-phase gate")
    ap.add_argument("--phase-fresh", default="BENCH_phase_breakdown.json",
                    help="freshly measured phase-breakdown JSON")
    ap.add_argument("--serving-committed", default=None,
                    help="committed (baseline) serving JSON; enables the "
                         "rodent16 QPS-at-SLO gate")
    ap.add_argument("--serving-fresh", default="BENCH_serving.json",
                    help="freshly measured serving JSON")
    ap.add_argument("--headroom", type=float, default=HEADROOM)
    ap.add_argument("--serving-headroom", type=float,
                    default=SERVING_HEADROOM)
    args = ap.parse_args()

    committed = json.load(open(args.committed))
    fresh = json.load(open(args.fresh))
    failures = []
    for name in GATED_SIZES:
        old, new = committed[name][METRIC], fresh[name][METRIC]
        print(f"{name}/{METRIC}: committed {old:.1f} us, fresh {new:.1f} us "
              f"({new / old:.2f}x, limit {args.headroom:.2f}x)")
        if new > old * args.headroom:
            failures.append(f"{name}/{METRIC} {new:.1f} us exceeds committed "
                            f"{old:.1f} us by >{args.headroom:.2f}x")

    if args.phase_committed:
        pc = json.load(open(args.phase_committed))
        pf = json.load(open(args.phase_fresh))
        for name, phase in GATED_PHASES:
            old = pc[name]["scan_ablation_us"][phase]
            new = pf[name]["scan_ablation_us"][phase]
            print(f"{name}/ablation/{phase}: committed {old:.1f} us, fresh "
                  f"{new:.1f} us ({new / old:.2f}x, "
                  f"limit {args.headroom:.2f}x)")
            if new > old * args.headroom:
                failures.append(
                    f"{name}/ablation/{phase} {new:.1f} us exceeds committed "
                    f"{old:.1f} us by >{args.headroom:.2f}x")

    if args.serving_committed:
        sc = json.load(open(args.serving_committed))
        sf = json.load(open(args.serving_fresh))
        old = sc["rodent16"][SERVING_METRIC]
        new = sf["rodent16"][SERVING_METRIC]
        hr = args.serving_headroom
        print(f"rodent16/{SERVING_METRIC}: committed {old:.2f} qps, fresh "
              f"{new:.2f} qps (floor {old / hr:.2f} qps at "
              f"{hr:.2f}x headroom)")
        if new == 0:
            failures.append(
                f"rodent16/{SERVING_METRIC} is 0 — p95 sojourn "
                f"{sf['rodent16']['p95_sojourn_ms']:.0f} ms missed the "
                f"{sf['rodent16']['slo_ms']:.0f} ms SLO")
        elif new < old / hr:
            failures.append(
                f"rodent16/{SERVING_METRIC} {new:.2f} qps below committed "
                f"{old:.2f} qps by >{hr:.2f}x")

    if failures:
        sys.exit("perf regression: " + "; ".join(failures))


if __name__ == "__main__":
    main()
