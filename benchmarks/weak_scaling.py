"""Weak-scaling sweep of the sharded BCPNN runtime (BENCH_weak_scaling.json).

  PYTHONPATH=src python -m benchmarks.weak_scaling [--legacy-cpu] [--json] \
      [--device-counts 1,2,4] [--ticks 64] [--repeats 3]

The paper's system argument (§I, §III.A): spike traffic (~250 GB/s) is three
orders of magnitude below synaptic weight traffic (~200 TB/s), which is what
makes a tiled message-passing cortex feasible. This sweep measures that
claim's software twin: HCUs-per-device held fixed while the device count
grows, every tick exchanging only fired spike words through the
capacity-bounded sparse `SparseExchange` (`core/distributed.py`), sized by
`default_route_config`'s Fig 7 Poisson math and overlapped with the column
plane phase.

Per swept device count N (each in its own subprocess — the forced
host-platform device count must be set before jax initializes):

  * `scan_us_per_tick`    — min-over-repeats wall clock of `make_dist_run`
                            (T ticks per compiled call);
  * `bytes_per_tick`      — the exchange payload: the static RouteConfig
                            model (N^2 * cap_route words) and the all_to_all
                            bytes parsed from the optimized HLO
                            (`launch/roofline.collective_bytes`);
  * `collective_bound_us` — that payload against the roofline ICI bound
                            (ICI_BW * ICI_LINKS), the paper-style check that
                            the spike fabric is nowhere near the limiting
                            resource;
  * `drops` / `fig7_budget` — observed per-class drop counters from the
                            deterministic T-tick run vs the per-class
                            `HealthMonitor` Fig 7 budgets at this mesh's
                            capacity;
  * `remesh` (N >= 2)     — mid-sweep elastic rescale: the live state is
                            re-placed onto an N/2-device mesh
                            (`runtime.elastic.remesh_network`) and the run
                            continues there — the data-movement cost and the
                            post-remesh tick are recorded.

Forced host "devices" share one machine's cores, so us/tick GROWS with N at
fixed HCUs/device here (threads contend instead of scaling); the committed
curve's N_max/N_1 ratio is still a real contract — a broken exchange or a
lost overlap shifts it by integer factors — and is gated in CI by
`benchmarks.check_regression --weak-scaling-committed`. Drop counts are
exactly reproducible (the trajectory is deterministic), so the gate holds
them against max(committed, Fig 7 budget).
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import time

HCUS_PER_DEVICE = 4
# rodent-scale per-HCU dimensioning (worklist regime; 4 devices == rodent16)
ROWS, COLS, FANOUT = 1200, 70, 16
TICKS, REPEATS = 64, 3
DEVICE_COUNTS = (1, 2, 4)


def _params(n_dev: int):
    from repro.core.params import BCPNNParams
    return BCPNNParams(n_hcu=HCUS_PER_DEVICE * n_dev, rows=ROWS, cols=COLS,
                       fanout=FANOUT, active_queue=16, max_delay=16)


def _child(args) -> dict:
    """Measure one device count inside a forced-device-count subprocess."""
    if args.legacy_cpu:
        from benchmarks.run import pin_legacy_cpu_runtime
        pin_legacy_cpu_runtime()
    import jax
    from benchmarks.tick_loop import _ext_tensor
    from repro.core import init_network, make_connectivity
    from repro.core import distributed as DD
    from repro.core.network import drop_counters
    from repro.launch import roofline as RL
    from repro.launch.mesh import make_bcpnn_mesh, make_elastic_mesh
    from repro.runtime import remesh_network
    from repro.runtime.resilience import HealthMonitor

    ndev = args.child
    assert len(jax.devices()) == ndev, (len(jax.devices()), ndev)
    p = _params(ndev)
    T = args.ticks
    key = jax.random.PRNGKey(0)
    conn = make_connectivity(p, jax.random.fold_in(key, 1))
    ext = _ext_tensor(p, T)

    mesh = make_bcpnn_mesh(ndev)
    rc = DD.default_route_config(p, HCUS_PER_DEVICE, n_dev=ndev)
    fn = DD.make_dist_run(mesh, p, rc)
    s, c = DD.shard_network(mesh, init_network(p, key), conn)
    compiled = fn.lower(s, c, ext).compile()
    coll = RL.collective_bytes(compiled.as_text(), loop_factor=float(T))

    # deterministic drop accounting: the first T ticks from the fresh init
    s, f = compiled(s, c, ext)
    jax.block_until_ready(f)
    drops = drop_counters(s)

    times = []
    for _ in range(args.repeats):
        t0 = time.perf_counter()
        s, f = compiled(s, c, ext)      # donated carry: feed the state back
        jax.block_until_ready(f)
        times.append((time.perf_counter() - t0) / T)

    hm = HealthMonitor(p, n_hcu=p.n_hcu)
    hm.set_mesh(ndev, rc)
    hm.ticks = T
    budgets = hm.class_budgets()

    word = 4 if rc.pack else 16
    a2a_per_tick = coll["all-to-all"] / T
    out = {
        "n_dev": ndev,
        "n_hcu": p.n_hcu,
        "h_local": HCUS_PER_DEVICE,
        "scan_us_per_tick": min(times) * 1e6,
        "cap_fire": rc.cap_fire,
        "cap_route": rc.cap_route,
        "bytes_per_tick": {
            "payload_total": ndev * ndev * rc.cap_route * word,
            "off_device": ndev * (ndev - 1) * rc.cap_route * word,
            "hlo_all_to_all": a2a_per_tick,
        },
        "collective_bound_us_per_tick":
            a2a_per_tick / (RL.ICI_BW * RL.ICI_LINKS) * 1e6,
        "drops": {k: int(v) for k, v in drops.items()},
        "fig7_budget": {k: float(v) for k, v in budgets.items()},
    }

    if ndev >= 2 and not args.no_remesh:
        # elastic rescale mid-sweep: re-place the live state onto the
        # half-size mesh and keep running there (pure data movement)
        nd2 = ndev // 2
        t0 = time.perf_counter()
        mesh2 = make_elastic_mesh(p.n_hcu, jax.devices()[:nd2])
        s2, c2 = remesh_network(s, c, mesh2)
        jax.block_until_ready(s2.hcus.zij)
        remesh_ms = (time.perf_counter() - t0) * 1e3
        rc2 = DD.default_route_config(p, p.n_hcu // nd2, n_dev=nd2)
        fn2 = DD.make_dist_run(mesh2, p, rc2)
        s2, f2 = fn2(s2, c2, ext)
        jax.block_until_ready(f2)
        t0 = time.perf_counter()
        s2, f2 = fn2(s2, c2, ext)
        jax.block_until_ready(f2)
        out["remesh"] = {
            "to_devices": nd2,
            "remesh_ms": remesh_ms,
            "post_remesh_us_per_tick":
                (time.perf_counter() - t0) / T * 1e6,
            "drops_after": {k: int(v)
                            for k, v in drop_counters(s2).items()},
        }
    return out


def _spawn(n_dev: int, args) -> dict:
    from repro.launch.mesh import force_host_device_count_flags
    env = os.environ.copy()
    env["XLA_FLAGS"] = force_host_device_count_flags(n_dev)
    # forced host devices only mean anything on the CPU platform
    env["JAX_PLATFORMS"] = "cpu"
    cmd = [sys.executable, "-m", "benchmarks.weak_scaling",
           "--child", str(n_dev), "--ticks", str(args.ticks),
           "--repeats", str(args.repeats)]
    if args.legacy_cpu:
        cmd.append("--legacy-cpu")
    if args.no_remesh:
        cmd.append("--no-remesh")
    r = subprocess.run(cmd, capture_output=True, text=True, env=env)
    if r.returncode != 0:
        raise RuntimeError(f"weak-scaling child (n_dev={n_dev}) failed:\n"
                           f"{r.stderr[-3000:]}")
    return json.loads(r.stdout)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--device-counts", default=",".join(
        str(n) for n in DEVICE_COUNTS))
    ap.add_argument("--ticks", type=int, default=TICKS)
    ap.add_argument("--repeats", type=int, default=REPEATS)
    ap.add_argument("--legacy-cpu", action="store_true",
                    help="pin the legacy XLA CPU runtime in every child "
                         "(matches the committed BENCH_*.json configuration)")
    ap.add_argument("--json", action="store_true",
                    help="print the JSON blob (the file is written anyway)")
    ap.add_argument("--no-remesh", action="store_true",
                    help="skip the mid-sweep elastic remesh leg")
    ap.add_argument("--child", type=int, default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    if args.child is not None:
        json.dump(_child(args), sys.stdout)
        print()
        return

    counts = sorted({int(x) for x in args.device_counts.split(",") if x})
    results = {
        "suite": "weak_scaling",
        "hcus_per_device": HCUS_PER_DEVICE,
        "size": {"rows": ROWS, "cols": COLS, "fanout": FANOUT},
        "ticks": args.ticks,
        "repeats": args.repeats,
        "estimator": "min-over-repeats",
        "devices": {},
        "caveats": "forced host devices share one machine's cores, so "
                   "us/tick grows with the device count at fixed "
                   "HCUs/device; the gated contract is the N_max/N_1 ratio "
                   "and the (deterministic) drop counters, not absolute "
                   "wall clock",
    }
    for n in counts:
        print(f"# measuring n_dev={n} "
              f"({HCUS_PER_DEVICE * n} HCUs)...", file=sys.stderr)
        results["devices"][str(n)] = _spawn(n, args)

    scaling = {"counts": counts}
    if 1 in counts and max(counts) > 1:
        one = results["devices"]["1"]["scan_us_per_tick"]
        top = results["devices"][str(max(counts))]["scan_us_per_tick"]
        scaling["us_per_tick_ratio_max_over_1"] = top / one
    results["scaling"] = scaling

    from repro.launch import roofline as RL
    results["roofline"] = {"ici_bw_Bps": RL.ICI_BW, "ici_links": RL.ICI_LINKS}

    out = pathlib.Path(__file__).resolve().parent.parent \
        / "BENCH_weak_scaling.json"
    out.write_text(json.dumps(results, indent=2) + "\n")
    print(f"# wrote {out}", file=sys.stderr)

    if args.json:
        json.dump(results, sys.stdout, indent=2)
        print()
        return
    print("name,us_per_call,derived")
    for n in counts:
        d = results["devices"][str(n)]
        print(f"weak_scaling/{n}dev/scan_us_per_tick,"
              f"{d['scan_us_per_tick']:.3f},0")
        print(f"weak_scaling/{n}dev/bytes_per_tick,0.000,"
              f"{d['bytes_per_tick']['payload_total']}")
        print(f"weak_scaling/{n}dev/drops_route,0.000,"
              f"{d['drops']['route']}")
    if "us_per_tick_ratio_max_over_1" in scaling:
        print(f"weak_scaling/ratio_max_over_1,0.000,"
              f"{scaling['us_per_tick_ratio_max_over_1']:.4g}")


if __name__ == "__main__":
    main()
