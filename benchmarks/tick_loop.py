"""Tick-loop runtime benchmark: per-tick host loop vs scan-compiled runtime.

The tentpole perf claim of the compiled runtime (core/network.py): the
per-tick host loop pays one jit dispatch + one device sync per simulated ms,
which dominates wall-clock long before the fused cell math does; `network_run`
compiles the whole loop with lax.scan and pays one dispatch per chunk.

Three sizes are measured (CPU `ref` backend):
  * default — small planes, the dispatch-bound regime the scan runtime is
    built to eliminate (this is the size the ≥5x acceptance gate runs at);
    stays on the per-HCU fused dense forms (below `hcu.use_worklist`).
  * rodent16 — rodent-ish R/C dimensioning (R=1200, C=70, 16 HCUs) on the
    worklist engine backend: since PR 4 the row phase runs as the fused
    single pass (`engine.worklist_lazy_rows` fused branch), so the tick is
    O(touched rows) with ONE loop walk and compute on valid entries only.
    Gated in CI alongside `default` since PR 3.
  * human_col — one human-scale hypercolumn slab: 4 HCUs at the paper's
    §II.A per-HCU dimensioning (R=10000, C=100, from
    `repro.configs.bcpnn_human`). This is the size whose per-row cost the
    paper's EQ2 budget is written about; it tracks that the worklist tick
    stays O(touched rows) when the planes are 25 MB/HCU. Gated in CI since
    PR 4.
  * human_col_blocked — the same slab stored under the Row-Merge
    column-blocked plane layout (PR 8, `layout="blocked"`): the end-to-end
    per-tick layout A/B. Not regression-gated; the targeted column-phase
    gate runs on the BENCH_phase_breakdown.json ablation.

All sizes are driven through the `Simulator` facade (scan runtime
`sim.run` vs host loop `sim.run_host`).

`python -m benchmarks.run --json` writes the results to BENCH_tick_loop.json.
The committed numbers are measured with `--legacy-cpu` (benchmarks.run's
opt-in pin of `--xla_cpu_use_thunk_runtime=false`): the legacy XLA CPU
runtime executes the identical HLO with ~3-4x lower per-op overhead, for
the host loop and the scan runtime alike. docs/BENCHMARKING.md has the
full workflow (regenerating the JSON, the CI regression gate, `make
profile`).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.bcpnn_human import CONFIG as HUMAN_CFG
from repro.core import Simulator
from repro.core.params import BCPNNParams

# dispatch-bound default: the acceptance gate (scan >= 5x host ticks/sec)
DEFAULT = ("default", BCPNNParams(n_hcu=8, rows=128, cols=16, fanout=8,
                                  active_queue=16, max_delay=16))
RODENT = ("rodent16", BCPNNParams(n_hcu=16, rows=1200, cols=70, fanout=16,
                                  active_queue=16, max_delay=16))
# one human-scale hypercolumn slab: paper per-HCU dimensioning (R=10000,
# C=100), bench-sized HCU count/queues like rodent16
HUMAN_COL = ("human_col", BCPNNParams(n_hcu=4, rows=HUMAN_CFG.rows,
                                      cols=HUMAN_CFG.cols, fanout=4,
                                      active_queue=16, max_delay=16))
# the same slab under the PR 8 Row-Merge column-blocked plane layout
# (layout="blocked", the CPU tile) — an end-to-end per-tick A/B against
# human_col in the same committed JSON; NOT regression-gated (the flat
# entries stay the gated baseline), the column-phase gate lives on the
# BENCH_phase_breakdown.json ablation instead
HUMAN_COL_BLOCKED = ("human_col_blocked", HUMAN_COL[1], "blocked")

N_SCAN = 128         # ticks per measured scan call (one compiled chunk)
N_HOST = 32          # ticks per measured host-loop pass
REPEATS = 5          # min over repeats (see note below)

# The estimator is MIN over repeats, not median: CI runners and shared dev
# VMs burst-throttle (measured on the dev box: a 10x CPU-speed swing within
# one minute), and contention is strictly additive noise on a deterministic
# computation — the fastest observed repeat is the best estimate of the
# code's cost, where a median of 3 is a lottery ticket on the throttle
# phase. The committed numbers and the CI regression gate both use this
# estimator (PR 5; earlier JSONs were medians of 3, so the PR 5
# regeneration is the comparison floor going forward).


def _ext_tensor(p, T, width=8, lam=4.0, seed=0):
    rng = np.random.default_rng(seed)
    out = np.full((T, p.n_hcu, width), p.rows, np.int32)
    for t in range(T):
        for h in range(p.n_hcu):
            n = min(width, rng.poisson(lam))
            out[t, h, :n] = rng.integers(0, p.rows, n)
    return jnp.asarray(out)


def _measure(p, backend="ref", layout=None):
    """Returns (host_us_per_tick, scan_us_per_tick), min over REPEATS."""
    sim = Simulator(p, key=0, kernel=backend, chunk=N_SCAN, layout=layout)
    ext = _ext_tensor(p, N_SCAN)

    # warm both compilation caches
    sim.run_host(lambda t: ext[(t - 1) % N_SCAN], 2)
    sim.reset()
    sim.run(ext)
    jax.block_until_ready(sim.state.hcus.zij)

    host_t, scan_t = [], []
    for _ in range(REPEATS):
        sim.reset()
        t0 = time.perf_counter()
        f = sim.run_host(lambda t: ext[(t - 1) % N_SCAN], N_HOST)
        jax.block_until_ready(f)
        host_t.append((time.perf_counter() - t0) / N_HOST)

        sim.reset()
        t0 = time.perf_counter()
        f = sim.run(ext)
        jax.block_until_ready(f)
        scan_t.append((time.perf_counter() - t0) / N_SCAN)
    return min(host_t) * 1e6, min(scan_t) * 1e6


def measure_sizes(sizes=(DEFAULT, RODENT, HUMAN_COL, HUMAN_COL_BLOCKED)):
    """Returns {name: {host_us_per_tick, scan_us_per_tick, host_ticks_per_sec,
    scan_ticks_per_sec, speedup, n_hcu, rows, cols}}. A size tuple may carry
    a third element: the plane layout to run under (see HUMAN_COL_BLOCKED)."""
    results = {}
    for name, p, *rest in sizes:
        layout = rest[0] if rest else None
        host_us, scan_us = _measure(p, layout=layout)
        results[name] = {
            "n_hcu": p.n_hcu, "rows": p.rows, "cols": p.cols,
            "host_us_per_tick": host_us, "scan_us_per_tick": scan_us,
            "host_ticks_per_sec": 1e6 / host_us,
            "scan_ticks_per_sec": 1e6 / scan_us,
            "speedup": host_us / scan_us,
        }
    return results


def tick_loop(results=None):
    """benchmarks.run suite hook: CSV rows from the measured sizes."""
    results = results or measure_sizes()
    rows = []
    for name, r in results.items():
        rows.append((f"tick_loop/{name}/host_us_per_tick",
                     r["host_us_per_tick"], r["host_ticks_per_sec"]))
        rows.append((f"tick_loop/{name}/scan_us_per_tick",
                     r["scan_us_per_tick"], r["scan_ticks_per_sec"]))
        rows.append((f"tick_loop/{name}/scan_speedup", 0.0, r["speedup"]))
    return rows
