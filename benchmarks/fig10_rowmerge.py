"""Fig 9-10 Row-Merge layout benchmark: model tables + a measured CPU A/B.

  PYTHONPATH=src python -m benchmarks.fig10_rowmerge [--legacy-cpu] [--json]

Always writes ``BENCH_layout.json`` at the repo root (uploaded as a CI
artifact next to BENCH_tick_loop.json). Three sections:

  * paper_dram_model — the paper's own Fig 10 objective: DRAM row misses/s
    vs the merge factor X for the §II.A human HCU (R=10000, C=100) at the
    BCPNN access rates (10 kHz rows, 100 Hz columns). Minimum at X=10,
    5.05x fewer misses than direct (X=1) — `layout.dram_row_misses_per_s`.
  * tpu_tile_model / cpu_cache_line_model — the same trade-off re-derived
    for our two execution substrates. TPU: HBM bytes touched/s over (8k,
    128m) register-tile shapes (`layout.tile_bytes_touched_per_s`,
    minimized by `layout.best_tile`). CPU: 64-byte cache lines touched/s
    (`layout.cache_lines_touched_per_s`) over candidate `BlockedLayout`
    tiles vs the flat row-major plane — the model that picks the default
    CPU tile (`layout.CPU_BLOCK_XR/XC`).
  * measured_human_col — a same-process, same-machine-window wall-clock
    A/B of the worklist column phase at the human_col bench size
    (benchmarks/tick_loop.py), canonical flat vs the column-blocked CPU
    tile: the full production scan (`engine.tick` under `lax.scan`,
    donated carry) minus the same scan with the column phase ablated, the
    scan-context methodology of benchmarks/profile_phases.py. Estimator is
    MIN over interleaved repeats (contention is additive noise; see
    tick_loop.py). Caveats: ablation deltas are O(phase) accurate, not
    exact — ablating the column phase perturbs downstream spike
    trajectories — and the flat/blocked deltas come from separately
    compiled scans, so XLA fusion differences are part of what is being
    measured (that is the point: the layout pays off only if the compiled
    artifact does).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time


def measure_column_ab(p, layouts, ticks=64, repeats=5):
    """Scan-context column-phase ablation per layout. Returns
    {tag: {scan_us_per_tick, column_update_ablation_us}} using one
    interleaved measurement window for all variants."""
    import functools
    from typing import NamedTuple

    import jax

    from benchmarks.tick_loop import _ext_tensor
    from repro.core import engine as E
    from repro.core import layout as L
    from repro.core import network as N

    class _NoColumns(NamedTuple):
        """Worklist backend with the lazy column phase swapped for a no-op
        (benchmark-only recomposition — tracks WorklistBackend.plane_update
        the same way profile_phases.AblatedBackend does)."""
        base: object

        def carry_in(self, state, p):
            return self.base.carry_in(state, p)

        def carry_out(self, state, p):
            return self.base.carry_out(state, p)

        def plane_update(self, state, rows, t, keys, p, cap, cond_columns):
            hcus, w_rows, c = E.worklist_lazy_rows(
                state.hcus, rows, t, p, kernel=self.base.kernel,
                fused=self.base.fused, layout=self.base.layout)
            hcus, fired = E._wta(hcus, w_rows, c["counts"], t, keys, p)
            h_idx, j_idx, n_drop = N.select_fired(fired, cap)
            return state._replace(hcus=hcus), fired, h_idx, j_idx, n_drop

    key = jax.random.PRNGKey(0)
    conn = N.make_connectivity(p, jax.random.fold_in(key, 1))
    ext = _ext_tensor(p, ticks)

    def make_run(be):
        @functools.partial(jax.jit, donate_argnums=(0,))
        def run(state, ext):
            def body(s, e):
                return E.tick(s, conn, e, p, be)
            s, f = jax.lax.scan(body, be.carry_in(state, p), ext)
            return be.carry_out(s, p), f
        return run

    variants = {}
    for lay in layouts:
        base = E.select_backend(p, layout=lay)
        assert isinstance(base, E.WorklistBackend), \
            "the column A/B is about the worklist regime"
        tag = L.layout_tag(lay)
        variants[(tag, "full")] = (lay, make_run(base))
        variants[(tag, "nocol")] = (lay, make_run(_NoColumns(base)))

    for lay, fn in variants.values():             # compile + warm all first
        s, f = fn(N.init_network(p, key, layout=lay), ext)
        jax.block_until_ready(f)
    meas = {k: [] for k in variants}
    for _ in range(repeats):                      # interleaved rounds
        for k, (lay, fn) in variants.items():
            state = N.init_network(p, key, layout=lay)
            t0 = time.perf_counter()
            s, f = fn(state, ext)
            jax.block_until_ready(f)
            meas[k].append((time.perf_counter() - t0) / ticks)

    out = {}
    for lay in layouts:
        tag = L.layout_tag(lay)
        full = min(meas[(tag, "full")]) * 1e6
        nocol = min(meas[(tag, "nocol")]) * 1e6
        out[tag] = {"scan_us_per_tick": full,
                    "column_update_ablation_us": full - nocol}
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--legacy-cpu", action="store_true",
                    help="pin the legacy XLA CPU runtime (matches the "
                         "committed BENCH_*.json configuration)")
    ap.add_argument("--json", action="store_true",
                    help="print the JSON blob instead of CSV rows (the "
                         "file is written either way)")
    ap.add_argument("--fast", action="store_true",
                    help="model tables only; skip the measured A/B")
    ap.add_argument("--ticks", type=int, default=64)
    ap.add_argument("--repeats", type=int, default=5)
    args = ap.parse_args()
    if args.legacy_cpu:
        from benchmarks.run import pin_legacy_cpu_runtime
        pin_legacy_cpu_runtime()

    from benchmarks.tick_loop import HUMAN_COL
    from repro.core import layout as L

    R, C, ROW_HZ, COL_HZ = 10_000, 100, 10_000.0, 100.0

    table = L.paper_fig10_table()
    best_x = min(table, key=table.get)
    (txr, txc), tscored = L.best_tile(R, C, ROW_HZ, COL_HZ)

    cpu_tiles = [(1, C)] + [(xr, xc) for xr in (4, 8, 16)
                            for xc in (2, 4, 8, 16)]
    cpu_model = {f"{xr}x{xc}":
                 L.cache_lines_touched_per_s(xr, xc, R, C, ROW_HZ, COL_HZ)
                 for xr, xc in cpu_tiles}

    results = {
        "paper_dram_model": {
            "rowmiss_per_s": {str(x): table[x] for x in sorted(table)},
            "best_x": best_x,
            "gain_vs_direct": table[1] / table[best_x],
        },
        "tpu_tile_model": {
            "best_tile": [txr, txc],
            "bytes_per_s": {f"{xr}x{xc}": v
                            for (xr, xc), v in sorted(tscored.items())},
        },
        "cpu_cache_line_model": {
            "lines_per_s": cpu_model,
            "default_tile": [L.CPU_BLOCK_XR, L.CPU_BLOCK_XC],
            "flat_over_default":
                cpu_model[f"1x{C}"]
                / cpu_model[f"{L.CPU_BLOCK_XR}x{L.CPU_BLOCK_XC}"],
        },
    }

    if not args.fast:
        name, p = HUMAN_COL
        lay = L.cpu_blocked(p)
        ab = measure_column_ab(p, [None, lay], ticks=args.ticks,
                               repeats=args.repeats)
        flat, blocked = ab["flat"], ab[L.layout_tag(lay)]
        results["measured_human_col"] = {
            "size": {"n_hcu": p.n_hcu, "rows": p.rows, "cols": p.cols},
            "ticks": args.ticks, "repeats": args.repeats,
            "estimator": "min-over-interleaved-repeats",
            "layouts": ab,
            "column_ablation_flat_over_blocked":
                flat["column_update_ablation_us"]
                / max(blocked["column_update_ablation_us"], 1e-9),
            "caveats": "scan-context ablation deltas are O(phase) accurate "
                       "(ablating columns perturbs downstream spikes); "
                       "flat/blocked are separately compiled scans measured "
                       "in one interleaved same-machine window",
        }

    out = pathlib.Path(__file__).resolve().parent.parent \
        / "BENCH_layout.json"
    out.write_text(json.dumps(results, indent=2) + "\n")
    print(f"# wrote {out}", file=sys.stderr)

    if args.json:
        json.dump(results, sys.stdout, indent=2)
        print()
        return
    print("name,us_per_call,derived")
    for x in sorted(table):
        print(f"fig10/rowmiss_per_s_X{x},0.000,{table[x]:.6g}")
    print(f"fig10/best_X,0.000,{best_x}")
    print(f"fig10/cpu_lines_flat_over_default,0.000,"
          f"{results['cpu_cache_line_model']['flat_over_default']:.6g}")
    if "measured_human_col" in results:
        m = results["measured_human_col"]
        for tag, r in m["layouts"].items():
            print(f"fig10/human_col/{tag}/scan_us_per_tick,"
                  f"{r['scan_us_per_tick']:.3f},0")
            print(f"fig10/human_col/{tag}/column_ablation_us,"
                  f"{r['column_update_ablation_us']:.3f},0")
        print(f"fig10/human_col/column_ablation_flat_over_blocked,0.000,"
              f"{m['column_ablation_flat_over_blocked']:.6g}")


if __name__ == "__main__":
    main()
