"""Regenerate README.md's benchmark table from BENCH_tick_loop.json.

  python -m benchmarks.render_bench_table

Rewrites the block between the BENCH_TABLE_START/END markers in README.md
from the committed JSON, so the README numbers can never drift from the
measured trajectory (they are the same bytes). `make bench-json` runs this
after refreshing the JSON.
"""
from __future__ import annotations

import json
import pathlib
import re

ROOT = pathlib.Path(__file__).resolve().parent.parent
START = "<!-- BENCH_TABLE_START (generated from BENCH_tick_loop.json) -->"
END = "<!-- BENCH_TABLE_END -->"


def render_table(results: dict) -> str:
    lines = [
        "| size | H | R | C | host µs/tick | scan µs/tick | scan speedup |",
        "|---|---|---|---|---|---|---|",
    ]
    for name, r in results.items():
        lines.append(
            f"| {name} | {r['n_hcu']} | {r['rows']} | {r['cols']} "
            f"| {r['host_us_per_tick']:.1f} | {r['scan_us_per_tick']:.1f} "
            f"| {r['speedup']:.1f}x |")
    return "\n".join(lines)


def main() -> None:
    results = json.loads((ROOT / "BENCH_tick_loop.json").read_text())
    readme = ROOT / "README.md"
    text = readme.read_text()
    block = f"{START}\n{render_table(results)}\n{END}"
    new, n = re.subn(re.escape(START) + r".*?" + re.escape(END), block, text,
                     flags=re.S)
    if n != 1:
        raise SystemExit("README.md bench-table markers missing or duplicated")
    readme.write_text(new)
    print(f"README.md bench table regenerated ({len(results)} sizes)")


if __name__ == "__main__":
    main()
