"""Resilience benchmark: recall under DRAM-retention faults + drop-budget
health at rodent16.

  PYTHONPATH=src python -m benchmarks.resilience [--legacy-cpu] [--fast]

Two measurements, written to BENCH_resilience.json for CI trending (the
robustness analogue of BENCH_tick_loop.json):

1. recall_vs_flip_rate — the paper's relaxed-refresh 3D DRAM argument made
   quantitative: train the associative memory once (the protocol from
   `repro.experiments`), then for each per-bit fault rate corrupt the
   synaptic ij planes of a fresh copy of the trained state
   (`repro.runtime.resilience.inject_retention_faults`) and measure
   partial-cue pattern completion. Recall runs from an
   `repro.experiments.sram_loss` state (volatile j-vectors reset, planes
   kept) so completion is carried by the DRAM planes alone — without that,
   the trained pj bias recalls the attractor regardless of plane damage and
   the curve measures nothing. Two fault patterns are curved:
     * "clear" — hit bits forced to 0, the retention-decay pattern the
       paper's relaxed refresh produces (measured: recall survives per-bit
       clear rates up to ~0.9 — the extreme-tolerance claim);
     * "flip"  — hit bits inverted, generic soft errors (knee near 1e-4).
   The zero-rate points double as the functional gate
   (`benchmarks/check_resilience.py`): recall must stay well above chance.

2. rodent16_health — a crash-recovery run at the rodent16 benchmark size
   through `repro.runtime.resilience.ResilientRunner` (one injected failure,
   restore-and-replay) with the `HealthMonitor` drop-budget + realtime
   deadline report (Fig 7 analytic budget from `repro.core.queues`).

Cue masks and fault keys are derived from fixed seeds, so the curve is
deterministic up to wall-clock fields in the health report.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import tempfile

# retention decay tolerates extreme clear rates; generic flips knee ~1e-4
RATES = {"clear": (0.0, 0.1, 0.5, 0.8, 0.9, 0.95, 1.0),
         "flip": (0.0, 1e-6, 1e-5, 1e-4, 1e-3, 1e-2)}
N_PATTERNS = 3
TRAIN_REPS = 30


def recall_vs_flip_rate(rates=None, *, train_reps=TRAIN_REPS):
    """Train once, then measure cue->attractor completion from an SRAM-loss
    state at each per-bit fault rate and pattern. Returns
    ({mode: curve rows}, chance, config dict)."""
    import jax
    import numpy as np
    from repro.core import Simulator
    from repro.data import make_patterns
    from repro.experiments import (assoc_params, recall_accuracy, sram_loss,
                                   train_assoc)
    from repro.runtime import inject_retention_faults

    rates = rates if rates is not None else RATES
    p = assoc_params()
    sim = Simulator(p, key=0, cap_fire=p.n_hcu)
    patterns = make_patterns(p, N_PATTERNS, seed=3)
    attractor = train_assoc(sim, patterns, reps=train_reps)
    trained = jax.tree.map(np.array, sim.state)

    def corrupter(rate, mode):
        base = jax.random.PRNGKey(42)
        count = [0]

        def corrupt(state):
            count[0] += 1
            return inject_retention_faults(
                sram_loss(state, p), jax.random.fold_in(base, count[0]),
                rate, mode=mode)
        return corrupt

    curves = {}
    for mode, mode_rates in rates.items():
        curve = curves[mode] = []
        for rate in mode_rates:
            # fresh rng per point: identical cue masks across the curves
            correct, total = recall_accuracy(
                sim, trained, patterns, attractor,
                rng=np.random.default_rng(0), corrupt=corrupter(rate, mode))
            curve.append({"rate": rate, "correct": correct, "total": total,
                          "acc": correct / max(total, 1)})
            print(f"resilience/recall@{mode}_rate={rate:g}: "
                  f"{correct}/{total} (acc={curve[-1]['acc']:.2f})")
    cfg = {"n_hcu": p.n_hcu, "rows": p.rows, "cols": p.cols,
           "n_patterns": N_PATTERNS, "train_reps": train_reps,
           "recall": "sram_loss", "planes": "zij/eij/pij/wij/tij"}
    return curves, 1.0 / p.cols, cfg


def rodent16_health(n_ticks=256, chunk_ticks=64):
    """Crash-recovery run at the rodent16 size with one injected failure;
    returns the structured HealthMonitor report."""
    from benchmarks.tick_loop import RODENT, _ext_tensor
    from repro.core import Simulator
    from repro.runtime import ResilientRunner

    _, p = RODENT
    sim = Simulator(p, key=0, chunk=chunk_ticks)
    ext = _ext_tensor(p, n_ticks)
    fails = {2}

    def injector(chunk):
        if chunk in fails:
            fails.discard(chunk)
            return True
        return False

    with tempfile.TemporaryDirectory() as ckpt_dir:
        runner = ResilientRunner(sim, ckpt_dir, chunk_ticks=chunk_ticks,
                                 save_every=1, fail_injector=injector)
        fired, health = runner.run(ext)
    health["size"] = {"name": "rodent16", "n_hcu": p.n_hcu, "rows": p.rows,
                      "cols": p.cols, "n_ticks": int(n_ticks)}
    health["fired_ticks"] = int((fired >= 0).any(axis=1).sum())
    print(f"resilience/rodent16: status={health['status']} "
          f"drops={health['drops']['total']} "
          f"(budget {health['budget']['expected_drops_run']:.1f}) "
          f"restarts={health['restarts']} "
          f"{health['deadline']['observed_us_per_tick']:.0f} us/tick")
    return health


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="shorter training and rodent16 run (smoke test; "
                         "do not commit the resulting JSON)")
    ap.add_argument("--legacy-cpu", action="store_true",
                    help="pin the legacy XLA CPU runtime (the configuration "
                         "the committed numbers were measured with)")
    ap.add_argument("--out", default=None,
                    help="output path (default: <repo>/BENCH_resilience.json)")
    args = ap.parse_args()
    if args.legacy_cpu:
        from benchmarks.run import pin_legacy_cpu_runtime
        pin_legacy_cpu_runtime()

    train_reps = 10 if args.fast else TRAIN_REPS
    n_ticks = 128 if args.fast else 256
    curves, chance, cfg = recall_vs_flip_rate(train_reps=train_reps)
    health = rodent16_health(n_ticks=n_ticks)

    out = pathlib.Path(args.out) if args.out else \
        pathlib.Path(__file__).resolve().parent.parent \
        / "BENCH_resilience.json"
    out.write_text(json.dumps({
        "schema": 1,
        "config": cfg,
        "chance": chance,
        "recall_vs_flip_rate": curves,
        "rodent16_health": health,
    }, indent=2) + "\n")
    print(f"# wrote {out}", file=sys.stderr)


if __name__ == "__main__":
    main()
