"""Resilience benchmark: recall under DRAM-retention faults + drop-budget
health at rodent16.

  PYTHONPATH=src python -m benchmarks.resilience [--legacy-cpu] [--fast]

Two measurements, written to BENCH_resilience.json for CI trending (the
robustness analogue of BENCH_tick_loop.json):

1. recall_vs_flip_rate — the paper's relaxed-refresh 3D DRAM argument made
   quantitative: train the associative memory once (the protocol from
   `repro.experiments`), then for each per-bit fault rate corrupt the
   synaptic ij planes of a fresh copy of the trained state
   (`repro.runtime.resilience.inject_retention_faults`) and measure
   partial-cue pattern completion. Recall runs from an
   `repro.experiments.sram_loss` state (volatile j-vectors reset, planes
   kept) so completion is carried by the DRAM planes alone — without that,
   the trained pj bias recalls the attractor regardless of plane damage and
   the curve measures nothing. Two fault patterns are curved:
     * "clear" — hit bits forced to 0, the retention-decay pattern the
       paper's relaxed refresh produces (measured: recall survives per-bit
       clear rates up to ~0.9 — the extreme-tolerance claim);
     * "flip"  — hit bits inverted, generic soft errors (knee near 1e-4).
   The zero-rate points double as the functional gate
   (`benchmarks/check_resilience.py`): recall must stay well above chance.

2. rodent16_health — a crash-recovery run at the rodent16 benchmark size
   through `repro.runtime.resilience.ResilientRunner` (one injected failure,
   restore-and-replay) with the `HealthMonitor` drop-budget + realtime
   deadline report (Fig 7 analytic budget from `repro.core.queues`).

3. device_loss — the degraded-mode elasticity scenario at rodent16: a
   sharded run on 4 (forced host-platform) devices loses 2 mid-run;
   `repro.runtime.resilience.ElasticRunner` restores the latest checkpoint,
   remeshes all hypercolumns onto the 2 survivors, re-lowers, and replays.
   Reported: recovery wall time, restart count, post-recovery drop health at
   the NEW capacity, and whether the completed trajectory is bitwise
   identical to the uninterrupted single-process run (it must be — the
   lossless-route mesh-shape-invariance contract; gated in
   `benchmarks/check_resilience.py`). Runs in a child process because
   XLA's forced device count must be set before jax initializes.

Cue masks and fault keys are derived from fixed seeds, so the curve is
deterministic up to wall-clock fields in the health report.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import tempfile
import time

# retention decay tolerates extreme clear rates; generic flips knee ~1e-4
RATES = {"clear": (0.0, 0.1, 0.5, 0.8, 0.9, 0.95, 1.0),
         "flip": (0.0, 1e-6, 1e-5, 1e-4, 1e-3, 1e-2)}
N_PATTERNS = 3
TRAIN_REPS = 30


def recall_vs_flip_rate(rates=None, *, train_reps=TRAIN_REPS):
    """Train once, then measure cue->attractor completion from an SRAM-loss
    state at each per-bit fault rate and pattern. Returns
    ({mode: curve rows}, chance, config dict)."""
    import jax
    import numpy as np
    from repro.core import Simulator
    from repro.data import make_patterns
    from repro.experiments import (assoc_params, recall_accuracy, sram_loss,
                                   train_assoc)
    from repro.runtime import inject_retention_faults

    rates = rates if rates is not None else RATES
    p = assoc_params()
    sim = Simulator(p, key=0, cap_fire=p.n_hcu)
    patterns = make_patterns(p, N_PATTERNS, seed=3)
    attractor = train_assoc(sim, patterns, reps=train_reps)
    trained = jax.tree.map(np.array, sim.state)

    def corrupter(rate, mode):
        base = jax.random.PRNGKey(42)
        count = [0]

        def corrupt(state):
            count[0] += 1
            return inject_retention_faults(
                sram_loss(state, p), jax.random.fold_in(base, count[0]),
                rate, mode=mode)
        return corrupt

    curves = {}
    for mode, mode_rates in rates.items():
        curve = curves[mode] = []
        for rate in mode_rates:
            # fresh rng per point: identical cue masks across the curves
            correct, total = recall_accuracy(
                sim, trained, patterns, attractor,
                rng=np.random.default_rng(0), corrupt=corrupter(rate, mode))
            curve.append({"rate": rate, "correct": correct, "total": total,
                          "acc": correct / max(total, 1)})
            print(f"resilience/recall@{mode}_rate={rate:g}: "
                  f"{correct}/{total} (acc={curve[-1]['acc']:.2f})")
    cfg = {"n_hcu": p.n_hcu, "rows": p.rows, "cols": p.cols,
           "n_patterns": N_PATTERNS, "train_reps": train_reps,
           "recall": "sram_loss", "planes": "zij/eij/pij/wij/tij"}
    return curves, 1.0 / p.cols, cfg


def rodent16_health(n_ticks=256, chunk_ticks=64):
    """Crash-recovery run at the rodent16 size with one injected failure;
    returns the structured HealthMonitor report."""
    from benchmarks.tick_loop import RODENT, _ext_tensor
    from repro.core import Simulator
    from repro.runtime import ResilientRunner

    _, p = RODENT
    sim = Simulator(p, key=0, chunk=chunk_ticks)
    ext = _ext_tensor(p, n_ticks)
    fails = {2}

    def injector(chunk):
        if chunk in fails:
            fails.discard(chunk)
            return True
        return False

    with tempfile.TemporaryDirectory() as ckpt_dir:
        runner = ResilientRunner(sim, ckpt_dir, chunk_ticks=chunk_ticks,
                                 save_every=1, fail_injector=injector)
        fired, health = runner.run(ext)
    health["size"] = {"name": "rodent16", "n_hcu": p.n_hcu, "rows": p.rows,
                      "cols": p.cols, "n_ticks": int(n_ticks)}
    health["fired_ticks"] = int((fired >= 0).any(axis=1).sum())
    print(f"resilience/rodent16: status={health['status']} "
          f"drops={health['drops']['total']} "
          f"(budget {health['budget']['expected_drops_run']:.1f}) "
          f"restarts={health['restarts']} "
          f"{health['deadline']['observed_us_per_tick']:.0f} us/tick")
    return health


DEVICE_LOSS_DEVICES = 4        # mesh before the injected loss
DEVICE_LOSS_LOSE = 2           # trailing devices lost (16 HCUs % 2 == 0)
_CHILD_MARK = "DEVICE_LOSS_JSON:"


def _device_loss_measure(n_ticks: int, chunk_ticks: int) -> dict:
    """The measurement body — must run under a forced host-platform device
    count (`device_loss_scenario` wraps it in a child process)."""
    import jax.numpy as jnp
    import numpy as np
    from benchmarks.tick_loop import RODENT, _ext_tensor
    from repro.core import Simulator
    from repro.runtime import ElasticRunner

    _, p = RODENT
    ext = np.asarray(_ext_tensor(p, n_ticks))

    # the pinned uninterrupted trajectory: a single-process run at the
    # lossless 1-device fire cap (mesh-shape-invariance contract)
    ref = Simulator(p, key=0, cap_fire=p.n_hcu, chunk=chunk_ticks)
    f_ref = np.asarray(ref.run(jnp.asarray(ext)))

    sim = Simulator(p, key=0)
    fails = {2: DEVICE_LOSS_LOSE}
    with tempfile.TemporaryDirectory() as ckpt_dir:
        runner = ElasticRunner(sim, ckpt_dir, chunk_ticks=chunk_ticks,
                               save_every=1,
                               fail_injector=lambda c: fails.pop(c, 0))
        t_start = time.perf_counter()
        fired, health = runner.run(ext)
        wall_s = time.perf_counter() - t_start
    rec = runner.recoveries[0] if runner.recoveries else {}
    return {
        "size": {"name": "rodent16", "n_hcu": p.n_hcu, "rows": p.rows,
                 "cols": p.cols, "n_ticks": int(n_ticks),
                 "chunk_ticks": int(chunk_ticks)},
        "devices_before": DEVICE_LOSS_DEVICES,
        "devices_lost": DEVICE_LOSS_LOSE,
        "devices_after": rec.get("devices"),
        "restarts": runner.restarts,
        "restored_tick": rec.get("restored_tick"),
        "recovery_s": rec.get("recovery_s"),
        "wall_s": wall_s,
        "bitwise_identical_to_uninterrupted":
            bool((fired == f_ref).all()),
        "health": health,
    }


def device_loss_scenario(n_ticks=192, chunk_ticks=48, *,
                         legacy_cpu=False) -> dict:
    """Run the device-loss recovery scenario in a child process with
    DEVICE_LOSS_DEVICES forced host devices (the forced count must land
    before jax initializes, and this process has already imported jax)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " "
                        "--xla_force_host_platform_device_count="
                        f"{DEVICE_LOSS_DEVICES}").strip()
    cmd = [sys.executable, "-m", "benchmarks.resilience",
           "--device-loss-child", "--n-ticks", str(n_ticks),
           "--chunk-ticks", str(chunk_ticks)]
    if legacy_cpu:
        cmd.append("--legacy-cpu")
    r = subprocess.run(cmd, capture_output=True, text=True, env=env)
    if r.returncode != 0:
        raise RuntimeError("device-loss child failed:\n" + r.stderr[-3000:])
    payload = [ln for ln in r.stdout.splitlines()
               if ln.startswith(_CHILD_MARK)]
    out = json.loads(payload[-1][len(_CHILD_MARK):])
    print(f"resilience/device_loss: {out['devices_before']} -> "
          f"{out['devices_after']} devices, restarts={out['restarts']}, "
          f"recovery {out['recovery_s']:.2f} s, bitwise="
          f"{out['bitwise_identical_to_uninterrupted']}, "
          f"health={out['health']['status']}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="shorter training and rodent16 run (smoke test; "
                         "do not commit the resulting JSON)")
    ap.add_argument("--legacy-cpu", action="store_true",
                    help="pin the legacy XLA CPU runtime (the configuration "
                         "the committed numbers were measured with)")
    ap.add_argument("--out", default=None,
                    help="output path (default: <repo>/BENCH_resilience.json)")
    ap.add_argument("--device-loss-child", action="store_true",
                    help=argparse.SUPPRESS)   # internal: forced-device child
    ap.add_argument("--n-ticks", type=int, default=None,
                    help=argparse.SUPPRESS)
    ap.add_argument("--chunk-ticks", type=int, default=None,
                    help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.legacy_cpu:
        from benchmarks.run import pin_legacy_cpu_runtime
        pin_legacy_cpu_runtime()

    if args.device_loss_child:
        out = _device_loss_measure(args.n_ticks or 192,
                                   args.chunk_ticks or 48)
        print(_CHILD_MARK + json.dumps(out))
        return

    train_reps = 10 if args.fast else TRAIN_REPS
    n_ticks = 128 if args.fast else 256
    curves, chance, cfg = recall_vs_flip_rate(train_reps=train_reps)
    health = rodent16_health(n_ticks=n_ticks)
    device_loss = device_loss_scenario(
        n_ticks=96 if args.fast else 192,
        chunk_ticks=24 if args.fast else 48,
        legacy_cpu=args.legacy_cpu)

    out = pathlib.Path(args.out) if args.out else \
        pathlib.Path(__file__).resolve().parent.parent \
        / "BENCH_resilience.json"
    out.write_text(json.dumps({
        "schema": 2,
        "config": cfg,
        "chance": chance,
        "recall_vs_flip_rate": curves,
        "rodent16_health": health,
        "device_loss": device_loss,
    }, indent=2) + "\n")
    print(f"# wrote {out}", file=sys.stderr)


if __name__ == "__main__":
    main()
