"""Paper-table benchmarks (Table 1, Fig 7, Fig 10, EQ2, Table 3 analogue).

Each function returns a list of (name, us_per_call, derived) rows; run.py
prints them as CSV. Analytic tables are computed from the same BCPNNParams
the runtime uses, so any drift between model and implementation shows up
here.
"""
from __future__ import annotations

import time

from repro.core.layout import best_tile, paper_fig10_table
from repro.core.params import BCPNNParams, human_scale, rodent_scale
from repro.core.queues import (FLOPS_PER_CELL, drop_probability_per_ms,
                               expected_drops_per_month,
                               min_queue_for_monthly_drop_budget,
                               worst_case_ms_load)


def table1_requirements():
    """Paper Table 1: compute / storage / bandwidth, per HCU and full scale.

    Derivation (lazy evaluation model, average rates):
      computation = (in_rate*cols + out_rate*rows + periodic) cells/ms * flops
      storage     = rows*cols cells * 24 B (192-bit cell)
      bandwidth   = cells_touched/ms * 24 B * 2 (read+write)
      spikes      = (in+out fanout) spikes/s * 13 B/spike (Fig 3)
    """
    p = human_scale()
    rows = []
    cells_per_ms = p.in_rate * p.cols + p.out_rate * p.rows + p.cols
    flops_hcu = cells_per_ms * FLOPS_PER_CELL * 1000          # per s
    rows.append(("table1/hcu_computation_MFlops", 0.0, flops_hcu / 1e6))
    paper_cell_b = 24
    stor_hcu = p.rows * p.cols * paper_cell_b
    rows.append(("table1/hcu_storage_MB", 0.0, stor_hcu / 1e6))
    bw_hcu = cells_per_ms * paper_cell_b * 2 * 1000
    rows.append(("table1/hcu_bandwidth_MBs", 0.0, bw_hcu / 1e6))
    n = p.n_hcu
    rows.append(("table1/net_computation_TFlops", 0.0, flops_hcu * n / 1e12))
    rows.append(("table1/net_storage_TB", 0.0, stor_hcu * n / 1e12))
    rows.append(("table1/net_bandwidth_TBs", 0.0, bw_hcu * n / 1e12))
    spike_bytes = 13  # Fig 3: dest HCU + row + delay (+ plasticity fields)
    spikes_s = (p.in_rate * 1000)
    rows.append(("table1/net_spike_GBs", 0.0, spikes_s * spike_bytes * n / 1e9))
    # paper anchors for eyeballing
    rows.append(("table1/paper_anchor_computation_TFlops", 0.0, 162.0))
    rows.append(("table1/paper_anchor_storage_TB", 0.0, 50.0))
    rows.append(("table1/paper_anchor_bandwidth_TBs", 0.0, 200.0))
    return rows


def fig7_queue_dimensioning():
    """Poisson tail (EQ1) -> queue size 36 at lambda=10."""
    rows = []
    for q in (10, 22, 30, 36):
        rows.append((f"fig7/p_drop_per_ms_q{q}", 0.0,
                     drop_probability_per_ms(q, 10.0)))
    rows.append(("fig7/drops_per_month_q36", 0.0,
                 expected_drops_per_month(36, 10.0)))
    rows.append(("fig7/min_queue_for_1_per_month", 0.0,
                 float(min_queue_for_monthly_drop_budget(10.0, 1.0))))
    return rows


def fig10_rowmerge():
    """DRAM row misses vs X (paper model) + the TPU tile re-derivation."""
    rows = []
    table = paper_fig10_table()
    for x in (1, 2, 4, 5, 10, 20, 25, 50, 100):
        rows.append((f"fig10/rowmiss_per_s_X{x}", 0.0, table[x]))
    best_x = min(table, key=table.get)
    rows.append(("fig10/best_X", 0.0, float(best_x)))
    rows.append(("fig10/gain_vs_direct", 0.0, table[1] / table[best_x]))
    (xr, xc), scored = best_tile(10_000, 100, 10_000.0, 100.0)
    rows.append(("fig10/tpu_best_tile_xr", 0.0, float(xr)))
    rows.append(("fig10/tpu_best_tile_xc", 0.0, float(xc)))
    rows.append(("fig10/tpu_bytes_per_s_best", 0.0, scored[(xr, xc)]))
    return rows


def eq2_worst_case_ms():
    """EQ2-EQ4 timing model on v5e-class constants: with (k=2) and without
    (k=1) ping-pong overlap; reproduces the paper's 'achieved in 0.8 ms'
    structure with TPU terms."""
    from repro.launch.roofline import HBM_BW, PEAK_FLOPS
    p = human_scale()
    rows_ = []
    wc = worst_case_ms_load(p)
    t_mem = wc["bytes_per_ms"] / HBM_BW          # s, per HCU at full HBM
    t_cmp = wc["flops_per_ms"] / PEAK_FLOPS
    # one v5e chip runs many HCUs; per-HCU share at H_local = 256
    h_local = 256
    for k, name in ((1, "no_pingpong"), (2, "pingpong")):
        if k == 2:
            t = max(t_mem, t_cmp) * h_local
        else:
            t = (t_mem + t_cmp) * h_local
        rows_.append((f"eq2/worst_ms_{name}_ms", 0.0, t * 1e3))
        rows_.append((f"eq2/realtime_ok_{name}", 0.0, float(t < 1e-3)))
    rows_.append(("eq2/worst_case_cells", 0.0, float(wc["cells_touched"])))
    rows_.append(("eq2/worst_case_MFLOP_per_ms", 0.0,
                  wc["flops_per_ms"] / 1e6))
    return rows_


def table3_bandwidth_utilization():
    """Paper Table 3: effective/peak bandwidth (93%). TPU analogue: the
    fraction of DMA'd bytes that are useful synaptic cells under the chosen
    tile (8,128) vs the 192-bit-cell ideal."""
    p = human_scale()
    useful_row = p.cols * 20                      # bytes of one logical row
    tile_row = 128 * 20                           # padded to 128 lanes
    rows = [("table3/row_utilization", 0.0, useful_row / tile_row)]
    # column access: all 8 rows of each (8,128) tile useful? only 1 of 128
    # lanes in naive layout; with SoA planes a column gathers (R,) vectors:
    rows.append(("table3/paper_anchor_utilization", 0.0, 0.93))
    return rows


def rodent_vs_human():
    """§VII.B-C: rodent scale fits ~1/512 of the human-scale resources."""
    h, r = human_scale(), rodent_scale()
    rows = [("scale/human_storage_TB", 0.0, h.network_storage_bytes / 1e12),
            ("scale/rodent_storage_GB", 0.0, r.network_storage_bytes / 1e9),
            ("scale/ratio", 0.0,
             h.network_storage_bytes / r.network_storage_bytes)]
    return rows
