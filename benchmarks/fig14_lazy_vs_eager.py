"""Fig 14 analogue: lazy (eBrainII) vs eager (GPU-style) execution.

The paper's GPU comparison reduces to: an eager mapping touches every
synaptic cell every tick (and reaches only ~5% of rated FLOPs); the lazy
custom design touches only spike-addressed rows/columns. We MEASURE both
pipelines (same network, same spikes, verified-identical trajectories) on
CPU and report wall time per tick plus the analytic cells-touched ratio —
the bytes/energy proxy that carries to any backend.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import init_network, make_connectivity, network_run
from repro.core.params import BCPNNParams


def _bench(p, eager: bool, n_ticks: int = 64, merged: bool = False):
    """Per-tick cost through the scan-compiled runtime — measures the
    pipelines' compute, not per-tick dispatch (benchmarks/tick_loop.py
    measures that separately)."""
    key = jax.random.PRNGKey(0)
    conn = make_connectivity(p, jax.random.fold_in(key, 1))
    rng = np.random.default_rng(0)

    ext = np.full((n_ticks, p.n_hcu, 8), p.rows, np.int32)
    for t in range(n_ticks):
        for h in range(p.n_hcu):
            n = min(8, rng.poisson(4))
            ext[t, h, :n] = rng.integers(0, p.rows, n)
    ext = jnp.asarray(ext)

    st = init_network(p, key, merged=merged)       # warmup/compile pass
    st, _ = network_run(st, conn, ext, p, chunk=n_ticks, eager=eager,
                        merged=merged, cap_fire=p.n_hcu)
    jax.block_until_ready(st.hcus.zij)
    st = init_network(p, key, merged=merged)
    t0 = time.perf_counter()
    st, f = network_run(st, conn, ext, p, chunk=n_ticks, eager=eager,
                        merged=merged, cap_fire=p.n_hcu)
    jax.block_until_ready(f)
    return (time.perf_counter() - t0) / n_ticks


def lazy_vs_eager():
    p = BCPNNParams(n_hcu=4, rows=2048, cols=64, fanout=4, active_queue=16,
                    max_delay=8)
    t_lazy = _bench(p, eager=False)
    t_eager = _bench(p, eager=True)
    t_merged = _bench(p, eager=False, merged=True)
    # analytic useful-work ratio (cells touched per tick)
    lazy_cells = p.in_rate * p.cols + p.out_rate * p.rows + p.cols
    merged_cells = p.in_rate * p.cols + p.cols
    eager_cells = p.rows * p.cols
    rows = [
        ("fig14/lazy_us_per_tick", t_lazy * 1e6, 0.0),
        ("fig14/eager_us_per_tick", t_eager * 1e6, 0.0),
        ("fig14/merged_us_per_tick", t_merged * 1e6, 0.0),
        ("fig14/wall_speedup", 0.0, t_eager / t_lazy),
        ("fig14/cells_ratio_eager_over_lazy", 0.0, eager_cells / lazy_cells),
        # the paper's 'GPU reaches 5% of rated flops' as useful-work fraction
        ("fig14/eager_useful_fraction", 0.0, lazy_cells / eager_cells),
        # eBrainIII (paper §IX): merged column updates
        ("fig14/ebrain3_cells_ratio_vs_lazy", 0.0,
         lazy_cells / merged_cells),
    ]
    return rows


def kernel_row_update():
    """Microbenchmark of the fused row update (ref backend on CPU)."""
    from repro.core.traces import make_coeffs
    from repro.kernels import ops
    k = make_coeffs(2.5, 100.0, 1000.0)
    rng = np.random.default_rng(0)
    S, C = 36, 128
    a = dict(
        zij=jnp.asarray(rng.uniform(0, 2, (S, C)), jnp.float32),
        eij=jnp.asarray(rng.uniform(0, 2, (S, C)), jnp.float32),
        pij=jnp.asarray(rng.uniform(1e-3, 1, (S, C)), jnp.float32),
        tij=jnp.asarray(rng.integers(0, 50, (S, C)), jnp.int32),
        now=60, counts=jnp.ones((S,), jnp.float32),
        zj=jnp.asarray(rng.uniform(0, 1, (C,)), jnp.float32),
        p_i=jnp.asarray(rng.uniform(1e-3, 1, (S,)), jnp.float32),
        p_j=jnp.asarray(rng.uniform(1e-3, 1, (C,)), jnp.float32),
    )
    f = jax.jit(lambda **kw: ops.row_update(**kw, coeffs=k, eps=1e-4,
                                            backend="ref"))
    out = f(**a)
    jax.block_until_ready(out)
    n = 200
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(**a)
    jax.block_until_ready(out)
    us = (time.perf_counter() - t0) / n * 1e6
    flops = S * C * 60
    return [("kernel/row_update_36x128_us", us, 0.0),
            ("kernel/row_update_GFLOPs", 0.0, flops / (us * 1e-6) / 1e9)]
