"""Render the §Roofline table from dry-run JSON records.

  PYTHONPATH=src python -m benchmarks.roofline_report [--dir results/dryrun]
      [--mesh pod16x16] [--md]

Reads every <arch>__<shape>__<mesh>.json produced by repro.launch.dryrun and
prints the three roofline terms, the dominant bottleneck, MODEL_FLOPS /
HLO_FLOPs (useful fraction) and MFU at the roofline bound.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.launch.roofline import HBM_BW, ICI_BW, ICI_LINKS, PEAK_FLOPS


def load_records(d: str, mesh: str | None = None):
    recs = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        r = json.load(open(f))
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def terms(rec):
    """(t_compute, t_memory, t_collective) seconds per step per chip.

    All three inputs are PER-PARTITION already: compiled.as_text() and
    cost_analysis() describe the SPMD per-device program."""
    cost = rec.get("cost_corrected") or rec.get("cost") or {}
    fl = cost.get("flops", 0.0)
    by = cost.get("bytes accessed", 0.0)
    co = rec.get("collectives", {}).get("total", 0.0)
    return fl / PEAK_FLOPS, by / HBM_BW, co / (ICI_LINKS * ICI_BW)


def analyze_record(rec):
    if "error" in rec:
        return None
    if "skipped" in rec:
        return {"arch": rec["arch"], "shape": rec["shape"],
                "mesh": rec["mesh"], "skipped": rec["skipped"]}
    tc, tm, tl = terms(rec)
    tstep = max(tc, tm, tl)
    which = {"compute": tc, "memory": tm, "collective": tl}
    bott = max(which, key=which.get)
    mfl = rec.get("model_flops", 0.0)
    hlo_total = (rec.get("cost_corrected") or rec.get("cost", {})
                 ).get("flops", 0.0) * rec["chips"]
    useful = mfl / hlo_total if hlo_total else 0.0
    mfu = mfl / (tstep * rec["chips"] * PEAK_FLOPS) if tstep else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": rec["chips"], "t_compute_ms": tc * 1e3,
        "t_memory_ms": tm * 1e3, "t_collective_ms": tl * 1e3,
        "bottleneck": bott, "useful_fraction": useful,
        "mfu_at_roofline": mfu, "t_step_ms": tstep * 1e3,
    }


def render(recs, md: bool = False):
    rows = []
    skips = []
    for rec in recs:
        a = analyze_record(rec)
        if a is None:
            rows.append(f"{rec.get('arch','?'):28s} {rec.get('shape','?'):12s}"
                        f" ERROR {rec.get('error','')[:60]}")
            continue
        if "skipped" in a:
            skips.append(a)
            continue
        rows.append(a)
    sep = " | " if md else " "
    hdr = sep.join([f"{'arch':28s}", f"{'shape':12s}", f"{'t_comp_ms':>9s}",
                    f"{'t_mem_ms':>9s}", f"{'t_coll_ms':>9s}",
                    f"{'bottleneck':10s}", f"{'useful':>6s}",
                    f"{'MFU@rl':>6s}"])
    lines = [hdr]
    if md:
        lines.append(sep.join(["-" * 28, "-" * 12, "-" * 9, "-" * 9, "-" * 9,
                               "-" * 10, "-" * 6, "-" * 6]))
    for a in rows:
        if isinstance(a, str):
            lines.append(a)
            continue
        lines.append(sep.join([
            f"{a['arch']:28s}", f"{a['shape']:12s}",
            f"{a['t_compute_ms']:9.2f}", f"{a['t_memory_ms']:9.2f}",
            f"{a['t_collective_ms']:9.2f}", f"{a['bottleneck']:10s}",
            f"{a['useful_fraction']:6.3f}", f"{a['mfu_at_roofline']:6.3f}"]))
    for s in skips:
        lines.append(f"{s['arch']:28s}{sep}{s['shape']:12s}{sep}"
                     f"skipped: {s['skipped'][:60]}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    recs = load_records(args.dir, args.mesh)
    print(render(recs, md=args.md))


if __name__ == "__main__":
    main()
