"""Serving benchmark: continuous-batching recall QPS at rodent16.

  PYTHONPATH=src python -m benchmarks.serve_bcpnn [--legacy-cpu] [--fast]

Measures the whole serving path (`repro.launch.serve_bcpnn`) end to end and
writes BENCH_serving.json for CI trending + the QPS-at-SLO regression gate
(`benchmarks/check_regression.py --serving-committed`):

  1. train the associative memory at the rodent16 benchmark dimensions
     (the tick-loop size preset with the assoc-protocol dynamics — slow P
     traces, soft WTA — swapped in; dims are what price a tick, dynamics
     are what make recall converge);
  2. serve >= 1000 synthetic client sessions (partial cues of the trained
     patterns) through a BCPNNRecallServer, paced closed-loop against
     `queue.free` so no request is rejected;
  3. report throughput (qps), latency percentiles, the drop-budget health
     verdict, and recall accuracy of the served sessions.

The gated metric is qps_at_slo: the measured throughput if the p95 sojourn
(submit -> finish, queueing included) met the SLO, else 0.0 — so CI fails
both on a throughput collapse and on a latency blow-up.

A warmup server with identical configuration runs first: `_serve_step` and
`write_sessions` are module-level jits, so the measured server hits a warm
jit cache and the numbers exclude compilation (same discipline as the
tick-loop benchmark's scan warmup).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import sys
import time

N_PATTERNS = 3
TRAIN_REPS = 10
CUE_FRACTION = 0.6
# sojourn SLO (queueing included): with the default queue_capacity=32 the
# closed-loop pacing keeps ~a full queue waiting, so p95 sojourn is about
# queue_capacity/qps (~10 s measured) — the SLO bounds that at 2x for CI
# noise; a latency blow-up beyond it zeroes qps_at_slo and fails the gate
SLO_MS = 20000.0


def _serving_params():
    """rodent16 dimensions (benchmarks/tick_loop.RODENT) with the
    assoc-memory dynamics from `repro.experiments.assoc_params`."""
    from benchmarks.tick_loop import RODENT
    _, p = RODENT
    return dataclasses.replace(p, mean_delay=1.5, out_rate=1.0,
                               wta_temp=0.25, tau_p=400.0)


def _make_clients(p, patterns, n_clients, budget_ticks, seed=0):
    """Synthetic client sessions: partial cues of the trained patterns.
    Returns (requests, pattern-id per rid)."""
    import numpy as np
    from repro.launch.serve_bcpnn import RecallRequest

    rng = np.random.default_rng(seed)
    reqs, pids = [], []
    for rid in range(n_clients):
        pid = rid % len(patterns)
        mask = rng.random(p.n_hcu) < CUE_FRACTION
        reqs.append(RecallRequest(rid, np.asarray(patterns[pid], np.int32),
                                  mask, budget_ticks=budget_ticks))
        pids.append(pid)
    return reqs, pids


def _recall_accuracy(p, done, pids, attractor):
    """Pattern-completion score over the UNDRIVEN HCUs of every completed
    session (same probe as experiments.recall_accuracy)."""
    import numpy as np

    correct = total = 0
    for req in done:
        att = attractor[pids[req.rid]]
        probe = ~np.asarray(req.cue_mask, bool) & (req.winners >= 0) \
            & (att >= 0)
        correct += int((req.winners[probe] == att[probe]).sum())
        total += int(probe.sum())
    return correct, total


def measure(n_clients, *, slots=8, queue_capacity=32, step_ticks=12,
            budget_ticks=48, train_reps=TRAIN_REPS, slo_ms=SLO_MS):
    import numpy as np
    from repro.core import Simulator
    from repro.data import make_patterns
    from repro.experiments import train_assoc
    from repro.launch.serve_bcpnn import BCPNNRecallServer

    p = _serving_params()
    sim = Simulator(p, key=0, cap_fire=p.n_hcu)
    patterns = make_patterns(p, N_PATTERNS, seed=3)
    t0 = time.perf_counter()
    attractor = train_assoc(sim, patterns, reps=train_reps)
    print(f"serve/train: {N_PATTERNS} patterns x {train_reps} reps "
          f"in {time.perf_counter() - t0:.1f} s")

    def serve(requests, req_rate):
        srv = BCPNNRecallServer(sim, slots=slots,
                                queue_capacity=queue_capacity,
                                step_ticks=step_ticks, req_rate=req_rate)
        pending = list(requests)
        while pending or srv.busy:
            while pending and srv.queue.free > 0:
                srv.submit(pending.pop(0))
            srv.step()
        return srv

    # warmup: identical server configuration -> the measured run hits a
    # warm jit cache (_serve_step / write_sessions are module-level jits)
    warm_reqs, _ = _make_clients(p, patterns, 2 * slots, budget_ticks,
                                 seed=99)
    t0 = time.perf_counter()
    serve(warm_reqs, req_rate=0.0)
    print(f"serve/warmup: {2 * slots} sessions (compile) "
          f"in {time.perf_counter() - t0:.1f} s")

    reqs, pids = _make_clients(p, patterns, n_clients, budget_ticks)
    t0 = time.perf_counter()
    srv = serve(reqs, req_rate=n_clients)   # paced lossless: rate ~ load
    wall_s = time.perf_counter() - t0

    s = srv.stats(slo_ms=slo_ms)
    qps = s["completed"] / wall_s
    correct, total = _recall_accuracy(p, srv.completed, pids, attractor)
    out = {
        "n_clients": n_clients,
        "completed": s["completed"],
        "done": s["done"],
        "expired": s["expired"],
        "wall_s": wall_s,
        "qps": qps,
        "p50_service_ms": s["p50_service_ms"],
        "p95_service_ms": s["p95_service_ms"],
        "p50_sojourn_ms": s["p50_sojourn_ms"],
        "p95_sojourn_ms": s["p95_sojourn_ms"],
        "slo_ms": slo_ms,
        "slo_met": s["slo_met"],
        "qps_at_slo": qps if s["slo_met"] else 0.0,
        "recall_correct": correct,
        "recall_total": total,
        "recall_acc": correct / max(total, 1),
        "chance": 1.0 / p.cols,
        "queue": s["queue"],
        "health": s["health"],
    }
    cfg = {"size": "rodent16", "n_hcu": p.n_hcu, "rows": p.rows,
           "cols": p.cols, "fanout": p.fanout, "slots": slots,
           "queue_capacity": queue_capacity, "step_ticks": step_ticks,
           "budget_ticks": budget_ticks, "n_patterns": N_PATTERNS,
           "train_reps": train_reps, "cue_fraction": CUE_FRACTION,
           "dynamics": "assoc (wta_temp=0.25, tau_p=400, mean_delay=1.5, "
                       "out_rate=1.0)"}
    print(f"serve/rodent16: {out['completed']} sessions "
          f"({out['done']} converged, {out['expired']} expired) in "
          f"{wall_s:.1f} s = {qps:.1f} qps, p95 sojourn "
          f"{out['p95_sojourn_ms']:.0f} ms (SLO {slo_ms:.0f} ms, "
          f"met={out['slo_met']}), recall {correct}/{total} "
          f"(acc={out['recall_acc']:.2f}, chance {out['chance']:.3f}), "
          f"health={out['health']['status']}")
    return out, cfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=1000)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--queue", type=int, default=32)
    ap.add_argument("--step-ticks", type=int, default=12)
    ap.add_argument("--budget", type=int, default=48)
    ap.add_argument("--slo-ms", type=float, default=SLO_MS)
    ap.add_argument("--fast", action="store_true",
                    help="few clients, short training (smoke test; do not "
                         "commit the resulting JSON)")
    ap.add_argument("--legacy-cpu", action="store_true",
                    help="pin the legacy XLA CPU runtime (the configuration "
                         "the committed numbers were measured with)")
    ap.add_argument("--out", default=None,
                    help="output path (default: <repo>/BENCH_serving.json)")
    args = ap.parse_args()
    if args.legacy_cpu:
        from benchmarks.run import pin_legacy_cpu_runtime
        pin_legacy_cpu_runtime()

    n_clients = 48 if args.fast else args.clients
    train_reps = 3 if args.fast else TRAIN_REPS
    result, cfg = measure(n_clients, slots=args.slots,
                          queue_capacity=args.queue,
                          step_ticks=args.step_ticks,
                          budget_ticks=args.budget,
                          train_reps=train_reps, slo_ms=args.slo_ms)

    out = pathlib.Path(args.out) if args.out else \
        pathlib.Path(__file__).resolve().parent.parent / "BENCH_serving.json"
    out.write_text(json.dumps({
        "schema": 1,
        "config": cfg,
        "rodent16": result,
    }, indent=2) + "\n")
    print(f"# wrote {out}", file=sys.stderr)


if __name__ == "__main__":
    main()
